//! Periodic offline analysis (Fig. 7 in miniature): how prediction
//! accuracy decays as the knowledge base goes stale, and how the
//! *additive* refresh path restores it without re-reading old logs.
//!
//!     cargo run --release --example offline_refresh

use dtopt::experiments::common::{default_backend, ExpConfig, World};
use dtopt::experiments::fig7;

fn main() {
    let mut backend = default_backend();
    let world = World::prepare(ExpConfig::quick(), &mut backend);
    println!(
        "initial knowledge base: {} clusters over {} rows (built through day {})\n",
        world.kb.clusters.len(),
        world.rows.len(),
        world.kb.built_through_day
    );
    let periods = [1u64, 2, 5];
    let result = fig7::run(&world, 8, &periods);
    print!("{}", fig7::render(&result));
    for (desc, ok) in fig7::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!(
        "\npaper: daily refresh ≈92% accuracy, 10-day-stale ≈87% — the additive\n\
         sufficient-statistics design makes each refresh O(new rows) only."
    );
}
