//! Coordinator-as-a-service demo: a mixed stream of transfer requests
//! across all three testbeds, served concurrently by the thread-pool
//! coordinator with ASM as the default optimizer, reporting the
//! service-side metrics (per-optimizer achieved throughput and the
//! decision-latency distribution — the paper's "constant time" claim).
//!
//!     cargo run --release --example serve_requests -- [--requests N]

use dtopt::coordinator::{OptimizerKind, TransferRequest};
use dtopt::experiments::common::{default_backend, ExpConfig, World};
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::TestbedId;
use dtopt::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(36);
    let mut backend = default_backend();
    let world = World::prepare(ExpConfig::quick(), &mut backend);
    let coord = world.coordinator(4);
    let mut rng = Rng::new(99);

    // A mixed stream: 2/3 default (ASM), 1/3 explicit baseline picks —
    // the coordinator routes per request.
    let requests: Vec<TransferRequest> = (0..n)
        .map(|i| {
            let optimizer = match i % 6 {
                0 => Some(OptimizerKind::Harp),
                3 => Some(OptimizerKind::AnnOt),
                _ => None, // coordinator default (ASM)
            };
            TransferRequest {
                id: coord.fresh_id(),
                testbed: TestbedId::all()[rng.index(3)],
                dataset: Dataset::sample(SizeClass::all()[rng.index(3)], &mut rng),
                t_submit: (world.config.history_days + 1) as f64 * 86_400.0
                    + rng.range_f64(0.0, 86_400.0),
                state_override: None,
                optimizer,
                seed: 7_000 + i as u64,
            }
        })
        .collect();

    let start = std::time::Instant::now();
    // Submit all asynchronously, then collect — the workers overlap.
    let receivers: Vec<_> = requests.into_iter().map(|r| coord.submit(r)).collect();
    let responses: Vec<_> = receivers.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = start.elapsed();

    println!(
        "served {} requests in {wall:.2?} wall ({:.1} req/s); decision p95 per optimizer below\n",
        responses.len(),
        responses.len() as f64 / wall.as_secs_f64()
    );
    print!("{}", coord.metrics.render());
    let asm_decisions: Vec<f64> = responses
        .iter()
        .filter(|r| r.optimizer == "ASM")
        .map(|r| r.decision_wall_ns as f64)
        .collect();
    if !asm_decisions.is_empty() {
        println!(
            "\nASM decision wall-clock: mean {}, max {} — constant-time KB queries",
            dtopt::util::timer::fmt_ns(dtopt::util::stats::mean(&asm_decisions)),
            dtopt::util::timer::fmt_ns(asm_decisions.iter().cloned().fold(0.0, f64::max)),
        );
    }
    coord.shutdown();
}
