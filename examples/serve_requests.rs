//! Coordinator-as-a-service demo of the full closed loop: a mixed
//! stream of transfer requests is served concurrently by the
//! thread-pool coordinator while the knowledge lifecycle service runs
//! behind it — every completed transfer is ingested into day-partition
//! logs, the refresh policy triggers an *additive* offline update over
//! only the new partitions, and the refreshed knowledge base hot-swaps
//! in as the next snapshot generation without pausing in-flight
//! transfers. Later requests report the generation they were served
//! from.
//!
//!     cargo run --release --example serve_requests -- [--requests N]

use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::experiments::common::{default_backend, ExpConfig, World};
use dtopt::feedback::{FeedbackConfig, FeedbackService, IngestConfig, RefreshPolicy};
use dtopt::logs::store::LogStore;
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::TestbedId;
use dtopt::util::rng::Rng;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(36);
    let mut backend = default_backend();
    let world = World::prepare(ExpConfig::quick(), &mut backend);

    // The knowledge lifecycle service: bounded ingestion into a scratch
    // log store, with a background refresher that fires once half of
    // wave 1 has been flushed.
    let store_dir =
        std::env::temp_dir().join(format!("dtopt_serve_requests_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let service = FeedbackService::start(
        world.kb.clone(),
        LogStore::open(&store_dir)?,
        FeedbackConfig {
            ingest: IngestConfig {
                capacity: 1024,
                flush_batch: 8,
                flush_interval: Duration::from_millis(10),
            },
            policy: RefreshPolicy {
                min_new_rows: (n / 2).max(4) as u64,
                min_interval: Duration::ZERO,
                ..Default::default()
            },
            poll_interval: Duration::from_millis(10),
            background: true,
        },
    )?;
    // ASM requests share the probe plane: concurrent requests for the
    // same network slice coalesce their sampling ladders and reuse the
    // decaying network-state estimate.
    let plane = std::sync::Arc::new(dtopt::probe::ProbePlane::default());
    let coord = Coordinator::with_feedback(
        &service,
        world.rows.clone(),
        CoordinatorConfig {
            workers: 4,
            default_optimizer: OptimizerKind::Asm,
            seed: world.config.seed,
            probe: Some(plane),
            ..Default::default()
        },
    );

    // A mixed stream: 2/3 default (ASM), 1/3 explicit baseline picks —
    // the coordinator routes per request.
    let mut rng = Rng::new(99);
    let mut make_wave = |wave: usize| -> Vec<TransferRequest> {
        (0..n)
            .map(|i| {
                let optimizer = match i % 6 {
                    0 => Some(OptimizerKind::Harp),
                    3 => Some(OptimizerKind::AnnOt),
                    _ => None, // coordinator default (ASM)
                };
                TransferRequest {
                    id: coord.fresh_id(),
                    testbed: TestbedId::all()[rng.index(3)],
                    dataset: Dataset::sample(SizeClass::all()[rng.index(3)], &mut rng),
                    t_submit: (world.config.history_days + 1 + wave as u64) as f64 * 86_400.0
                        + rng.range_f64(0.0, 86_400.0),
                    state_override: None,
                    optimizer,
                    seed: 7_000 + (wave * n + i) as u64,
                }
            })
            .collect()
    };

    // --- Wave 1: served from the startup KB (generation 0) --------------
    let start = Instant::now();
    let receivers: Vec<_> = make_wave(0).into_iter().map(|r| coord.submit(r)).collect();
    let wave1: Vec<_> = receivers.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = start.elapsed();
    let gen1 = wave1.iter().map(|r| r.kb_generation).max().unwrap_or(0);
    println!(
        "wave 1: served {} requests in {wall:.2?} ({:.1} req/s), all from KB generation ≤ {gen1}",
        wave1.len(),
        wave1.len() as f64 / wall.as_secs_f64()
    );

    // --- The loop turns: ingested logs trip the policy, the refresher
    // publishes the next generation while the service keeps running ------
    let deadline = Instant::now() + Duration::from_secs(30);
    while service.generation() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    if service.generation() == 0 {
        // Policy did not trip in time (tiny --requests): force the turn.
        service.flush_barrier(Duration::from_secs(10));
        let _ = service.refresh_now()?;
    }
    println!(
        "refresh: policy fired after {} flushed rows → KB generation {} published (no pause)",
        service.stats.rows_flushed.load(std::sync::atomic::Ordering::Relaxed),
        service.generation()
    );

    // --- Wave 2: new transfers observe the refreshed snapshot -----------
    let start = Instant::now();
    let receivers: Vec<_> = make_wave(1).into_iter().map(|r| coord.submit(r)).collect();
    let wave2: Vec<_> = receivers.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = start.elapsed();
    let gen2 = wave2.iter().map(|r| r.kb_generation).min().unwrap_or(0);
    println!(
        "wave 2: served {} requests in {wall:.2?}, all from KB generation ≥ {gen2}\n",
        wave2.len()
    );
    assert!(gen2 >= 1, "wave 2 must observe the refreshed snapshot");

    print!("{}", coord.metrics.render());
    let asm_decisions: Vec<f64> = wave1
        .iter()
        .chain(&wave2)
        .filter(|r| r.optimizer == "ASM")
        .map(|r| r.decision_wall_ns as f64)
        .collect();
    if !asm_decisions.is_empty() {
        println!(
            "\nASM decision wall-clock: mean {}, max {} — constant-time KB queries",
            dtopt::util::timer::fmt_ns(dtopt::util::stats::mean(&asm_decisions)),
            dtopt::util::timer::fmt_ns(asm_decisions.iter().cloned().fold(0.0, f64::max)),
        );
    }
    coord.shutdown();
    service.shutdown();
    let _ = std::fs::remove_dir_all(&store_dir);
    Ok(())
}
