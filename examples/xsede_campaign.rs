//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! a realistic transfer campaign on the simulated XSEDE testbed.
//!
//! * 14 simulated days of production-like history (~10–15k log rows),
//! * full offline pipeline (PJRT artifacts when built),
//! * a held-out campaign across all file classes and both load periods,
//!   served through the coordinator by ASM and every baseline on
//!   identical workloads,
//! * the paper's headline metrics: achieved throughput per class/period,
//!   fraction of the true optimum, prediction accuracy (Eq. 25), and
//!   samples-to-convergence.
//!
//!     cargo run --release --example xsede_campaign        # full
//!     cargo run --release --example xsede_campaign -- --quick

use dtopt::coordinator::{OptimizerKind, TransferRequest};
use dtopt::experiments::common::{default_backend, submit_time, ExpConfig, Table, World};
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::{Testbed, TestbedId};
use dtopt::sim::traffic::Period;
use dtopt::util::rng::Rng;
use dtopt::util::stats::{mean, paper_accuracy};
use std::collections::BTreeMap;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let config = if quick {
        ExpConfig::quick()
    } else {
        ExpConfig { history_days: 14, arrivals_per_hour: 35.0, requests_per_cell: 5, seed: 0xCAFE }
    };
    let mut backend = default_backend();
    println!("== xsede campaign ({} backend) ==", backend.name());
    let start = std::time::Instant::now();
    let world = World::prepare(config, &mut backend);
    println!(
        "offline: {} rows → {} clusters, {} surfaces ({:.2?})",
        world.rows.len(),
        world.kb.clusters.len(),
        world.kb.clusters.iter().map(|c| c.surfaces.len()).sum::<usize>(),
        start.elapsed()
    );

    let coord = world.coordinator(4);
    let testbed = Testbed::by_id(TestbedId::Xsede);
    let mut table =
        Table::new(&["class", "period", "model", "mean_gbps", "frac_opt", "acc_%", "samples"]);
    let mut asm_fracs = Vec::new();
    let mut asm_accs = Vec::new();
    for class in SizeClass::all() {
        for period in [Period::OffPeak, Period::Peak] {
            let mut per_model: BTreeMap<&'static str, (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> =
                BTreeMap::new();
            for kind in OptimizerKind::all() {
                let mut rng = Rng::new(
                    0xCA11 ^ class.name().len() as u64 ^ (period.name().len() as u64) << 8,
                );
                let requests: Vec<TransferRequest> = (0..world.config.requests_per_cell)
                    .map(|i| {
                        let mut case = rng.fork(i as u64);
                        TransferRequest {
                            id: coord.fresh_id(),
                            testbed: TestbedId::Xsede,
                            dataset: Dataset::sample(class, &mut case),
                            t_submit: submit_time(
                                &testbed,
                                period,
                                world.config.history_days,
                                &mut case,
                            ),
                            state_override: None,
                            optimizer: Some(kind),
                            seed: 0xCA11 ^ (i as u64) << 24,
                        }
                    })
                    .collect();
                for resp in coord.run_batch(requests) {
                    let entry = per_model.entry(kind.name()).or_default();
                    entry.0.push(resp.report.achieved_mbps() / 1e3);
                    entry.1.push(resp.report.achieved_mbps() / resp.optimal_mbps.max(1.0));
                    if let Some(pred) = resp.report.predicted_mbps {
                        entry.2.push(paper_accuracy(resp.report.final_steady_mbps(), pred));
                    }
                    entry.3.push(resp.report.sample_transfers() as f64);
                }
            }
            for (model, (gbps, fracs, accs, samples)) in &per_model {
                table.push(vec![
                    class.name().into(),
                    period.name().into(),
                    model.to_string(),
                    format!("{:.2}", mean(gbps)),
                    format!("{:.2}", mean(fracs)),
                    if accs.is_empty() { "-".into() } else { format!("{:.1}", mean(accs)) },
                    format!("{:.1}", mean(samples)),
                ]);
                if *model == "ASM" {
                    asm_fracs.extend_from_slice(fracs);
                    asm_accs.extend_from_slice(accs);
                }
            }
        }
    }
    print!("{}", table.render());
    println!(
        "\nheadline: ASM mean fraction-of-optimal = {:.2}, mean prediction accuracy = {:.1}% \
         (paper: up to 93% accuracy), campaign wall time {:.2?}",
        mean(&asm_fracs),
        mean(&asm_accs),
        start.elapsed()
    );
    print!("\ncoordinator metrics:\n{}", coord.metrics.render());
    coord.shutdown();
}
