//! Quickstart: the minimal end-to-end flow of the library —
//! 1. generate a small transfer history on the simulated XSEDE testbed,
//! 2. run offline knowledge discovery,
//! 3. serve one transfer request with the Adaptive Sampling Module,
//! 4. compare against the Globus static baseline and the true optimum.
//!
//!     cargo run --release --example quickstart

use dtopt::baselines::go::GlobusOnline;
use dtopt::baselines::{Optimizer, TransferEnv};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::online::asm::AdaptiveSampling;
use dtopt::sim::dataset::Dataset;
use dtopt::sim::testbed::Testbed;
use dtopt::sim::transfer::NetState;

fn main() -> anyhow::Result<()> {
    // 1. Historical logs: 5 simulated days of production-like traffic.
    let testbed = Testbed::xsede();
    let rows = generate(
        &testbed,
        &GenConfig { days: 5, arrivals_per_hour: 30.0, start_day: 0, seed: 42 },
    );
    println!("history: {} transfer-log rows", rows.len());

    // 2. Offline knowledge discovery: clustering → throughput surfaces →
    //    confidence regions → precomputed maxima → sampling regions.
    let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign)?;
    println!(
        "knowledge base: {} clusters, {} surfaces",
        kb.clusters.len(),
        kb.clusters.iter().map(|c| c.surfaces.len()).sum::<usize>()
    );

    // 3. A new transfer request under a hidden network load the
    //    optimizer has never seen.
    let dataset = Dataset::new(200, 100.0); // 20 GB of 100 MB files
    let hidden = NetState::with_load(0.35);
    let mut env = TransferEnv::new(testbed.clone(), dataset, hidden, 7);
    let report = AdaptiveSampling::new(&kb).run(&mut env);
    let (_, optimal) = testbed.path.optimal(&dataset, &hidden, 16);
    println!(
        "\nASM : {:.0} Mbps end-to-end ({} sample transfers, final θ = {})",
        report.achieved_mbps(),
        report.sample_transfers(),
        report.final_params
    );

    // 4. Baseline comparison.
    let mut env_go = TransferEnv::new(testbed, dataset, hidden, 7);
    let go = GlobusOnline.run(&mut env_go);
    println!("GO  : {:.0} Mbps end-to-end (static defaults)", go.achieved_mbps());
    println!("OPT : {optimal:.0} Mbps (simulator ground truth)");
    println!(
        "\nASM reaches {:.0}% of optimal vs GO's {:.0}%",
        100.0 * report.achieved_mbps() / optimal,
        100.0 * go.achieved_mbps() / optimal
    );
    Ok(())
}
