"""AOT lowering: jax -> HLO *text* artifacts + manifest.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  Lowered with
``return_tuple=True`` so the rust side unwraps a tuple of outputs.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import aot_signatures


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_entry(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"format": "hlo-text", "artifacts": {}}
    for name, fn, example_args in aot_signatures():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        # Output shapes from an abstract evaluation.
        out_avals = jax.eval_shape(fn, *example_args)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [shape_entry(a) for a in example_args],
            "outputs": [shape_entry(a) for a in out_avals],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
