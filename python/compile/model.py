"""L2 compute graphs for the offline-analysis hot spots.

These are the jax functions AOT-lowered to the HLO artifacts the rust
coordinator executes through PJRT.  Both call the L1 Pallas kernels
(interpret=True) so kernel + graph lower into one HLO module.

Fixed AOT shapes (rust pads/masks to them; see `aot.py` and
`rust/src/runtime/artifacts.rs`):

* k-means step:  points (1024, 8) f32, centroids (32, 8) f32,
  weights (1024,) f32  ->  new_centroids (32, 8), counts (32,),
  inertia (1,), assign (1024,) i32
* pairwise:      points (1024, 8), centroids (32, 8) -> (1024, 32)
* surface eval:  coeffs (64, 7, 7, 4, 4) -> (64, 56, 56)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.pairwise import pairwise_sq_dists
from .kernels.surface_eval import assemble, eval_patches

# Canonical AOT shapes.
KM_N = 1024
KM_K = 32
KM_D = 8
SURF_S = 64
SURF_G = 7  # patches per axis (8x8 knots)
SURF_R = 8  # sub-resolution per patch


def pairwise(points, centroids):
    """Raw pairwise squared distances (the L1 kernel end-to-end)."""
    return (pairwise_sq_dists(points, centroids),)


def kmeans_step(points, centroids, weights):
    """One weighted Lloyd iteration.

    Weighted so padded points (w=0) vanish from the update; empty
    clusters keep their previous centroid (standard fix-up, matches the
    rust native implementation bit-for-bit in semantics).
    """
    d2 = pairwise_sq_dists(points, centroids)  # (N, K)
    assign = jnp.argmin(d2, axis=1)  # (N,)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=jnp.float32)  # (N, K)
    wo = onehot * weights[:, None]  # (N, K)
    counts = jnp.sum(wo, axis=0)  # (K,)
    sums = wo.T @ points  # (K, D)
    new_centroids = jnp.where(
        counts[:, None] > 0.0, sums / jnp.maximum(counts[:, None], 1e-12), centroids
    )
    min_d2 = jnp.min(d2, axis=1)
    inertia = jnp.sum(min_d2 * weights)[None]
    return new_centroids, counts, inertia, assign.astype(jnp.int32)


def surface_eval(coeffs, v):
    """Per-patch dense evaluations ``(S, GP, GC, R, R)``.

    Two HLO-text interchange constraints shape this signature (both
    discovered the hard way; see DESIGN.md):
    * the Vandermonde `v` is a runtime input — the HLO text emitter
      elides non-scalar constants (``constant({...})``), which the
      0.5.1 parser silently reads as zeros;
    * the stitch into dense ``(S, GP·R, GC·R)`` grids happens in rust —
      the trailing transpose carries a permuted layout annotation the
      0.5.1 round-trip executes incorrectly.
    """
    return (eval_patches(coeffs, v, res=SURF_R),)


def aot_signatures():
    """(name, fn, example_args) for every artifact `aot.py` emits."""
    f32 = jnp.float32
    return [
        (
            "pairwise",
            pairwise,
            (
                jax.ShapeDtypeStruct((KM_N, KM_D), f32),
                jax.ShapeDtypeStruct((KM_K, KM_D), f32),
            ),
        ),
        (
            "kmeans_step",
            kmeans_step,
            (
                jax.ShapeDtypeStruct((KM_N, KM_D), f32),
                jax.ShapeDtypeStruct((KM_K, KM_D), f32),
                jax.ShapeDtypeStruct((KM_N,), f32),
            ),
        ),
        (
            "surface_eval",
            surface_eval,
            (
                jax.ShapeDtypeStruct((SURF_S, SURF_G, SURF_G, 4, 4), f32),
                jax.ShapeDtypeStruct((SURF_R, 4), f32),
            ),
        ),
    ]
