"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract.  pytest asserts kernel == ref to float tolerance across a
hypothesis sweep of shapes; the rust side separately asserts the PJRT
artifacts match its native implementations."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pairwise_sq_dists_ref(points, centroids):
    """(N, K) squared Euclidean distances, direct broadcast form."""
    diff = points[:, None, :] - centroids[None, :, :]
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


def eval_patches_ref(coeffs, res: int):
    """(S, GP, GC, res, res) bicubic patch evaluations, loop-free."""
    t = np.arange(res, dtype=np.float32) / np.float32(res)
    v = np.stack([np.ones_like(t), t, t * t, t * t * t], axis=1)  # (res, 4)
    v = jnp.asarray(v)
    # out[s,i,j,a,b] = sum_{r,c} V[a,r] coeffs[s,i,j,r,c] V[b,c]
    return jnp.einsum("ar,sijrc,bc->sijab", v, coeffs.astype(jnp.float32), v)


def kmeans_step_ref(points, centroids, weights):
    """Reference Lloyd step (numpy semantics, used by pytest):

    returns (new_centroids, counts, inertia) with weighted points and
    empty clusters keeping their previous centroid."""
    d2 = np.asarray(pairwise_sq_dists_ref(points, centroids))
    assign = d2.argmin(axis=1)
    n, _ = points.shape
    k, dim = centroids.shape
    w = np.asarray(weights, dtype=np.float64)
    sums = np.zeros((k, dim))
    counts = np.zeros(k)
    for i in range(n):
        sums[assign[i]] += w[i] * np.asarray(points[i], dtype=np.float64)
        counts[assign[i]] += w[i]
    new_c = np.where(
        counts[:, None] > 0, sums / np.maximum(counts[:, None], 1e-12), np.asarray(centroids)
    )
    inertia = float(np.sum(w * d2[np.arange(n), assign]))
    return new_c.astype(np.float32), counts.astype(np.float32), np.float32(inertia)
