"""L1 Pallas kernel: batched bicubic-patch evaluation.

Offline surface construction produces, per (cluster, load-bin) surface,
a ``(GP, GC)`` grid of bicubic patches with 4x4 power-basis coefficient
tiles ``A`` such that ``f(t, u) = sum_{r,c} A[r, c] t^r u^c`` on the
unit square (the rust `math::bicubic` layout).  Dense evaluation over a
``R x R`` sub-grid per patch — used for maxima scans and the Fig. 1
surface dumps — is a pair of tiny matmuls per patch:

    OUT = T @ A @ U^T,   T[i, r] = t_i^r,  U[j, c] = u_j^c

The kernel runs on a ``(S, GP, GC)`` grid, one program per patch; the
Vandermonde matrices are compile-time constants that live in VMEM, and
each program touches exactly one (4, 4) coefficient tile and one
(R, R) output tile.  VMEM per program: 16*4 + 2*R*4*4 + R*R*4 bytes
(R=8: ~0.6 KiB) — the schedule is wholly BlockSpec-driven.

Lowered with ``interpret=True`` (see pairwise.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_RES = 8


def vandermonde(res: int) -> np.ndarray:
    """``V[i, r] = (i / res)^r`` for r < 4 — local coordinates of the
    evaluation sub-grid (half-open: patch (i+1) owns the right edge)."""
    t = np.arange(res, dtype=np.float32) / np.float32(res)
    return np.stack([np.ones_like(t), t, t * t, t * t * t], axis=1)  # (res, 4)


def _surface_kernel(v_ref, a_ref, o_ref, *, gp: int, gc: int, res: int):
    """OUT[p] = V @ A[p] @ V^T for every patch p of one surface.

    Buffers are kept 2-D throughout: the HLO-text → xla_extension 0.5.1
    round-trip executes the ≥4-D dynamic-update-slices that pallas
    interpret mode emits for higher-rank blocks incorrectly (observed:
    all-zero outputs), while rank ≤ 2 loop state is solid — so the
    surface batch is flattened to (S, GP·GC·16) in / (S, GP·GC·res²)
    out and each grid step processes one whole surface.
    """
    a = a_ref[...].reshape(gp * gc, 4, 4)  # (P, 4, 4)
    v = v_ref[...]  # (res, 4)
    # (res,4) · (P,4,4) → (P,res,4), then · (4,res) → (P,res,res)
    ta = jnp.einsum("ar,prc->pac", v, a)
    out = jnp.einsum("pac,bc->pab", ta, v)
    o_ref[...] = out.reshape(1, gp * gc * res * res)


@functools.partial(jax.jit, static_argnames=("res", "interpret"))
def eval_patches(coeffs, v=None, *, res: int = DEFAULT_RES, interpret: bool = True):
    """Evaluate all patches densely.

    coeffs: ``(S, GP, GC, 4, 4)`` power-basis tiles.
    v: optional ``(res, 4)`` Vandermonde; passed as a runtime *input*
       because the HLO text emitter elides non-scalar constants
       (``constant({...})``) which the 0.5.1 text parser reads as
       zeros — array constants must never be baked into the artifact.
    returns ``(S, GP, GC, res, res)`` patch-local evaluations.
    """
    s, gp, gc, four_a, four_b = coeffs.shape
    if (four_a, four_b) != (4, 4):
        raise ValueError(f"coeff tiles must be 4x4, got {four_a}x{four_b}")
    grid = (s,)
    if v is None:
        v = jnp.asarray(vandermonde(res))
    if v.shape != (res, 4):
        raise ValueError(f"vandermonde must be ({res}, 4), got {v.shape}")
    flat_in = coeffs.astype(jnp.float32).reshape(s, gp * gc * 16)
    kernel = functools.partial(_surface_kernel, gp=gp, gc=gc, res=res)
    flat_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((res, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, gp * gc * 16), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, gp * gc * res * res), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, gp * gc * res * res), jnp.float32),
        interpret=interpret,
    )(v, flat_in)
    return flat_out.reshape(s, gp, gc, res, res)


def assemble(patch_vals):
    """Stitch ``(S, GP, GC, R, R)`` patch evaluations into dense
    ``(S, GP*R, GC*R)`` surface grids (row-major over the p axis)."""
    s, gp, gc, r, r2 = patch_vals.shape
    assert r == r2
    return jnp.transpose(patch_vals, (0, 1, 3, 2, 4)).reshape(s, gp * r, gc * r)
