"""L1 Pallas kernel: tiled pairwise squared-Euclidean distances.

The k-means assignment step of the offline clustering pipeline reduces
to ``D[i, j] = |x_i - c_j|^2`` over ``N x D`` points and ``K x D``
centroids.  Expanded as ``|x|^2 - 2 x.c + |c|^2`` the middle term is a
matmul, which is what makes this kernel MXU-friendly on real TPU
hardware: the ``(TILE_N, D) @ (D, K)`` contraction feeds the systolic
array while the two rank-1 norm corrections ride along in the VPU.

BlockSpec schedule (the HBM<->VMEM plan a CUDA version would express
with threadblocks):

* grid over ``N / TILE_N`` row tiles;
* each program sees one ``(TILE_N, D)`` tile of points plus the whole
  ``(K, D)`` centroid panel (K and D are small: K <= 32, D = 8, so the
  panel is 1 KiB and stays resident in VMEM across the sweep);
* one ``(TILE_N, K)`` output tile per program.

VMEM per program at the default TILE_N=128: 128*8*4 + 32*8*4 + 128*32*4
= ~21 KiB, far under the ~16 MiB budget; the tile size is chosen so the
lane dimension is a multiple of 128 on the output.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; on-TPU behaviour is estimated in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 128


def _pairwise_kernel(x_ref, c_ref, o_ref):
    """One (TILE_N, K) output tile: |x|^2 - 2 x@c^T + |c|^2."""
    x = x_ref[...]  # (TILE_N, D)
    c = c_ref[...]  # (K, D)
    # MXU contraction in f32 accumulation.
    cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # (TILE_N, K)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (TILE_N, 1)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    # Clamp tiny negatives from cancellation: distances are >= 0.
    o_ref[...] = jnp.maximum(x2 - 2.0 * cross + c2, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def pairwise_sq_dists(points, centroids, *, tile_n: int = DEFAULT_TILE_N, interpret: bool = True):
    """Pairwise squared distances ``(N, K)`` via the Pallas kernel.

    ``N`` must be a multiple of ``tile_n`` (the AOT wrapper pads).
    """
    n, d = points.shape
    k, d2 = centroids.shape
    if d != d2:
        raise ValueError(f"dim mismatch: points D={d} centroids D={d2}")
    if n % tile_n != 0:
        raise ValueError(f"N={n} not a multiple of tile_n={tile_n}")
    grid = (n // tile_n,)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(points.astype(jnp.float32), centroids.astype(jnp.float32))
