"""L1 pairwise kernel vs the pure-jnp oracle (hypothesis sweep)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise import pairwise_sq_dists
from compile.kernels.ref import pairwise_sq_dists_ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([8, 32, 128]),
    k=st.integers(min_value=1, max_value=32),
    d=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_reference_across_shapes(n_tiles, tile, k, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n_tiles * tile, d)
    c = rand(rng, k, d)
    got = np.asarray(pairwise_sq_dists(x, c, tile_n=tile))
    want = np.asarray(pairwise_sq_dists_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_known_values():
    x = np.array([[0.0, 0.0], [3.0, 4.0]], dtype=np.float32)
    c = np.array([[0.0, 0.0], [0.0, 4.0]], dtype=np.float32)
    got = np.asarray(pairwise_sq_dists(x, c, tile_n=2))
    want = np.array([[0.0, 16.0], [25.0, 9.0]], dtype=np.float32)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_distances_nonnegative_even_with_cancellation():
    rng = np.random.default_rng(0)
    # Identical points and centroids: the expanded |x|²−2x·c+|c|² form
    # cancels catastrophically on the diagonal — the kernel must clamp
    # to zero (never negative) and stay within f32 cancellation error
    # (~|x|²·eps ≈ 8e6·1e-7 ≈ 1 at this scale).
    pts = rand(rng, 128, 8) * 1e3
    got = np.asarray(pairwise_sq_dists(pts, pts[:32], tile_n=128))
    assert (got >= 0.0).all()
    np.testing.assert_allclose(np.diag(got[:32, :32]), 0.0, atol=8.0)


def test_rejects_bad_shapes():
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError):
        pairwise_sq_dists(rand(rng, 100, 8), rand(rng, 4, 8), tile_n=128)  # N % tile
    with pytest.raises(ValueError):
        pairwise_sq_dists(rand(rng, 128, 8), rand(rng, 4, 9))  # D mismatch


def test_float64_inputs_are_cast():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((128, 4))  # f64
    c = rng.standard_normal((8, 4))
    got = np.asarray(pairwise_sq_dists(x, c))
    assert got.dtype == np.float32
