"""L1 bicubic patch-eval kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import eval_patches_ref
from compile.kernels.surface_eval import assemble, eval_patches, vandermonde


@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=8),
    g=st.integers(min_value=1, max_value=7),
    res=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_reference(s, g, res, seed):
    rng = np.random.default_rng(seed)
    coeffs = rng.standard_normal((s, g, g, 4, 4)).astype(np.float32)
    got = np.asarray(eval_patches(coeffs, res=res))
    want = np.asarray(eval_patches_ref(coeffs, res))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_constant_patch():
    coeffs = np.zeros((1, 2, 2, 4, 4), dtype=np.float32)
    coeffs[..., 0, 0] = 7.0  # f(t, u) = 7
    got = np.asarray(eval_patches(coeffs, res=8))
    np.testing.assert_allclose(got, 7.0, atol=1e-6)


def test_polynomial_identity():
    # f(t, u) = 1 + 2t + 3u^2 + t^3 u on one patch.
    coeffs = np.zeros((1, 1, 1, 4, 4), dtype=np.float32)
    coeffs[0, 0, 0, 0, 0] = 1.0
    coeffs[0, 0, 0, 1, 0] = 2.0
    coeffs[0, 0, 0, 0, 2] = 3.0
    coeffs[0, 0, 0, 3, 1] = 1.0
    res = 8
    got = np.asarray(eval_patches(coeffs, res=res))[0, 0, 0]
    t = np.arange(res) / res
    for i, ti in enumerate(t):
        for j, uj in enumerate(t):
            want = 1.0 + 2.0 * ti + 3.0 * uj * uj + ti**3 * uj
            np.testing.assert_allclose(got[i, j], want, rtol=1e-5)


def test_vandermonde_halfopen_grid():
    v = vandermonde(4)
    assert v.shape == (4, 4)
    np.testing.assert_allclose(v[:, 0], 1.0)
    np.testing.assert_allclose(v[:, 1], [0.0, 0.25, 0.5, 0.75])


def test_assemble_layout():
    # Patch (i, j) fills block rows i*R..(i+1)*R, cols j*R..(j+1)*R.
    s, g, r = 1, 2, 4
    vals = np.zeros((s, g, g, r, r), dtype=np.float32)
    for i in range(g):
        for j in range(g):
            vals[0, i, j] = 10 * i + j
    dense = np.asarray(assemble(vals))
    assert dense.shape == (1, g * r, g * r)
    assert dense[0, 0, 0] == 0.0
    assert dense[0, 0, r] == 1.0
    assert dense[0, r, 0] == 10.0
    assert dense[0, r, r] == 11.0
