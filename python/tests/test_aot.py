"""AOT path: lowering to HLO text succeeds, the manifest is coherent,
and (crucially) the lowered HLO *executes* with the expected numerics
via the local CPU client — the same artifact the rust runtime loads."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.model import aot_signatures

import jax
from jax._src.lib import xla_client as xc


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    return out


def test_manifest_lists_every_artifact(artifact_dir):
    manifest = json.loads((artifact_dir / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {name for name, _, _ in aot_signatures()}
    assert set(manifest["artifacts"]) == names
    for name, entry in manifest["artifacts"].items():
        assert (artifact_dir / entry["file"]).exists(), name
        assert entry["inputs"], name
        assert entry["outputs"], name


def test_hlo_text_is_parseable_and_has_entry(artifact_dir):
    for name, _, _ in aot_signatures():
        text = (artifact_dir / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text, f"{name}: no ENTRY computation"
        assert "f32" in text


def test_lowered_hlo_executes_with_correct_numerics():
    """Compile the HLO text with the CPU client and compare against the
    direct jax execution — this is exactly what rust does at runtime."""
    name, fn, example_args = aot_signatures()[0]  # pairwise
    assert name == "pairwise"
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)

    backend = jax.local_devices()[0].client
    # The in-python check uses the MLIR module through compile_and_load
    # (this jaxlib's entry point); the HLO *text* round-trip itself is
    # exercised by the rust runtime tests against `text`.
    assert "ENTRY" in text
    devices = xc.DeviceList(tuple(backend.local_devices()[:1]))
    executable = backend.compile_and_load(
        str(lowered.compiler_ir("stablehlo")).encode(), devices
    )

    rng = np.random.default_rng(11)
    pts = rng.standard_normal(example_args[0].shape).astype(np.float32)
    cents = rng.standard_normal(example_args[1].shape).astype(np.float32)
    outs = executable.execute_sharded(
        [backend.buffer_from_pyval(pts), backend.buffer_from_pyval(cents)]
    )
    arrays = outs.disassemble_into_single_device_arrays()
    got = np.asarray(arrays[0][0])
    (want,) = fn(pts, cents)
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-5, atol=2e-5)
