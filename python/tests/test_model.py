"""L2 graph semantics: kmeans_step and surface_eval vs references."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import eval_patches_ref, kmeans_step_ref
from compile.kernels.surface_eval import vandermonde
from compile.model import (  # noqa
    KM_D,
    KM_K,
    KM_N,
    SURF_G,
    SURF_R,
    SURF_S,
    kmeans_step,
    pairwise,
    surface_eval,
)


def test_kmeans_step_full_shape():
    rng = np.random.default_rng(3)
    pts = rng.standard_normal((KM_N, KM_D)).astype(np.float32)
    cents = rng.standard_normal((KM_K, KM_D)).astype(np.float32)
    w = np.ones(KM_N, dtype=np.float32)
    new_c, counts, inertia, assign = kmeans_step(jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(w))
    ref_c, ref_counts, ref_inertia = kmeans_step_ref(pts, cents, w)
    np.testing.assert_allclose(np.asarray(new_c), ref_c, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(counts), ref_counts)
    np.testing.assert_allclose(float(inertia[0]), ref_inertia, rtol=1e-4)
    assert np.asarray(assign).shape == (KM_N,)
    assert np.asarray(assign).dtype == np.int32


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kmeans_step_weighted_padding(seed):
    """Padded points (w=0) must not influence the update at all."""
    rng = np.random.default_rng(seed)
    n_real = rng.integers(10, KM_N)
    pts = np.zeros((KM_N, KM_D), dtype=np.float32)
    pts[:n_real] = rng.standard_normal((n_real, KM_D)).astype(np.float32)
    pts[n_real:] = 1e6  # poison the pad region
    cents = rng.standard_normal((4, KM_D)).astype(np.float32)
    cents_padded = np.full((KM_K, KM_D), 1e15, dtype=np.float32)
    cents_padded[:4] = cents
    w = np.zeros(KM_N, dtype=np.float32)
    w[:n_real] = 1.0
    new_c, counts, inertia, _ = kmeans_step(
        jnp.asarray(pts), jnp.asarray(cents_padded), jnp.asarray(w)
    )
    ref_c, ref_counts, ref_inertia = kmeans_step_ref(pts[:n_real], cents, np.ones(n_real))
    np.testing.assert_allclose(np.asarray(new_c)[:4], ref_c, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(counts)[:4], ref_counts)
    # Sentinel centroids attracted nothing.
    assert np.asarray(counts)[4:].sum() == 0.0
    np.testing.assert_allclose(float(inertia[0]), ref_inertia, rtol=1e-3)


def test_empty_cluster_keeps_centroid():
    pts = np.zeros((KM_N, KM_D), dtype=np.float32)  # all at origin
    cents = np.zeros((KM_K, KM_D), dtype=np.float32)
    cents[1:] = 100.0  # far away: only centroid 0 attracts
    w = np.ones(KM_N, dtype=np.float32)
    new_c, counts, _, assign = kmeans_step(jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(w))
    assert (np.asarray(assign) == 0).all()
    np.testing.assert_allclose(np.asarray(new_c)[1:], 100.0)
    assert np.asarray(counts)[0] == KM_N


def test_pairwise_wrapper_shape():
    rng = np.random.default_rng(5)
    pts = rng.standard_normal((KM_N, KM_D)).astype(np.float32)
    cents = rng.standard_normal((KM_K, KM_D)).astype(np.float32)
    (d2,) = pairwise(jnp.asarray(pts), jnp.asarray(cents))
    assert d2.shape == (KM_N, KM_K)


def test_surface_eval_matches_ref_at_aot_shape():
    rng = np.random.default_rng(7)
    coeffs = rng.standard_normal((SURF_S, SURF_G, SURF_G, 4, 4)).astype(np.float32)
    v = jnp.asarray(vandermonde(SURF_R))
    (patches,) = surface_eval(jnp.asarray(coeffs), v)
    assert patches.shape == (SURF_S, SURF_G, SURF_G, SURF_R, SURF_R)
    want = np.asarray(eval_patches_ref(coeffs, SURF_R))
    np.testing.assert_allclose(np.asarray(patches), want, rtol=2e-5, atol=2e-5)
