//! The scenario conformance suite: every bundled scenario replays in
//! quick mode and every invariant in `scenario::invariant` must hold.
//! This is the one entry point that exercises regressions across the
//! feedback loop, the sharded fabric, and the probe plane at once —
//! PRs 1–3's subsystems under composed regime changes instead of their
//! own happy-path bake-offs.

use dtopt::probe::ProbeMode;
use dtopt::scenario::invariant::Event;
use dtopt::scenario::script::{bundled, bundled_names, Scenario};
use dtopt::scenario::{
    render_timeline, render_verdict, run, run_stampede, Fault, RunOptions, ScenarioOutcome,
};
use dtopt::telemetry::{alerts_to_json, traces_to_json};

fn run_bundled_stampede(name: &str, workers: usize) -> ScenarioOutcome {
    let scenario = Scenario::parse(bundled(name).expect("bundled scenario exists"))
        .unwrap_or_else(|e| panic!("parsing bundled '{name}': {e:#}"));
    run_stampede(&scenario, &RunOptions::default(), workers)
        .unwrap_or_else(|e| panic!("stampeding bundled '{name}': {e:#}"))
}

fn run_bundled(name: &str) -> ScenarioOutcome {
    let scenario = Scenario::parse(bundled(name).expect("bundled scenario exists"))
        .unwrap_or_else(|e| panic!("parsing bundled '{name}': {e:#}"));
    run(&scenario, &RunOptions::default())
        .unwrap_or_else(|e| panic!("running bundled '{name}': {e:#}"))
}

fn assert_passed(outcome: &ScenarioOutcome) {
    assert!(
        outcome.passed(),
        "scenario '{}' violated invariants:\n{}\n{}",
        outcome.name,
        render_verdict(outcome),
        render_timeline(&outcome.timeline),
    );
}

#[test]
fn bundled_library_is_complete() {
    assert_eq!(
        bundled_names(),
        vec!["flash-crowd", "brownout", "stale-kb", "probe-famine", "shard-churn", "convoy"]
    );
}

#[test]
fn every_bundled_scenario_passes_conformance() {
    // The two newest invariants apply to every scenario (every replay
    // runs on the contention plane), so sweep the whole library: each
    // bundled scenario must pass every checker, with the occupancy
    // invariants actually exercised, never vacuous.
    for name in bundled_names() {
        let outcome = run_bundled(name);
        assert_passed(&outcome);
        let drained = outcome.report("occupancy-drained").unwrap();
        assert!(drained.checked >= 1, "'{name}': occupancy-drained never exercised");
        let capacity = outcome.report("offered-within-capacity").unwrap();
        assert!(capacity.checked >= 1, "'{name}': offered-within-capacity never exercised");
    }
}

#[test]
fn flash_crowd_coalesces_and_passes() {
    let outcome = run_bundled("flash-crowd");
    assert_passed(&outcome);
    let led = outcome.responses().filter(|r| r.mode == Some(ProbeMode::Led)).count();
    let piggybacked =
        outcome.responses().filter(|r| r.mode == Some(ProbeMode::Piggybacked)).count();
    let served =
        outcome.responses().filter(|r| r.mode == Some(ProbeMode::EstimateServed)).count();
    assert!(led >= 1, "someone must lead\n{}", render_timeline(&outcome.timeline));
    assert!(
        piggybacked >= 2,
        "the coalesced burst must piggyback its followers\n{}",
        render_timeline(&outcome.timeline)
    );
    assert!(
        served >= 1,
        "post-burst stragglers must reuse the estimate\n{}",
        render_timeline(&outcome.timeline)
    );
    // The piggyback-leader-match invariant was actually exercised, not
    // vacuously true.
    let pig = outcome.report("piggyback-leader-match").unwrap();
    assert!(pig.checked >= 2, "piggyback invariant judged {} followers", pig.checked);
}

#[test]
fn brownout_goodput_stays_above_the_floor() {
    let outcome = run_bundled("brownout");
    assert_passed(&outcome);
    let control = outcome.control_mean_mbps.expect("floor scenario runs a control replay");
    assert!(control > 0.0);
    assert!(outcome.faulted_mean_mbps > 0.0);
    assert!(
        outcome.faulted_mean_mbps < control,
        "the brownout must actually hurt: faulted {:.0} vs control {control:.0}",
        outcome.faulted_mean_mbps
    );
    assert!(outcome.report("goodput-floor").is_some());
}

#[test]
fn stale_kb_generation_guard_forces_resampling() {
    let outcome = run_bundled("stale-kb");
    assert_passed(&outcome);
    // Before the refresh: at least one non-forced estimate-served
    // response judged by the generation guard.
    let guard = outcome.report("estimate-generation-guard").unwrap();
    assert!(guard.checked >= 1, "generation guard never exercised");
    // After the forced refresh bumps the generation, the stale estimate
    // must be demoted: the first response on the new generation leads a
    // fresh ladder (warm-started from the old estimate) instead of
    // being served the old generation's surface index. This is exactly
    // the behavior that disappears if PR 3's cross-generation penalty
    // is removed — and the guard invariant would then flag the serve.
    let refresh_at = outcome
        .timeline
        .iter()
        .find_map(|event| match event {
            Event::Refresh { t_s, cause, .. } if cause == "forced" => Some(*t_s),
            _ => None,
        })
        .expect("stale-kb forces a refresh");
    let first_after = outcome
        .responses()
        .find(|r| r.t_s > refresh_at)
        .expect("arrivals follow the refresh");
    assert_eq!(
        first_after.mode,
        Some(ProbeMode::Led),
        "post-refresh request must re-sample, not adopt the stale estimate\n{}",
        render_timeline(&outcome.timeline)
    );
    let stale = first_after.est.expect("the stale estimate was still stored");
    assert!(
        stale.generation < first_after.generation,
        "the stored estimate predates the refresh"
    );
    assert!(!stale.confident, "the generation penalty demoted it below the serve threshold");
}

#[test]
fn probe_famine_degrades_to_estimate_reuse() {
    let outcome = run_bundled("probe-famine");
    assert_passed(&outcome);
    let forced = outcome.responses().filter(|r| r.budget_forced).count();
    assert!(
        forced >= 1,
        "starvation must force at least one budget-forced serve\n{}",
        render_timeline(&outcome.timeline)
    );
    let starve = outcome.report("starvation-serves").expect("famine scenario checks starvation");
    assert!(starve.checked >= 1, "starvation invariant never exercised");
    // The budget never went negative and stays pinned at zero once
    // starved (zero earn fraction).
    let last = outcome.responses().last().unwrap();
    assert!(last.budget_after_mb >= 0.0 && last.budget_after_mb < 1.0);
}

#[test]
fn shard_churn_resets_generations_only_at_evictions() {
    let outcome = run_bundled("shard-churn");
    assert_passed(&outcome);
    let evictions = outcome
        .timeline
        .iter()
        .filter(|event| matches!(event, Event::Fault { fault: Fault::EvictShard { .. }, .. }))
        .count();
    assert_eq!(evictions, 2);
    // A post-eviction incarnation really does restart at generation 0
    // after generation 1 was observed — the monotone checker passed
    // only because it accounts for the injected eviction.
    let mut saw_gen1 = false;
    let mut saw_reset = false;
    for event in &outcome.timeline {
        match event {
            Event::Response(r) if r.generation >= 1 => saw_gen1 = true,
            Event::Refresh { generation, .. } if *generation >= 1 => saw_gen1 = true,
            Event::Response(r) if saw_gen1 && r.generation == 0 => saw_reset = true,
            _ => {}
        }
    }
    assert!(saw_gen1, "forced refreshes must bump a generation\n{}", render_timeline(&outcome.timeline));
    assert!(saw_reset, "an eviction must reset an incarnation\n{}", render_timeline(&outcome.timeline));
}

#[test]
fn convoy_contention_bites_and_occupancy_stamps_estimates() {
    let outcome = run_bundled("convoy");
    assert_passed(&outcome);

    // The convoy actually hurts: mean goodput of the responses served
    // while it stands sits below the quiet ones' (same replay, same
    // seeds — the only difference is the ambient neighbor pressure).
    let convoy_at = outcome
        .timeline
        .iter()
        .find_map(|event| match event {
            Event::Fault { t_s, fault: Fault::Contention { .. } } => Some(*t_s),
            _ => None,
        })
        .expect("convoy scenario parks a convoy");
    let clear_at = outcome
        .timeline
        .iter()
        .find_map(|event| match event {
            Event::Fault { t_s, fault: Fault::ClearContention { .. } } => Some(*t_s),
            _ => None,
        })
        .expect("convoy scenario drains the convoy");
    let mean = |values: Vec<f64>| -> f64 {
        if values.is_empty() {
            0.0
        } else {
            values.iter().sum::<f64>() / values.len() as f64
        }
    };
    let under = mean(
        outcome
            .responses()
            .filter(|r| r.t_s > convoy_at && r.t_s < clear_at)
            .map(|r| r.achieved_mbps)
            .collect(),
    );
    let quiet = mean(
        outcome
            .responses()
            .filter(|r| r.t_s < convoy_at || r.t_s > clear_at)
            .map(|r| r.achieved_mbps)
            .collect(),
    );
    assert!(
        under < quiet,
        "the convoy must bite: {under:.0} under vs {quiet:.0} quiet\n{}",
        render_timeline(&outcome.timeline)
    );

    // Occupancy-stamped estimates: the first request under the convoy
    // must re-lead (quiet knowledge demoted), the next one serves the
    // convoy-learned estimate, and the first request after the drain
    // re-leads again (convoy knowledge is not quiet-network truth).
    let first_under = outcome.responses().find(|r| r.t_s > convoy_at && r.t_s < clear_at).unwrap();
    assert_eq!(
        first_under.mode,
        Some(ProbeMode::Led),
        "first contended request must re-sample\n{}",
        render_timeline(&outcome.timeline)
    );
    let stale = first_under.est.expect("the quiet estimate was still stored");
    assert_eq!(stale.occ_streams, 0, "it was recorded on a quiet link");
    assert!(!stale.confident, "the occupancy penalty demoted it");
    let second_under = outcome
        .responses()
        .find(|r| r.t_s > first_under.t_s && r.t_s < clear_at)
        .expect("two arrivals land inside the convoy window");
    assert_eq!(
        second_under.mode,
        Some(ProbeMode::EstimateServed),
        "convoy-learned knowledge serves while the convoy stands\n{}",
        render_timeline(&outcome.timeline)
    );
    let first_after = outcome.responses().find(|r| r.t_s > clear_at).unwrap();
    assert_eq!(
        first_after.mode,
        Some(ProbeMode::Led),
        "post-drain request must re-sample, not serve convoy truth\n{}",
        render_timeline(&outcome.timeline)
    );
    assert!(first_after.est.expect("convoy estimate stored").occ_streams > 16);

    // The goodput floor ran against a fault-free control replay.
    let control = outcome.control_mean_mbps.expect("convoy declares a floor");
    assert!(outcome.faulted_mean_mbps < control, "the convoy run must trail its control");

    // Occupancy invariants were exercised with real pressure: at least
    // one response observed carried load above its own offered rate.
    assert!(outcome
        .responses()
        .any(|r| r.t_s > convoy_at && r.t_s < clear_at && r.occ_peak_offered > 6_000.0));
}

#[test]
fn accuracy_ledger_scores_every_bundled_scenario() {
    // The paper's 93%-of-optimal headline as a continuously tracked
    // metric: every replayed response is scored against the sim
    // oracle's optimal, the accuracy-floor invariant judges the
    // per-shard means, and the ledger reports per-shard quantiles.
    for name in bundled_names() {
        let outcome = run_bundled(name);
        let floor = outcome.report("accuracy-floor").unwrap();
        assert!(floor.checked >= 1, "'{name}': accuracy floor never exercised");
        assert!(floor.violations.is_empty(), "'{name}': {:?}", floor.violations);
        let responses = outcome.responses().count() as u64;
        // Exactly one score and one flight per response — a mismatch
        // here means a serve path skipped the health plane (too few) or
        // double-fed it (too many).
        assert_eq!(
            outcome.metrics.ledger.scored(),
            responses,
            "'{name}': ledger scores != responses"
        );
        assert_eq!(
            outcome.metrics.recorder.total_seen(),
            responses,
            "'{name}': recorded flights != responses"
        );
        let overall = outcome.metrics.ledger.overall().expect("scored scenarios summarize");
        assert!(overall.transfers >= 1 && overall.p50 > 0.0, "'{name}': {overall:?}");
        let shards = outcome.metrics.ledger.snapshot();
        assert!(!shards.is_empty(), "'{name}': no per-shard accuracy");
        for (shard, hist) in &shards {
            assert!(!hist.is_empty(), "'{name}': shard '{shard}' empty");
            let summary = outcome.metrics.ledger.shard(shard).unwrap();
            assert!(
                summary.p10 <= summary.p50 && summary.p50 <= summary.p90,
                "'{name}' shard '{shard}': quantiles out of order: {summary:?}"
            );
        }
    }
}

#[test]
fn same_seed_metric_exports_are_byte_identical() {
    // The obs-conformance bar, in-process: two same-seed replays must
    // export byte-identical metrics in both formats (CI re-enforces
    // this end to end through `dtopt scenario --metrics-out`).
    use dtopt::telemetry::export;
    for name in bundled_names() {
        let a = run_bundled(name);
        let b = run_bundled(name);
        let (snap_a, snap_b) = (a.metrics.export_snapshot(), b.metrics.export_snapshot());
        assert!(!snap_a.is_empty(), "'{name}': export snapshot is empty");
        assert_eq!(
            export::to_prometheus(&snap_a),
            export::to_prometheus(&snap_b),
            "scenario '{name}' prometheus export is not deterministic"
        );
        assert_eq!(
            export::to_json(&snap_a).to_string_compact(),
            export::to_json(&snap_b).to_string_compact(),
            "scenario '{name}' json export is not deterministic"
        );
    }
}

#[test]
fn same_seed_replays_are_byte_identical() {
    // The acceptance bar: two quick-mode runs with the same seed
    // produce byte-identical event timelines AND byte-identical
    // decision traces — for every bundled scenario, including the one
    // with real thread concurrency (flash-crowd's coalesced burst) and
    // the contention-plane one.
    for name in bundled_names() {
        let a = run_bundled(name);
        let b = run_bundled(name);
        assert_eq!(
            render_timeline(&a.timeline),
            render_timeline(&b.timeline),
            "scenario '{name}' replay is not deterministic"
        );
        assert_eq!(
            traces_to_json(&a.traces).to_string_compact(),
            traces_to_json(&b.traces).to_string_compact(),
            "scenario '{name}' decision traces are not deterministic"
        );
    }
}

#[test]
fn declared_alerts_raise_after_their_faults() {
    // The sentry's conformance surface, asserted directly on the alert
    // timelines (the alert-conformance invariant re-checks the same
    // facts inside each verdict). Every declared detector fires, and
    // never before the fault that provokes it.
    let first_raise = |outcome: &ScenarioOutcome, detector: &str| -> f64 {
        outcome
            .alerts
            .iter()
            .filter(|a| a.detector == detector)
            .map(|a| a.raised_t_s)
            .fold(f64::INFINITY, f64::min)
    };

    let convoy = run_bundled("convoy");
    assert_passed(&convoy);
    for detector in ["occupancy-leak", "allowance-thrash", "accuracy-below-floor"] {
        let t = first_raise(&convoy, detector);
        assert!(
            t.is_finite() && t >= 125.0,
            "convoy '{detector}' first raise at {t}, convoy parks at 125s:\n{}",
            dtopt::telemetry::render_alerts(&convoy.alerts)
        );
    }

    let famine = run_bundled("probe-famine");
    assert_passed(&famine);
    let t = first_raise(&famine, "probe-budget-famine");
    assert!(t.is_finite() && t >= 140.0, "famine raise at {t}, starvation at 140s");
    let t = first_raise(&famine, "stale-knowledge");
    assert!(t.is_finite() && t >= 150.0, "stale raise at {t}, forced refresh at 150s");

    for (name, fault_t) in [("stale-kb", 400.0), ("shard-churn", 140.0)] {
        let outcome = run_bundled(name);
        assert_passed(&outcome);
        let t = first_raise(&outcome, "stale-knowledge");
        assert!(
            t.is_finite() && t >= fault_t,
            "'{name}' stale-knowledge raise at {t}, forced refresh at {fault_t}s:\n{}",
            dtopt::telemetry::render_alerts(&outcome.alerts)
        );
    }

    // Declaring scenarios carry the conformance report in the verdict.
    for name in ["convoy", "probe-famine", "stale-kb", "shard-churn", "flash-crowd"] {
        let outcome = run_bundled(name);
        let report = outcome.report("alert-conformance").unwrap();
        assert!(report.checked >= 1, "'{name}': alert conformance never exercised");
        assert!(report.violations.is_empty(), "'{name}': {:?}", report.violations);
    }
}

#[test]
fn quiet_replays_and_controls_raise_no_alerts() {
    // The false-positive bar: flash-crowd (fault-free, expect-quiet)
    // raises nothing, and every fault-free control replay the runner
    // spawned is pinned to a zero-alert baseline.
    let quiet = run_bundled("flash-crowd");
    assert_passed(&quiet);
    assert!(
        quiet.alerts.is_empty(),
        "fault-free flash-crowd raised alerts:\n{}",
        dtopt::telemetry::render_alerts(&quiet.alerts)
    );

    let mut controls = 0;
    for name in bundled_names() {
        let outcome = run_bundled(name);
        if let Some(control_alerts) = &outcome.control_alerts {
            controls += 1;
            assert!(
                control_alerts.is_empty(),
                "'{name}' control replay raised alerts:\n{}",
                dtopt::telemetry::render_alerts(control_alerts)
            );
        }
    }
    assert!(controls >= 4, "only {controls} control replays ran — the pin is near-vacuous");
}

#[test]
fn same_seed_alert_timelines_are_byte_identical() {
    // Alerts inherit the replay's determinism contract: same seed, same
    // raise/clear edges, byte for byte — the property CI re-checks end
    // to end through `dtopt scenario --alerts --json`.
    for name in bundled_names() {
        let a = run_bundled(name);
        let b = run_bundled(name);
        assert_eq!(
            alerts_to_json(&a.alerts).to_string_compact(),
            alerts_to_json(&b.alerts).to_string_compact(),
            "scenario '{name}' alert timeline is not deterministic"
        );
    }
}

#[test]
fn every_response_carries_a_complete_decision_trace() {
    // The trace-completeness invariant is part of every verdict, and
    // the structural guarantee holds scenario-wide: one trace per
    // response, each passing its own completeness check.
    for name in bundled_names() {
        let outcome = run_bundled(name);
        let report = outcome.report("trace-complete").unwrap();
        assert!(report.checked >= 1, "'{name}': trace completeness never exercised");
        assert!(report.violations.is_empty(), "'{name}': {:?}", report.violations);
        let responses = outcome.responses().count();
        assert_eq!(
            outcome.traces.len(),
            responses,
            "'{name}': {} traces for {responses} responses",
            outcome.traces.len()
        );
        for trace in &outcome.traces {
            assert!(
                trace.is_complete(),
                "'{name}' request {} trace incomplete:\n{}",
                trace.request_id,
                trace.render_text()
            );
        }
    }
}

#[test]
fn every_bundled_scenario_survives_a_four_worker_stampede() {
    // The stampede bar: every bundled script replayed with four racing
    // OS threads per same-instant window still produces a legal run —
    // links drained, budgets within bounds, the accuracy floor held,
    // one complete trace per response, and the stampede plane's live
    // audits clean. Order-sensitive checkers are exempt by design (the
    // sequential `run` stays their oracle), so assert they are absent
    // rather than silently vacuous.
    for name in bundled_names() {
        let outcome = run_bundled_stampede(name, 4);
        assert_passed(&outcome);
        for check in
            ["occupancy-drained", "budget-non-negative", "accuracy-floor", "trace-complete"]
        {
            let report = outcome
                .report(check)
                .unwrap_or_else(|| panic!("'{name}': stampede verdict lost '{check}'"));
            assert!(report.checked >= 1, "'{name}': '{check}' never exercised");
            assert!(
                report.violations.is_empty(),
                "'{name}' stampede violated '{check}': {:?}\n{}",
                report.violations,
                render_timeline(&outcome.timeline)
            );
        }
        for audit in ["occupancy-balance", "one-leader-per-cohort", "budget-conservation"] {
            let report = outcome
                .report(audit)
                .unwrap_or_else(|| panic!("'{name}': stampede verdict lost audit '{audit}'"));
            assert!(report.checked >= 1, "'{name}': audit '{audit}' never exercised");
            assert!(
                report.violations.is_empty(),
                "'{name}' stampede failed audit '{audit}': {:?}",
                report.violations
            );
        }
        for absent in
            ["monotone-generations", "estimate-generation-guard", "piggyback-leader-match"]
        {
            assert!(
                outcome.report(absent).is_none(),
                "'{name}': order-sensitive '{absent}' must not judge a concurrent run"
            );
        }
        let responses = outcome.responses().count();
        assert!(responses >= 1, "'{name}': stampede served nothing");
        assert_eq!(
            outcome.traces.len(),
            responses,
            "'{name}': {} traces for {responses} stampeded responses",
            outcome.traces.len()
        );
    }
}

#[test]
fn stampede_keeps_declared_alert_conformance() {
    // Alert conformance is order-insensitive (raise-after-fault,
    // control pinned quiet), so it survives the concurrency exemption:
    // every declaring scenario's stampede verdict carries the report,
    // exercised and clean.
    for name in ["convoy", "probe-famine", "stale-kb", "shard-churn", "flash-crowd"] {
        let outcome = run_bundled_stampede(name, 4);
        let report = outcome
            .report("alert-conformance")
            .unwrap_or_else(|| panic!("'{name}': stampede verdict lost alert conformance"));
        assert!(report.checked >= 1, "'{name}': alert conformance never exercised");
        assert!(
            report.violations.is_empty(),
            "'{name}' stampede alert conformance: {:?}\n{}",
            report.violations,
            dtopt::telemetry::render_alerts(&outcome.alerts)
        );
    }
}
