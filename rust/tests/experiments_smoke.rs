//! Smoke tests over the experiment harnesses: every figure regenerator
//! runs at quick scale and satisfies the paper's qualitative claims.

use dtopt::experiments::common::{ExpConfig, World};
use dtopt::experiments::{convoy, fig12, fig3, fig5, fig6, fig7, fleet, rush};
use dtopt::runtime::Backend;

fn quick_world() -> World {
    let mut backend = Backend::Native;
    World::prepare(
        ExpConfig { history_days: 5, arrivals_per_hour: 20.0, requests_per_cell: 2, seed: 0xE0 },
        &mut backend,
    )
}

#[test]
fn fig5_headline_shape_holds() {
    let world = quick_world();
    let result = fig5::run(&world, 4);
    assert_eq!(result.len(), 18, "3 networks × 3 classes × 2 periods");
    let rendered = fig5::render(&result);
    assert!(rendered.contains("xsede"));
    assert!(rendered.contains("ASM"));
    for (desc, ok) in fig5::headline_checks(&result) {
        assert!(ok, "fig5 check failed: {desc}\n{rendered}");
    }
}

#[test]
fn fig6_accuracy_curves() {
    let world = quick_world();
    let result = fig6::run(&world);
    assert!(result.contains_key("ASM"));
    assert!(result.contains_key("HARP"));
    assert!(result.contains_key("ANN+OT"));
    for (desc, ok) in fig6::headline_checks(&result) {
        assert!(ok, "fig6 check failed: {desc}\n{}", fig6::render(&result));
    }
}

#[test]
fn fig7_staleness_decay() {
    let world = quick_world();
    let result = fig7::run(&world, 4, &[1, 3]);
    assert_eq!(result.len(), 2);
    for (desc, ok) in fig7::headline_checks(&result) {
        assert!(ok, "fig7 check failed: {desc}\n{}", fig7::render(&result));
    }
}

#[test]
fn fleet_fabric_matches_single_global_kb() {
    let mut backend = Backend::Native;
    // More eval requests than the shared quick world: the per-network
    // accuracy comparison needs a few samples per day to be stable.
    let world = World::prepare(
        ExpConfig { history_days: 5, arrivals_per_hour: 20.0, requests_per_cell: 6, seed: 0xE0 },
        &mut backend,
    );
    let dir = std::env::temp_dir().join(format!("dtopt_fleet_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let result = fleet::run(&world, 3, &dir).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    let rendered = fleet::render(&result);
    assert_eq!(result.nets.len(), 3);
    assert!(rendered.contains("xsede"), "{rendered}");
    assert!(rendered.contains("fabric:"), "{rendered}");
    for (desc, ok) in fleet::headline_checks(&result) {
        assert!(ok, "fleet check failed: {desc}\n{rendered}");
    }
}

#[test]
fn rush_probe_plane_coalesces_the_burst() {
    let world = quick_world();
    let result = rush::run(&world, 16, 4);
    let rendered = rush::render(&result);
    assert!(rendered.contains("probe-plane"), "{rendered}");
    assert!(rendered.contains("probe plane:"), "{rendered}");
    for (desc, ok) in rush::headline_checks(&result) {
        assert!(ok, "rush check failed: {desc}\n{rendered}");
    }
}

#[test]
fn convoy_plane_aware_decisions_beat_the_fiction() {
    let world = quick_world();
    let result = convoy::run(&world, 12, 4);
    let rendered = convoy::render(&result);
    assert!(rendered.contains("plane-aware"), "{rendered}");
    assert!(rendered.contains("link plane:"), "{rendered}");
    assert_eq!(result.plane.cohort_mbps.len(), 12);
    assert_eq!(result.isolated.cohort_mbps.len(), 12);
    for (desc, ok) in convoy::headline_checks(&result) {
        assert!(ok, "convoy check failed: {desc}\n{rendered}");
    }
}

#[test]
fn fig12_render() {
    let f1 = fig12::run_fig1(1, 3);
    assert!(f1.contains("class=small") && f1.contains("class=large"));
    let f2 = fig12::run_fig2(1, 4);
    assert!(f2.contains("pp"));
}

#[test]
fn fig3_render() {
    let a = fig3::run_3a(120, 5);
    assert!(a.sigma > 0.0 && a.histogram.len() > 5);
    let b = fig3::run_3b(1, 48, 6);
    assert!(b.spline > b.quadratic, "spline {} vs quadratic {}", b.spline, b.quadratic);
}
