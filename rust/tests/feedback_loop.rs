//! Integration tests for the knowledge lifecycle service: snapshot
//! consistency under concurrent publish, ingest backpressure at the
//! service level, the background refresher, and the full closed loop
//! through the coordinator.

use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::feedback::{
    FeedbackConfig, FeedbackService, IngestConfig, RefreshPolicy, SnapshotSlot,
};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::logs::store::LogStore;
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::sim::dataset::Dataset;
use dtopt::sim::testbed::{Testbed, TestbedId};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dtopt_fbloop_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn history(days: u64, seed: u64) -> Vec<dtopt::logs::record::TransferLog> {
    generate(
        &Testbed::xsede(),
        &GenConfig { days, arrivals_per_hour: 20.0, start_day: 0, seed },
    )
}

fn small_kb(seed: u64) -> (Arc<KnowledgeBase>, Vec<dtopt::logs::record::TransferLog>) {
    let rows = history(4, seed);
    let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    (kb, rows)
}

/// N worker threads continuously resolve snapshots while a publisher
/// pushes M generations: every reader must observe a fully formed KB
/// and a monotone generation sequence (no torn reads).
#[test]
fn concurrent_resolvers_observe_monotone_generations() {
    const GENERATIONS: u64 = 60;
    let (kb, _) = small_kb(501);
    let expected_clusters = kb.clusters.len();
    let slot = Arc::new(SnapshotSlot::new(kb.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..6)
        .map(|_| {
            let slot = slot.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut last_generation = 0u64;
                let mut resolves = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let snap = slot.resolve();
                    assert!(
                        snap.generation >= last_generation,
                        "generation went backwards: {} after {}",
                        snap.generation,
                        last_generation
                    );
                    // A torn read would surface as a half-built KB.
                    assert_eq!(snap.kb.clusters.len(), expected_clusters);
                    assert!(snap.kb.clusters.iter().map(|c| c.n_rows).sum::<u64>() > 0);
                    last_generation = snap.generation;
                    resolves += 1;
                }
                (last_generation, resolves)
            })
        })
        .collect();
    for _ in 0..GENERATIONS {
        slot.publish(kb.clone());
        std::thread::sleep(Duration::from_micros(200));
    }
    stop.store(true, Ordering::Release);
    for reader in readers {
        let (last, resolves) = reader.join().unwrap();
        assert!(resolves > 0, "reader never resolved");
        assert!(last <= GENERATIONS);
    }
    assert_eq!(slot.generation(), GENERATIONS);
    assert_eq!(slot.resolve().generation, GENERATIONS);
}

/// Service-level backpressure: a burst far beyond queue capacity never
/// blocks the offering threads, and every offered row is accounted for
/// as either flushed or dropped.
#[test]
fn ingest_burst_never_blocks_and_accounts_for_every_row() {
    let dir = tmpdir("burst");
    let (kb, rows) = small_kb(502);
    let service = FeedbackService::start(
        kb,
        LogStore::open(&dir).unwrap(),
        FeedbackConfig {
            ingest: IngestConfig {
                capacity: 8,
                flush_batch: 4,
                flush_interval: Duration::from_millis(5),
            },
            background: false,
            ..Default::default()
        },
    )
    .unwrap();
    let per_thread = 2_000u64;
    let offerers: Vec<_> = (0..4)
        .map(|t| {
            let queue = service.queue();
            let row = rows[t as usize].clone();
            std::thread::spawn(move || {
                let started = Instant::now();
                for i in 0..per_thread {
                    let mut r = row.clone();
                    r.id = t * per_thread + i;
                    queue.offer(r);
                }
                started.elapsed()
            })
        })
        .collect();
    for offerer in offerers {
        let elapsed = offerer.join().unwrap();
        // 2k non-blocking try_sends must complete almost instantly; a
        // generous bound still catches any accidental blocking path.
        assert!(elapsed < Duration::from_secs(5), "offer path blocked: {elapsed:?}");
    }
    assert!(service.flush_barrier(Duration::from_secs(30)));
    let enqueued = service.stats.rows_enqueued.load(Ordering::Relaxed);
    let dropped = service.stats.rows_dropped.load(Ordering::Relaxed);
    let flushed = service.stats.rows_flushed.load(Ordering::Relaxed);
    assert_eq!(enqueued + dropped, 4 * per_thread, "every offer is accounted for");
    assert_eq!(flushed, enqueued, "every accepted row reaches the store");
    let on_disk: usize = {
        let store = LogStore::open(&dir).unwrap();
        store.read_all().unwrap().len()
    };
    assert_eq!(on_disk as u64, flushed);
    service.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The background refresher turns the loop on its own: rows offered to
/// the queue eventually produce a new published generation.
#[test]
fn background_refresher_publishes_without_manual_ticks() {
    let dir = tmpdir("background");
    let (kb, _) = small_kb(503);
    let service = FeedbackService::start(
        kb,
        LogStore::open(&dir).unwrap(),
        FeedbackConfig {
            ingest: IngestConfig {
                capacity: 1024,
                flush_batch: 16,
                flush_interval: Duration::from_millis(2),
            },
            policy: RefreshPolicy {
                min_new_rows: 50,
                min_interval: Duration::ZERO,
                ..Default::default()
            },
            poll_interval: Duration::from_millis(5),
            background: true,
        },
    )
    .unwrap();
    let queue = service.queue();
    for row in history(1, 504).into_iter().take(200) {
        queue.offer(row);
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    while service.generation() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(service.generation() >= 1, "background refresher never published");
    assert!(service.stats.refreshes.load(Ordering::Relaxed) >= 1);
    service.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Full closed loop through the coordinator: serve → ingest → refresh →
/// generation increments and later transfers observe the new snapshot,
/// while earlier responses stay attributed to the old one.
#[test]
fn coordinator_closed_loop_advances_generations() {
    let dir = tmpdir("closed");
    let (kb, rows) = small_kb(505);
    let service = FeedbackService::start(
        kb,
        LogStore::open(&dir).unwrap(),
        FeedbackConfig {
            ingest: IngestConfig {
                capacity: 256,
                flush_batch: 2,
                flush_interval: Duration::from_millis(2),
            },
            policy: RefreshPolicy {
                min_new_rows: 1,
                min_interval: Duration::ZERO,
                ..Default::default()
            },
            background: false,
            ..Default::default()
        },
    )
    .unwrap();
    let coord = Coordinator::with_feedback(
        &service,
        Arc::new(rows),
        CoordinatorConfig { workers: 2, ..Default::default() },
    );
    let request = |id: u64| TransferRequest {
        id,
        testbed: TestbedId::Xsede,
        dataset: Dataset::new(80, 64.0),
        t_submit: 4.5 * 86_400.0,
        state_override: None,
        optimizer: Some(OptimizerKind::Asm),
        seed: 4_000 + id,
    };
    for round in 0u64..3 {
        let responses = coord.run_batch((0..3).map(|i| request(round * 10 + i)).collect());
        for r in &responses {
            assert_eq!(
                r.kb_generation, round,
                "round {round} must be served from generation {round}"
            );
        }
        assert!(service.flush_barrier(Duration::from_secs(30)));
        let fired = service.tick().unwrap();
        assert_eq!(
            fired.map(|(generation, _)| generation),
            Some(round + 1),
            "each round's ingested rows trigger the next generation"
        );
    }
    assert_eq!(service.generation(), 3);
    let stats = &service.stats;
    assert_eq!(stats.rows_flushed.load(Ordering::Relaxed), 9);
    assert_eq!(stats.rows_consumed.load(Ordering::Relaxed), 9);
    assert_eq!(stats.rows_dropped.load(Ordering::Relaxed), 0);
    coord.shutdown();
    service.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
