//! Golden-render test for the operator-facing metrics output: the full
//! `coordinator::Metrics::render` — per-optimizer table, pooled
//! request-latency line, knowledge-service block, fabric shard table,
//! probe-plane block, and the shared-link contention block — is
//! snapshotted against a checked-in fixture, so format drift is a
//! reviewed diff instead of a silent reshape of what operators parse
//! and alert on.
//!
//! Every input is hand-picked so the render is bit-deterministic: fixed
//! nanosecond latencies (never wall-clock measurements), manually set
//! service counters, an empty fallback KB for the fabric (one
//! borrowed(fallback) shard, zero rows), a probe estimate whose
//! confidence cannot visibly decay (million-second half-life), and a
//! link plane holding one scripted registration plus an ambient convoy
//! (epochs and occupancy are counters, not clocks).
//!
//! To regenerate after an *intentional* format change:
//! `DTOPT_UPDATE_GOLDEN=1 cargo test --test metrics_golden` — then
//! review and commit the fixture diff.

use dtopt::coordinator::Metrics;
use dtopt::fabric::{FabricConfig, ShardKey, ShardRouter};
use dtopt::feedback::FeedbackStats;
use dtopt::netplane::LinkPlane;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::probe::{BudgetConfig, EstimateConfig, ProbeConfig, ProbeOcc, ProbePlane};
use dtopt::sim::dataset::SizeClass;
use dtopt::sim::testbed::TestbedId;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

const GOLDEN_PATH: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/metrics_golden.txt");

#[test]
fn full_metrics_render_matches_golden_fixture() {
    let metrics = Metrics::new();
    // Per-optimizer entries with fixed decision latencies.
    metrics.record("ASM", 2000.0, 1000.0, 4.0, 2, 10_000);
    metrics.record("ASM", 1000.0, 1000.0, 8.0, 0, 30_000);
    metrics.record("GO", 500.0, 250.0, 4.0, 0, 2_000_000);

    // Knowledge-service block: counters set by hand.
    let feedback = Arc::new(FeedbackStats::default());
    feedback.kb_generation.store(3, Ordering::Relaxed);
    feedback.refreshes.store(2, Ordering::Relaxed);
    feedback.rows_consumed.store(120, Ordering::Relaxed);
    feedback.last_refresh_ns.store(2_000_000, Ordering::Relaxed);
    feedback.total_refresh_ns.store(6_000_000, Ordering::Relaxed);
    feedback.rows_enqueued.store(130, Ordering::Relaxed);
    feedback.rows_flushed.store(128, Ordering::Relaxed);
    feedback.flushes.store(16, Ordering::Relaxed);
    feedback.rows_dropped.store(2, Ordering::Relaxed);
    feedback.drift_events.store(5, Ordering::Relaxed);
    metrics.attach_feedback(feedback);

    // Fabric shard table: an empty fallback KB means the routed shard
    // borrows it with zero rows — every rendered counter is fixed.
    let dir = std::env::temp_dir()
        .join(format!("dtopt_metrics_golden_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fabric = Arc::new(
        ShardRouter::open(&dir, Arc::new(KnowledgeBase::empty()), FabricConfig::default())
            .unwrap(),
    );
    let _ = fabric.route(ShardKey::new(TestbedId::Xsede, SizeClass::Large));
    metrics.attach_fabric(fabric.clone());

    // Probe block: scripted counters, bytes, and one estimate whose
    // confidence cannot visibly decay before the render.
    let plane = Arc::new(ProbePlane::new(ProbeConfig {
        estimate: EstimateConfig {
            half_life: Duration::from_secs(1_000_000),
            ..Default::default()
        },
        budget: BudgetConfig { capacity_mb: 4096.0, initial_mb: 4096.0, earn_fraction: 0.05 },
        ..Default::default()
    }));
    plane.stats.led.store(2, Ordering::Relaxed);
    plane.stats.piggybacked.store(5, Ordering::Relaxed);
    plane.stats.estimate_served.store(3, Ordering::Relaxed);
    plane.stats.budget_forced.store(1, Ordering::Relaxed);
    plane.stats.note_bytes(500.0, 9_500.0);
    plane.estimates().record(
        ShardKey::new(TestbedId::Xsede, SizeClass::Large),
        1,
        3,
        0.42,
        1.0,
        2,
        ProbeOcc::default(),
    );
    metrics.attach_probe(plane);

    // Link-plane block: one scripted registration plus an ambient
    // convoy — counters only, so the render is exact.
    let links = Arc::new(LinkPlane::shared());
    let lease = links.clone().admit(TestbedId::Xsede, 7);
    lease.update(8, 24, 2_500.0);
    links.set_ambient(TestbedId::Xsede, 4_000.0, 48);
    metrics.attach_links(links);

    let rendered = metrics.render();
    drop(lease);
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    if std::env::var("DTOPT_UPDATE_GOLDEN").is_ok() {
        std::fs::write(GOLDEN_PATH, &rendered).expect("rewriting the golden fixture");
        eprintln!("metrics_golden: fixture regenerated at {GOLDEN_PATH}");
        return;
    }
    let golden = include_str!("fixtures/metrics_golden.txt");
    assert_eq!(
        rendered, golden,
        "metrics render drifted from the golden fixture.\n\
         If the change is intentional, regenerate with \
         DTOPT_UPDATE_GOLDEN=1 cargo test --test metrics_golden\n\
         --- rendered ---\n{rendered}\n--- golden ---\n{golden}"
    );
}
