//! Golden fixtures for the sentry plane's alert timelines: every
//! bundled scenario replays in quick mode and its normalized raise/clear
//! timeline (`alerts_to_json`, the exact bytes `dtopt scenario --alerts
//! --json` prints) is pinned against `tests/fixtures/alerts/<name>.json`.
//! Any drift in detector thresholds, window geometry, settlement
//! ordering, or the JSON shape shows up as a reviewed fixture diff
//! instead of a silent change to what alert consumers parse.
//!
//! Like `obs_golden` the fixtures are read at runtime, not
//! `include_str!`: they bootstrap from a machine that can run the
//! suite, so a missing fixture is a note to regenerate, not a compile
//! error. Once committed they are enforced bytewise.
//!
//! To (re)generate after an *intentional* change:
//! `DTOPT_UPDATE_GOLDEN=1 cargo test --test alert_golden` — then review
//! and commit the fixture diffs.

use dtopt::scenario::script::{bundled, bundled_names, Scenario};
use dtopt::scenario::{run, RunOptions};
use dtopt::telemetry::alerts_to_json;
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/alerts").join(name)
}

fn check(name: &str, rendered: &str, update: bool, missing: &mut Vec<String>) {
    let path = fixture_path(name);
    if update {
        std::fs::create_dir_all(path.parent().unwrap())
            .expect("creating the alerts fixture directory");
        std::fs::write(&path, rendered).expect("rewriting the alert golden");
        eprintln!("alert_golden: fixture regenerated at {}", path.display());
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            rendered, golden,
            "alert timeline '{name}' drifted from the golden fixture.\n\
             If the change is intentional, regenerate with \
             DTOPT_UPDATE_GOLDEN=1 cargo test --test alert_golden"
        ),
        Err(_) => missing.push(name.to_string()),
    }
}

#[test]
fn bundled_alert_timelines_match_golden_fixtures() {
    let update = std::env::var("DTOPT_UPDATE_GOLDEN").is_ok();
    let mut missing = Vec::new();
    for name in bundled_names() {
        let scenario = Scenario::parse(bundled(name).expect("bundled scenario exists"))
            .unwrap_or_else(|e| panic!("parsing bundled '{name}': {e:#}"));
        let outcome = run(&scenario, &RunOptions::default())
            .unwrap_or_else(|e| panic!("running bundled '{name}': {e:#}"));
        let rendered = format!("{}\n", alerts_to_json(&outcome.alerts).to_string_compact());
        check(&format!("{name}.json"), &rendered, update, &mut missing);
    }
    if !missing.is_empty() {
        eprintln!(
            "alert_golden: no fixture yet for {missing:?}; bootstrap with \
             DTOPT_UPDATE_GOLDEN=1 cargo test --test alert_golden"
        );
    }
}
