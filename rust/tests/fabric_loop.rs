//! Integration tests for the sharded knowledge fabric: shard-aware
//! request routing through the coordinator, and the full cold-start
//! path — a brand-new shard serves borrowed knowledge, accrues native
//! rows from its own completed transfers, and flips to its own fitted
//! KB, all observable through `TransferResponse::{shard_key, borrowed,
//! kb_generation}`.

use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::fabric::{FabricConfig, ShardConfig, ShardKey, ShardRouter};
use dtopt::feedback::{IngestConfig, RefreshPolicy};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::{Testbed, TestbedId};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dtopt_fabric_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Fallback KB mined from xsede history only — so a didclab shard that
/// borrows it is visibly serving foreign knowledge until its own fit.
fn xsede_kb(seed: u64) -> (Arc<KnowledgeBase>, Vec<dtopt::logs::record::TransferLog>) {
    let rows = generate(
        &Testbed::xsede(),
        &GenConfig { days: 4, arrivals_per_hour: 20.0, start_day: 0, seed },
    );
    let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    (kb, rows)
}

fn quick_fabric(dir: &PathBuf, fallback: Arc<KnowledgeBase>, min_native_rows: u64) -> Arc<ShardRouter> {
    Arc::new(
        ShardRouter::open(
            dir,
            fallback,
            FabricConfig {
                shard: ShardConfig {
                    ingest: IngestConfig {
                        capacity: 256,
                        flush_batch: 4,
                        flush_interval: Duration::from_millis(2),
                    },
                    policy: RefreshPolicy {
                        min_new_rows: 1,
                        min_interval: Duration::ZERO,
                        ..Default::default()
                    },
                    min_native_rows,
                },
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

/// A didclab medium-class request; dataset shape varies with the id so
/// the shard's native fit sees non-degenerate features.
fn didclab_request(id: u64) -> TransferRequest {
    TransferRequest {
        id,
        testbed: TestbedId::Didclab,
        dataset: Dataset::new(20 + id, 20.0 + (id % 24) as f64),
        t_submit: 5.0 * 86_400.0 + (id as f64 % 24.0) * 3_600.0,
        state_override: None,
        optimizer: Some(OptimizerKind::Asm),
        seed: 9_000 + id,
    }
}

/// The acceptance path: borrowed KB serves → native rows accrue →
/// shard flips to its own fitted KB, observable per response.
#[test]
fn cold_start_shard_flips_from_borrowed_to_native() {
    let dir = tmpdir("coldstart");
    let (kb, history) = xsede_kb(901);
    let fabric = quick_fabric(&dir, kb, 20);
    let coord = Coordinator::with_fabric(
        fabric.clone(),
        Arc::new(history),
        CoordinatorConfig { workers: 2, ..Default::default() },
    );
    let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);

    // Wave 1: the shard cold-starts by borrowing the fallback KB.
    let wave1 = coord.run_batch((1..=24).map(didclab_request).collect());
    for r in &wave1 {
        assert_eq!(r.shard_key, Some(key));
        assert!(r.borrowed, "no native knowledge exists yet");
        assert_eq!(r.kb_generation, 0);
    }
    // Wave 1's completed transfers are this shard's first native rows.
    assert!(fabric.flush_all(Duration::from_secs(30)), "shard ingest queue drained");
    let fired = fabric.tick_all();
    assert_eq!(fired, vec![(key, 1, "native-fit")]);

    // Wave 2: served from the shard's own fitted KB.
    let wave2 = coord.run_batch((25..=32).map(didclab_request).collect());
    for r in &wave2 {
        assert_eq!(r.shard_key, Some(key));
        assert!(!r.borrowed, "shard fit its own KB");
        assert_eq!(r.kb_generation, 1);
    }

    // And from here the per-shard policy keeps the loop turning: wave
    // 2's rows additively refresh the native KB into generation 2.
    assert!(fabric.flush_all(Duration::from_secs(30)));
    assert_eq!(fabric.tick_all(), vec![(key, 2, "row-threshold")]);
    let wave3 = coord.run_batch(vec![didclab_request(40)]);
    assert_eq!(wave3[0].kb_generation, 2);
    assert!(!wave3[0].borrowed);

    assert!(fabric.flush_all(Duration::from_secs(30)));
    let shard = fabric.shard(&key).unwrap();
    assert_eq!(shard.native_rows(), 33, "every completed transfer became a native row");
    coord.shutdown();
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mixed traffic fans out to distinct shards, each tagged in its
/// responses; the fabric materializes exactly the keys that saw
/// traffic.
#[test]
fn mixed_traffic_routes_to_per_network_shards() {
    let dir = tmpdir("routing");
    let (kb, history) = xsede_kb(902);
    let fabric = quick_fabric(&dir, kb, 1_000_000);
    let coord = Coordinator::with_fabric(
        fabric.clone(),
        Arc::new(history),
        CoordinatorConfig { workers: 3, ..Default::default() },
    );
    let cases = [
        (TestbedId::Xsede, Dataset::new(50, 200.0), SizeClass::Large),
        (TestbedId::Didclab, Dataset::new(200, 2.0), SizeClass::Small),
        (TestbedId::DidclabToXsede, Dataset::new(80, 30.0), SizeClass::Medium),
    ];
    let requests: Vec<TransferRequest> = cases
        .iter()
        .enumerate()
        .map(|(i, (tb, dataset, _))| TransferRequest {
            id: i as u64 + 1,
            testbed: *tb,
            dataset: *dataset,
            t_submit: 5.5 * 86_400.0,
            state_override: None,
            optimizer: Some(OptimizerKind::Asm),
            seed: 7_700 + i as u64,
        })
        .collect();
    let responses = coord.run_batch(requests);
    for (r, (tb, _, class)) in responses.iter().zip(&cases) {
        assert_eq!(r.shard_key, Some(ShardKey::new(*tb, *class)));
        assert!(r.borrowed);
    }
    let live: Vec<ShardKey> = fabric.live_shards().iter().map(|s| s.key).collect();
    assert_eq!(live.len(), 3, "exactly the routed keys materialized: {live:?}");
    coord.shutdown();
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
