//! Cross-format ingest conformance: the columnar `.dtc` partition
//! format and the lazy scanning path must be observationally identical
//! to the JSONL + tree-parsing paths they optimize — same rows back,
//! same days, and (the load-bearing check) a knowledge base refreshed
//! through the feedback service over columnar partitions serializes to
//! the same bytes as one refreshed over JSONL partitions holding the
//! same rows.

use dtopt::feedback::{FeedbackConfig, FeedbackService, IngestConfig, RefreshPolicy};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::logs::record::TransferLog;
use dtopt::logs::store::{LogStore, StoreFormat};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::offline::pipeline::{build, update, OfflineConfig};
use dtopt::sim::testbed::Testbed;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dtopt_ingconf_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn history(days: u64, seed: u64) -> Vec<TransferLog> {
    generate(
        &Testbed::xsede(),
        &GenConfig { days, arrivals_per_hour: 15.0, start_day: 0, seed },
    )
}

fn kb_bytes(kb: &KnowledgeBase) -> String {
    kb.to_json().to_string_compact()
}

#[test]
fn columnar_roundtrip_across_partitions() {
    let dir = tmpdir("roundtrip");
    let rows = history(3, 71);
    let store = LogStore::open_with_format(&dir, StoreFormat::Columnar).unwrap();
    store.append(&rows).unwrap();
    assert_eq!(store.days().unwrap().len(), 3);
    // Only .dtc partitions on disk.
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        assert!(name.ends_with(".dtc"), "unexpected partition {name}");
    }
    // Every field of every row survives the round trip, in order.
    let back = store.read_all().unwrap();
    assert_eq!(back, rows);
    // Appending more rows to an existing partition keeps earlier groups.
    let mut extra = rows[0].clone();
    extra.id = 999_999;
    store.append(std::slice::from_ref(&extra)).unwrap();
    let day0 = (rows[0].t_start / 86_400.0).floor() as u64;
    let again = store.read_day(day0).unwrap();
    assert_eq!(*again.last().unwrap(), extra);
    assert_eq!(store.row_count(day0).unwrap(), again.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mixed_format_directory_reads_both() {
    let dir = tmpdir("mixed");
    let rows = history(4, 72);
    let day_of = |r: &TransferLog| (r.t_start / 86_400.0).floor() as u64;
    let first_half: Vec<TransferLog> =
        rows.iter().filter(|r| day_of(r) < 2).cloned().collect();
    let second_half: Vec<TransferLog> =
        rows.iter().filter(|r| day_of(r) >= 2).cloned().collect();
    // Days 0–1 as JSONL, days 2–3 as columnar, one directory.
    LogStore::open(&dir).unwrap().append(&first_half).unwrap();
    LogStore::open_with_format(&dir, StoreFormat::Columnar)
        .unwrap()
        .append(&second_half)
        .unwrap();
    let store = LogStore::open(&dir).unwrap();
    assert_eq!(store.days().unwrap(), vec![0, 1, 2, 3]);
    assert_eq!(store.read_all().unwrap(), rows);
    // read_range is half-open: [1, 3) spans the JSONL/columnar seam.
    assert_eq!(store.read_range(1, 3).unwrap().len(), {
        rows.iter().filter(|r| (1..=2).contains(&day_of(r))).count()
    });
    // The scanning path agrees row-for-row regardless of which format
    // backs each partition.
    let mut scanned = 0usize;
    for day in store.days().unwrap() {
        let scan = store.scan_day(day).unwrap();
        for view in scan.rows() {
            let view = view.unwrap();
            assert_eq!(view.to_log(), rows[scanned]);
            scanned += 1;
        }
    }
    assert_eq!(scanned, rows.len());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The refresher regression the tentpole hinges on: drive the public
/// feedback service over a JSONL store and a columnar store, feed both
/// the same completed transfers, and require the refreshed knowledge
/// bases — and a direct in-memory `update` — to be byte-identical.
#[test]
fn service_refresh_is_byte_identical_across_formats() {
    let base_rows = history(3, 73);
    let kb = Arc::new(build(&base_rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    let mut fresh = history(1, 74);
    for row in &mut fresh {
        row.t_start += 4.0 * 86_400.0; // land in a new partition
    }

    let config = FeedbackConfig {
        ingest: IngestConfig {
            capacity: 4096,
            flush_batch: 16,
            flush_interval: Duration::from_millis(2),
        },
        policy: RefreshPolicy { min_new_rows: 1, min_interval: Duration::ZERO, ..Default::default() },
        poll_interval: Duration::from_millis(100),
        background: false,
    };

    let mut refreshed = Vec::new();
    for (tag, format) in [("jsonl", StoreFormat::Jsonl), ("dtc", StoreFormat::Columnar)] {
        let dir = tmpdir(tag);
        let store = LogStore::open_with_format(&dir, format).unwrap();
        let service = FeedbackService::start(kb.clone(), store, config.clone()).unwrap();
        let queue = service.queue();
        for row in fresh.iter().cloned() {
            assert!(queue.offer(row), "bounded queue overflowed in test");
        }
        drop(queue);
        assert!(service.flush_barrier(Duration::from_secs(30)), "flush timed out");
        let generation = service.refresh_now().unwrap();
        assert_eq!(generation, Some(1), "{tag}: one refresh folds in the new partition");
        refreshed.push(kb_bytes(&service.slot.resolve().kb));
        service.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    let mut direct = (*kb).clone();
    update(&mut direct, &fresh).unwrap();
    assert_eq!(refreshed[0], refreshed[1], "JSONL vs columnar refresh diverged");
    assert_eq!(refreshed[0], kb_bytes(&direct), "scanned refresh diverged from in-memory update");
}

#[test]
fn compact_preserves_rows_and_is_idempotent() {
    let dir = tmpdir("compact");
    let rows = history(3, 75);
    let store = LogStore::open(&dir).unwrap();
    store.append(&rows).unwrap();
    let before = store.read_all().unwrap();

    let compacting = LogStore::open_with_format(&dir, StoreFormat::Columnar).unwrap();
    let report = compacting.compact().unwrap();
    assert_eq!(report.migrated, vec![0, 1, 2]);
    assert!(report.already_columnar.is_empty());
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        assert!(name.ends_with(".dtc"), "original left behind: {name}");
    }
    assert_eq!(compacting.read_all().unwrap(), before);
    // A plain (JSONL-default) handle on the same directory reads the
    // columnar partitions transparently.
    assert_eq!(LogStore::open(&dir).unwrap().read_all().unwrap(), before);

    let second = compacting.compact().unwrap();
    assert!(second.migrated.is_empty());
    assert_eq!(second.already_columnar, vec![0, 1, 2]);
    assert_eq!(compacting.read_all().unwrap(), before);
    let _ = std::fs::remove_dir_all(&dir);
}
