//! Cross-layer integration tests: PJRT artifacts vs native reference
//! (the L1/L2 ⇄ L3 numerical contract), the offline pipeline on the
//! accelerated backend, and the full offline→online→coordinator loop.
//!
//! PJRT tests skip gracefully when `artifacts/` has not been built
//! (`make artifacts`); the Makefile test target always builds it first.

use dtopt::logs::generate::{generate, GenConfig};
#[cfg(feature = "pjrt")]
use dtopt::math::bicubic::BicubicSurface;
#[cfg(feature = "pjrt")]
use dtopt::offline::kmeans::{kmeans_pp, AssignBackend};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::runtime::Backend;
#[cfg(feature = "pjrt")]
use dtopt::runtime::{ArtifactRegistry, PjrtAssign};
use dtopt::sim::testbed::Testbed;
#[cfg(feature = "pjrt")]
use dtopt::util::rng::Rng;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_pairwise_matches_native_assign() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = ArtifactRegistry::load(&dir).unwrap();
    let mut rng = Rng::new(101);
    for &(n, d, k) in &[(50usize, 6usize, 3usize), (1024, 8, 32), (1500, 4, 7), (3, 2, 2)] {
        let points: Vec<f64> = (0..n * d).map(|_| rng.range_f64(-5.0, 5.0)).collect();
        let centroids: Vec<f64> = (0..k * d).map(|_| rng.range_f64(-5.0, 5.0)).collect();
        let mut native = vec![0u32; n];
        let mut pjrt = vec![0u32; n];
        let native_inertia = NativeAssign
            .assign(&points, n, d, &centroids, k, &mut native)
            .unwrap();
        let pjrt_inertia = PjrtAssign { registry: &registry }
            .assign(&points, n, d, &centroids, k, &mut pjrt)
            .unwrap();
        assert_eq!(native, pjrt, "assignments diverge at n={n} d={d} k={k}");
        let rel = (native_inertia - pjrt_inertia).abs() / native_inertia.max(1e-9);
        assert!(rel < 1e-4, "inertia diverges: {native_inertia} vs {pjrt_inertia}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_kmeans_run_matches_native_clusters() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = ArtifactRegistry::load(&dir).unwrap();
    // Well-separated blobs: both backends must find the same partition.
    let mut rng = Rng::new(7);
    let mut points = Vec::new();
    for &(cx, cy) in &[(0.0, 0.0), (20.0, 0.0), (0.0, 20.0), (20.0, 20.0)] {
        for _ in 0..100 {
            points.push(cx + rng.normal());
            points.push(cy + rng.normal());
        }
    }
    let n = 400;
    let mut rng_a = Rng::new(55);
    let mut rng_b = Rng::new(55);
    let native = kmeans_pp(&points, n, 2, 4, &mut rng_a, &mut NativeAssign, 40).unwrap();
    let pjrt = kmeans_pp(
        &points,
        n,
        2,
        4,
        &mut rng_b,
        &mut PjrtAssign { registry: &registry },
        40,
    )
    .unwrap();
    assert_eq!(native.assignments, pjrt.assignments);
    for (a, b) in native.centroids.iter().zip(&pjrt.centroids) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_surface_eval_matches_native_bicubic() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = ArtifactRegistry::load(&dir).unwrap();
    let knots: Vec<f64> = dtopt::logs::generate::PARAM_KNOTS.iter().map(|&k| k as f64).collect();
    let mut rng = Rng::new(31);
    let z: Vec<f64> = (0..64).map(|_| rng.range_f64(0.0, 5_000.0)).collect();
    let surface = BicubicSurface::fit(&knots, &knots, &z).unwrap();
    let grids = registry.surface_eval_batch(&[&surface]).unwrap();
    let dense = &grids[0];
    // PJRT grid point (i*8+a, j*8+b) is the patch-local (a/8, b/8)
    // evaluation of patch (i, j).
    let gp = 7usize;
    let r = 8usize;
    let mut max_rel: f64 = 0.0;
    for i in 0..gp {
        for a in 0..r {
            for j in 0..gp {
                for b in 0..r {
                    let x = knots[i] + (knots[i + 1] - knots[i]) * a as f64 / r as f64;
                    let y = knots[j] + (knots[j + 1] - knots[j]) * b as f64 / r as f64;
                    let want = surface.eval(x, y);
                    let got = dense[(i * r + a) * gp * r + (j * r + b)] as f64;
                    let rel = (got - want).abs() / want.abs().max(1.0);
                    max_rel = max_rel.max(rel);
                }
            }
        }
    }
    assert!(max_rel < 1e-4, "surface eval diverges: max rel {max_rel:.2e}");
}

#[cfg(feature = "pjrt")]
#[test]
fn offline_pipeline_identical_on_both_backends() {
    let Some(dir) = artifacts_dir() else { return };
    let rows = generate(
        &Testbed::xsede(),
        &GenConfig { days: 4, arrivals_per_hour: 25.0, start_day: 0, seed: 77 },
    );
    let cfg = OfflineConfig::default();
    let kb_native = build(&rows, &cfg, &mut NativeAssign).unwrap();
    let registry = ArtifactRegistry::load(&dir).unwrap();
    let kb_pjrt = build(&rows, &cfg, &mut PjrtAssign { registry: &registry }).unwrap();
    assert_eq!(kb_native.clusters.len(), kb_pjrt.clusters.len());
    for (a, b) in kb_native.clusters.iter().zip(&kb_pjrt.clusters) {
        assert_eq!(a.n_rows, b.n_rows, "cluster populations diverge");
        assert_eq!(a.surfaces.len(), b.surfaces.len());
        for (sa, sb) in a.surfaces.iter().zip(&b.surfaces) {
            assert_eq!(sa.argmax.0, sb.argmax.0, "argmax diverges between backends");
        }
    }
}

#[test]
fn backend_auto_detects() {
    let missing = Backend::auto(std::path::Path::new("/nonexistent"));
    assert_eq!(missing.name(), "native");
    #[cfg(feature = "pjrt")]
    if let Some(dir) = artifacts_dir() {
        let found = Backend::auto(&dir);
        assert_eq!(found.name(), "pjrt");
        assert!(found.registry().is_some());
    }
}

#[test]
fn end_to_end_offline_online_coordinator() {
    use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
    use dtopt::sim::dataset::Dataset;
    use dtopt::sim::testbed::TestbedId;
    use std::sync::Arc;

    let tb = Testbed::xsede();
    let rows = generate(&tb, &GenConfig { days: 6, arrivals_per_hour: 30.0, start_day: 0, seed: 88 });
    let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    let coord = Coordinator::new(kb, Arc::new(rows), CoordinatorConfig::default());
    let mut asm_sum = 0.0;
    let mut go_sum = 0.0;
    let mut opt_sum = 0.0;
    for i in 0..6u64 {
        let base = TransferRequest {
            id: coord.fresh_id(),
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(150, 80.0),
            t_submit: i as f64 * 7_200.0,
            state_override: None,
            optimizer: Some(OptimizerKind::Asm),
            seed: 900 + i,
        };
        let mut go_req = base.clone();
        go_req.id = coord.fresh_id();
        go_req.optimizer = Some(OptimizerKind::Go);
        let responses = coord.run_batch(vec![base, go_req]);
        asm_sum += responses[0].report.achieved_mbps();
        go_sum += responses[1].report.achieved_mbps();
        opt_sum += responses[0].optimal_mbps;
    }
    // The paper's headline ordering: ASM ≥ GO, and ASM close to optimal.
    assert!(asm_sum > go_sum, "ASM {asm_sum:.0} vs GO {go_sum:.0}");
    assert!(
        asm_sum > 0.7 * opt_sum,
        "ASM at {:.0}% of optimal",
        100.0 * asm_sum / opt_sum
    );
    coord.shutdown();
}
