//! Seeded-schedule stress suite for the stampede plane's lock-sharding
//! work: the races the N-worker runner makes real, each pinned down in
//! isolation. Companion to `scenario_conformance.rs` (which races whole
//! scenario replays) and `crate::stampede::conformance` (which defines
//! what a legal interleaving is).

use dtopt::fabric::{FabricConfig, ShardKey, ShardRouter};
use dtopt::feedback::SnapshotSlot;
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::netplane::LinkPlane;
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::probe::{FollowOutcome, Role, SingleFlight};
use dtopt::sim::dataset::SizeClass;
use dtopt::sim::testbed::{Testbed, TestbedId};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

fn tiny_kb(seed: u64) -> Arc<KnowledgeBase> {
    let rows = generate(
        &Testbed::xsede(),
        &GenConfig { days: 2, arrivals_per_hour: 15.0, start_day: 0, seed },
    );
    Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap())
}

/// Concurrent snapshot swaps vs pinned readers: a reader crowd hammers
/// `resolve` through 300 publishes and must never observe a torn
/// snapshot (a generation that was never published, an empty KB body)
/// or a regressing generation sequence.
#[test]
fn snapshot_swap_under_pinned_readers_never_tears() {
    let kb = tiny_kb(0x5EED_01);
    let slot = Arc::new(SnapshotSlot::new(kb.clone()));
    let publishes = 300u64;
    let start = Arc::new(Barrier::new(7));
    let readers: Vec<_> = (0..6)
        .map(|_| {
            let slot = slot.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                start.wait();
                let mut last = 0u64;
                let mut pinned = Vec::new();
                loop {
                    let snap = slot.resolve();
                    assert!(snap.generation >= last, "generation regressed");
                    assert!(snap.generation <= publishes, "torn: unpublished generation");
                    assert!(!snap.kb.clusters.is_empty(), "torn: empty snapshot body");
                    last = snap.generation;
                    // Keep every 32nd snapshot pinned across later
                    // publishes — pinned handles must stay intact.
                    if last % 32 == 0 {
                        pinned.push(snap);
                    }
                    if last == publishes {
                        break;
                    }
                    std::hint::spin_loop();
                }
                for snap in &pinned {
                    assert!(!snap.kb.clusters.is_empty(), "pinned snapshot body freed");
                }
            })
        })
        .collect();
    start.wait();
    for _ in 0..publishes {
        slot.publish(kb.clone());
    }
    for reader in readers {
        reader.join().expect("reader panicked");
    }
    assert_eq!(slot.generation(), publishes);
}

/// Two threads racing a cold key through the router must materialize
/// exactly one shard: both land on the same `Arc`, and the map holds
/// one live shard (the per-key guard's double-check, at the
/// integration boundary).
#[test]
fn racing_routes_materialize_one_shard() {
    let dir = std::env::temp_dir()
        .join(format!("dtopt_stampede_race_route_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let router = Arc::new(
        ShardRouter::open(&dir, tiny_kb(0x5EED_02), FabricConfig::default()).unwrap(),
    );
    let key = ShardKey::new(TestbedId::Xsede, SizeClass::Medium);
    let start = Arc::new(Barrier::new(4));
    let racers: Vec<_> = (0..4)
        .map(|_| {
            let router = router.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                start.wait();
                router.route(key).shard.expect("route must yield a shard")
            })
        })
        .collect();
    let shards: Vec<_> = racers
        .into_iter()
        .map(|racer| racer.join().expect("racer panicked"))
        .collect();
    for other in &shards[1..] {
        assert!(
            Arc::ptr_eq(&shards[0], other),
            "two racers received different shard instances for one key"
        );
    }
    assert_eq!(router.live_shards().len(), 1, "the race built more than one shard");
    router.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A worker that panics mid-transfer still drains its link occupancy:
/// the `LinkLease` releases on unwind (Drop), so the link never leaks
/// a phantom transfer. This is what makes `StampedeRunner`'s
/// panic-propagation safe for the shared planes.
#[test]
fn link_lease_drop_on_panic_drains_occupancy() {
    let links = Arc::new(LinkPlane::shared());
    let survivor = links.clone().admit(TestbedId::Xsede, 1);
    let panicker = {
        let links = links.clone();
        std::thread::spawn(move || {
            let _lease = links.admit(TestbedId::Xsede, 2);
            assert_eq!(2, 3, "worker dies mid-transfer, lease still held");
        })
    };
    assert!(panicker.join().is_err(), "worker must have panicked");
    // The panicker's lease unwound; only the survivor remains.
    assert_eq!(links.active_total(), 1);
    assert_eq!(links.occupancy(TestbedId::Xsede).transfers, 1);
    drop(survivor);
    assert_eq!(links.active_total(), 0, "occupancy must drain to zero");
    assert_eq!(links.occupancy(TestbedId::Xsede).transfers, 0);
}

/// A single-flight cohort whose leader aborts wakes every follower:
/// no deadlock, no bounded-wait expiry — every waiter sees `Aborted`
/// well inside its timeout, and the key is immediately leadable again.
#[test]
fn leader_abort_wakes_all_followers() {
    let flights = SingleFlight::new();
    let key = ShardKey::new(TestbedId::Didclab, SizeClass::Large);
    let guard = match flights.lead_or_join(key) {
        Role::Leader(guard) => guard,
        Role::Follower(_) => panic!("first contact must lead"),
    };
    let followers: Vec<_> = (0..8)
        .map(|_| {
            let flights = flights.clone();
            std::thread::spawn(move || match flights.lead_or_join(key) {
                Role::Leader(_) => panic!("flight is open; nobody else may lead"),
                Role::Follower(flight) => flight.wait(Duration::from_secs(30)),
            })
        })
        .collect();
    // Hold the leader until the whole cohort is parked on the flight.
    let deadline = Instant::now() + Duration::from_secs(10);
    while flights.waiters(key) < 8 {
        assert!(Instant::now() < deadline, "followers never reached the flight");
        std::thread::yield_now();
    }
    let woke_by = Instant::now() + Duration::from_secs(10);
    guard.abort();
    for follower in followers {
        let outcome = follower.join().expect("follower panicked");
        assert_eq!(outcome, FollowOutcome::Aborted, "abort must wake, not time out");
    }
    assert!(
        Instant::now() < woke_by,
        "followers woke, but nowhere near the abort — bounded wait violated"
    );
    // The aborted flight is gone: the next contact leads again.
    match flights.lead_or_join(key) {
        Role::Leader(guard) => {
            assert_eq!(flights.in_flight(), 1);
            drop(guard);
        }
        Role::Follower(_) => panic!("aborted flight must not linger"),
    }
    assert_eq!(flights.in_flight(), 0, "dropping the guard clears the flight");
}

/// Dropping the leader's guard (a panicking leader) is an abort too —
/// the unwind path a stampede worker takes when its ladder dies.
#[test]
fn leader_panic_unwind_aborts_the_flight() {
    let flights = SingleFlight::new();
    let key = ShardKey::new(TestbedId::DidclabToXsede, SizeClass::Small);
    let parked = Arc::new(AtomicBool::new(false));
    let follower = {
        let flights = flights.clone();
        let parked = parked.clone();
        std::thread::spawn(move || {
            let flight = loop {
                match flights.lead_or_join(key) {
                    Role::Follower(flight) => break flight,
                    // The leader thread hasn't led yet; retry — the
                    // guard from this accidental lead aborts on drop,
                    // so the retry can lead or follow cleanly.
                    Role::Leader(guard) => {
                        drop(guard);
                        std::thread::yield_now();
                    }
                }
            };
            parked.store(true, Ordering::Release);
            flight.wait(Duration::from_secs(30))
        })
    };
    let leader = {
        let flights = flights.clone();
        let parked = parked.clone();
        std::thread::spawn(move || {
            let _guard = loop {
                match flights.lead_or_join(key) {
                    Role::Leader(guard) => break guard,
                    Role::Follower(_) => std::thread::yield_now(),
                }
            };
            // Wait for the follower to park, then die with the guard
            // held: the unwind must abort the flight.
            let deadline = Instant::now() + Duration::from_secs(10);
            while !parked.load(Ordering::Acquire) || flights.waiters(key) == 0 {
                assert!(Instant::now() < deadline, "follower never parked");
                std::thread::yield_now();
            }
            panic!("leader dies mid-ladder");
        })
    };
    assert!(leader.join().is_err(), "leader must have panicked");
    let outcome = follower.join().expect("follower panicked");
    assert_eq!(outcome, FollowOutcome::Aborted, "unwound leader must wake followers");
}
