//! Golden fixtures for the decision-provenance traces: every bundled
//! scenario's full `traces_to_json` output is snapshotted, so any drift
//! in the trace schema, the emission points, or the replay itself shows
//! up as a reviewed fixture diff instead of a silent change to what
//! `dtopt trace --json` consumers parse.
//!
//! Unlike `metrics_golden` this reads its fixtures at runtime (not
//! `include_str!`): the goldens bootstrap from a machine that can run
//! the suite, so a missing fixture is a note to regenerate, not a
//! compile error. Once a fixture is committed it is enforced bytewise.
//!
//! To (re)generate after an *intentional* trace change:
//! `DTOPT_UPDATE_GOLDEN=1 cargo test --test trace_golden` — then review
//! and commit the fixture diffs.

use dtopt::scenario::script::{bundled, bundled_names, Scenario};
use dtopt::scenario::{run, RunOptions};
use dtopt::telemetry::traces_to_json;
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/traces")
        .join(format!("{name}.json"))
}

#[test]
fn bundled_scenario_traces_match_golden_fixtures() {
    let update = std::env::var("DTOPT_UPDATE_GOLDEN").is_ok();
    let mut missing = Vec::new();
    for name in bundled_names() {
        let scenario = Scenario::parse(bundled(name).expect("bundled scenario exists"))
            .unwrap_or_else(|e| panic!("parsing bundled '{name}': {e:#}"));
        let outcome = run(&scenario, &RunOptions::default())
            .unwrap_or_else(|e| panic!("running bundled '{name}': {e:#}"));
        // The golden ends in a newline so `diff` in CI stays quiet
        // about incomplete last lines.
        let rendered = format!("{}\n", traces_to_json(&outcome.traces).to_string_compact());
        let path = fixture_path(name);
        if update {
            std::fs::create_dir_all(path.parent().unwrap())
                .expect("creating the trace fixture directory");
            std::fs::write(&path, &rendered).expect("rewriting the trace golden");
            eprintln!("trace_golden: fixture regenerated at {}", path.display());
            continue;
        }
        match std::fs::read_to_string(&path) {
            Ok(golden) => assert_eq!(
                rendered,
                golden,
                "scenario '{name}' traces drifted from the golden fixture.\n\
                 If the change is intentional, regenerate with \
                 DTOPT_UPDATE_GOLDEN=1 cargo test --test trace_golden"
            ),
            Err(_) => missing.push(name),
        }
    }
    if !missing.is_empty() {
        eprintln!(
            "trace_golden: no fixture yet for {missing:?}; bootstrap with \
             DTOPT_UPDATE_GOLDEN=1 cargo test --test trace_golden"
        );
    }
}
