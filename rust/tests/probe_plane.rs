//! Integration: the shared probe plane on a fabric-backed coordinator.
//!
//! A burst of concurrent requests for one shard must coalesce its
//! sampling ladders (one leader, the rest piggybacked or served from
//! the estimate), attribute every response with its `probe_mode`, key
//! the plane by the serving shard, and render the probe metrics block
//! alongside the shard table.

use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::fabric::{FabricConfig, ShardKey, ShardRouter};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::online::asm::AsmOutcome;
use dtopt::probe::{Admission, ProbeMode, ProbeOcc, ProbePlane};
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::{Testbed, TestbedId};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[test]
fn fabric_coordinator_shares_one_probe_plane_per_shard() {
    let tb = Testbed::xsede();
    let rows =
        generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 71 });
    let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    let dir = std::env::temp_dir().join(format!("dtopt_probe_fabric_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fabric = Arc::new(ShardRouter::open(&dir, kb, FabricConfig::default()).unwrap());
    let plane = Arc::new(ProbePlane::default());
    let coord = Coordinator::with_fabric(
        fabric.clone(),
        Arc::new(rows),
        CoordinatorConfig { workers: 3, probe: Some(plane.clone()), ..Default::default() },
    );
    let requests: Vec<TransferRequest> = (1..=12)
        .map(|i| TransferRequest {
            id: i,
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(400, 100.0), // one shard: xsede/large
            t_submit: 3_600.0 * 10.0,
            state_override: None,
            optimizer: Some(OptimizerKind::Asm),
            seed: 4_000 + i,
        })
        .collect();
    let responses = coord.run_batch(requests);

    // Every response is attributed to the shard AND to a probe mode.
    let expected_key = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
    for response in &responses {
        assert_eq!(response.shard_key, Some(expected_key));
        assert!(response.probe_mode.is_some(), "ASM under a plane always has a mode");
    }
    let led = responses
        .iter()
        .filter(|r| r.probe_mode == Some(ProbeMode::Led))
        .count();
    assert!(led >= 1, "someone led the sampling ladder");
    assert!(led < responses.len(), "the burst coalesced instead of all leading");

    // The plane learned an estimate for the serving shard, and sampled
    // far less than one ladder per request.
    assert!(!plane.estimates().is_empty());
    let sampled: usize = responses.iter().map(|r| r.report.sample_transfers()).sum();
    assert!(sampled < responses.len(), "{sampled} samples across 12 coalesced requests");

    // Metrics: shard table, pooled latency line, and probe block all
    // render together.
    let table = coord.metrics.render();
    assert!(table.contains("fabric:"), "{table}");
    assert!(table.contains("request latency: p50"), "{table}");
    assert!(table.contains("probe plane:"), "{table}");
    assert!(table.contains("xsede/large"), "{table}");

    coord.shutdown();
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression lock on PR 3's documented piggyback mismatch path: a
/// follower whose request maps to a different KB cluster, or is pinned
/// to a different KB generation, must treat the leader's result as a
/// miss and fall back to its own decision (an unregistered independent
/// probe when the budget allows) — never adopt the leader's surface. A
/// matched follower, admitted in the same cohort, still piggybacks.
#[test]
fn mismatched_followers_fall_back_instead_of_adopting() {
    let plane = Arc::new(ProbePlane::default());
    let key = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
    let guard = match plane.admit(key, Some(0), 0, 10.0, ProbeOcc::default()) {
        Admission::Lead { guard, .. } => guard,
        _ => panic!("cold plane must lead"),
    };
    let spawn_follower = |cluster: usize, generation: u64| {
        let plane = plane.clone();
        std::thread::spawn(move || plane.admit(key, Some(cluster), generation, 10.0, ProbeOcc::default()))
    };
    let wrong_cluster = spawn_follower(1, 0);
    let wrong_generation = spawn_follower(0, 1);
    let matched = spawn_follower(0, 0);
    // Converge the leader only once the whole cohort is blocked on the
    // flight, so every follower deterministically observes the result.
    let deadline = Instant::now() + Duration::from_secs(30);
    while plane.waiting_followers(key) < 3 && Instant::now() < deadline {
        std::thread::yield_now();
    }
    assert_eq!(plane.waiting_followers(key), 3, "cohort never joined the flight");
    plane.lead_converged(
        key,
        Some(0),
        guard,
        AsmOutcome { surface_idx: 3, converged_idx: 3, sampled: true, intensity: 0.5 },
        0,
        ProbeOcc::default(),
    );
    match matched.join().unwrap() {
        Admission::Piggyback(result) => {
            assert_eq!(result.cluster_idx, 0);
            assert_eq!(result.generation, 0);
            assert_eq!(result.surface_idx, 3);
        }
        _ => panic!("the matched follower must piggyback on the leader"),
    }
    for (what, handle) in [("cluster", wrong_cluster), ("generation", wrong_generation)] {
        match handle.join().unwrap() {
            Admission::Piggyback(result) => {
                panic!("{what}-mismatched follower adopted the leader's result {result:?}")
            }
            Admission::Serve(surface) => {
                panic!("{what}-mismatched follower was served {surface:?} instead of probing")
            }
            Admission::Lead { guard, warm_start } => {
                // The documented fallback: probe independently, without
                // registering a new flight, warm-started only by an
                // estimate valid for the follower's own cluster and
                // generation — none exists here.
                assert!(guard.is_none(), "{what}: fallback probes are unregistered");
                assert!(warm_start.is_none(), "{what}: no valid estimate to warm-start from");
            }
        }
    }
    // Attribution: the leader plus the two fallback probes all count as
    // led; only the matched follower piggybacked.
    assert_eq!(plane.stats.led.load(std::sync::atomic::Ordering::Relaxed), 3);
    assert_eq!(plane.stats.piggybacked.load(std::sync::atomic::Ordering::Relaxed), 1);
}
