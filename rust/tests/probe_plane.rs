//! Integration: the shared probe plane on a fabric-backed coordinator.
//!
//! A burst of concurrent requests for one shard must coalesce its
//! sampling ladders (one leader, the rest piggybacked or served from
//! the estimate), attribute every response with its `probe_mode`, key
//! the plane by the serving shard, and render the probe metrics block
//! alongside the shard table.

use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::fabric::{FabricConfig, ShardKey, ShardRouter};
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::offline::kmeans::NativeAssign;
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::probe::{ProbeMode, ProbePlane};
use dtopt::sim::dataset::{Dataset, SizeClass};
use dtopt::sim::testbed::{Testbed, TestbedId};
use std::sync::Arc;

#[test]
fn fabric_coordinator_shares_one_probe_plane_per_shard() {
    let tb = Testbed::xsede();
    let rows =
        generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 71 });
    let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
    let dir = std::env::temp_dir().join(format!("dtopt_probe_fabric_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fabric = Arc::new(ShardRouter::open(&dir, kb, FabricConfig::default()).unwrap());
    let plane = Arc::new(ProbePlane::default());
    let coord = Coordinator::with_fabric(
        fabric.clone(),
        Arc::new(rows),
        CoordinatorConfig { workers: 3, probe: Some(plane.clone()), ..Default::default() },
    );
    let requests: Vec<TransferRequest> = (1..=12)
        .map(|i| TransferRequest {
            id: i,
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(400, 100.0), // one shard: xsede/large
            t_submit: 3_600.0 * 10.0,
            state_override: None,
            optimizer: Some(OptimizerKind::Asm),
            seed: 4_000 + i,
        })
        .collect();
    let responses = coord.run_batch(requests);

    // Every response is attributed to the shard AND to a probe mode.
    let expected_key = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
    for response in &responses {
        assert_eq!(response.shard_key, Some(expected_key));
        assert!(response.probe_mode.is_some(), "ASM under a plane always has a mode");
    }
    let led = responses
        .iter()
        .filter(|r| r.probe_mode == Some(ProbeMode::Led))
        .count();
    assert!(led >= 1, "someone led the sampling ladder");
    assert!(led < responses.len(), "the burst coalesced instead of all leading");

    // The plane learned an estimate for the serving shard, and sampled
    // far less than one ladder per request.
    assert!(!plane.estimates().is_empty());
    let sampled: usize = responses.iter().map(|r| r.report.sample_transfers()).sum();
    assert!(sampled < responses.len(), "{sampled} samples across 12 coalesced requests");

    // Metrics: shard table, pooled latency line, and probe block all
    // render together.
    let table = coord.metrics.render();
    assert!(table.contains("fabric:"), "{table}");
    assert!(table.contains("request latency: p50"), "{table}");
    assert!(table.contains("probe plane:"), "{table}");
    assert!(table.contains("xsede/large"), "{table}");

    coord.shutdown();
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
