//! Golden fixtures for the fleet health plane's exporters: one
//! hand-built, bit-deterministic `Metrics` — the same scripted inputs
//! as `metrics_golden`, plus accuracy-ledger scores and flight-recorder
//! entries — is cut through `Metrics::export_snapshot` and rendered by
//! both exporters, so any drift in the registry name taxonomy, the
//! Prometheus/JSON formats, or the snapshot merge semantics shows up as
//! a reviewed fixture diff instead of a silent change to what
//! `dtopt obs` (and `--metrics-out`) consumers parse.
//!
//! Like `trace_golden` (and unlike `metrics_golden`) the fixtures are
//! read at runtime, not `include_str!`: they bootstrap from a machine
//! that can run the suite, so a missing fixture is a note to
//! regenerate, not a compile error. Once committed they are enforced
//! bytewise.
//!
//! To (re)generate after an *intentional* change:
//! `DTOPT_UPDATE_GOLDEN=1 cargo test --test obs_golden` — then review
//! and commit the fixture diffs.

use dtopt::coordinator::Metrics;
use dtopt::fabric::{FabricConfig, ShardKey, ShardRouter};
use dtopt::feedback::FeedbackStats;
use dtopt::netplane::LinkPlane;
use dtopt::offline::knowledge::KnowledgeBase;
use dtopt::probe::{BudgetConfig, EstimateConfig, ProbeConfig, ProbeOcc, ProbePlane};
use dtopt::sim::dataset::SizeClass;
use dtopt::sim::testbed::TestbedId;
use dtopt::telemetry::{export, FlightRecord};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/obs").join(name)
}

fn check(name: &str, rendered: &str, update: bool, missing: &mut Vec<String>) {
    let path = fixture_path(name);
    if update {
        std::fs::create_dir_all(path.parent().unwrap())
            .expect("creating the obs fixture directory");
        std::fs::write(&path, rendered).expect("rewriting the obs golden");
        eprintln!("obs_golden: fixture regenerated at {}", path.display());
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            rendered, golden,
            "obs export '{name}' drifted from the golden fixture.\n\
             If the change is intentional, regenerate with \
             DTOPT_UPDATE_GOLDEN=1 cargo test --test obs_golden"
        ),
        Err(_) => missing.push(name.to_string()),
    }
}

#[test]
fn handbuilt_export_matches_golden_fixtures() {
    let metrics = Metrics::new();
    // Per-optimizer entries with fixed decision latencies (the wall-ns
    // column is render-only; the export must never carry it).
    metrics.record("ASM", 2000.0, 1000.0, 4.0, 2, 10_000);
    metrics.record("ASM", 1000.0, 1000.0, 8.0, 0, 30_000);
    metrics.record("GO", 500.0, 250.0, 4.0, 0, 2_000_000);

    // Knowledge-service counters set by hand.
    let feedback = Arc::new(FeedbackStats::default());
    feedback.kb_generation.store(3, Ordering::Relaxed);
    feedback.refreshes.store(2, Ordering::Relaxed);
    feedback.rows_consumed.store(120, Ordering::Relaxed);
    feedback.last_refresh_ns.store(2_000_000, Ordering::Relaxed);
    feedback.total_refresh_ns.store(6_000_000, Ordering::Relaxed);
    feedback.rows_enqueued.store(130, Ordering::Relaxed);
    feedback.rows_flushed.store(128, Ordering::Relaxed);
    feedback.flushes.store(16, Ordering::Relaxed);
    feedback.rows_dropped.store(2, Ordering::Relaxed);
    feedback.drift_events.store(5, Ordering::Relaxed);
    metrics.attach_feedback(feedback);

    // Fabric: an empty fallback KB means the routed shard borrows it
    // with zero rows — every published gauge is fixed.
    let dir = std::env::temp_dir().join(format!("dtopt_obs_golden_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fabric = Arc::new(
        ShardRouter::open(&dir, Arc::new(KnowledgeBase::empty()), FabricConfig::default())
            .unwrap(),
    );
    let _ = fabric.route(ShardKey::new(TestbedId::Xsede, SizeClass::Large));
    metrics.attach_fabric(fabric.clone());

    // Probe plane: scripted counters plus one estimate whose
    // confidence cannot visibly decay (million-second half-life).
    let plane = Arc::new(ProbePlane::new(ProbeConfig {
        estimate: EstimateConfig {
            half_life: Duration::from_secs(1_000_000),
            ..Default::default()
        },
        budget: BudgetConfig { capacity_mb: 4096.0, initial_mb: 4096.0, earn_fraction: 0.05 },
        ..Default::default()
    }));
    plane.stats.led.store(2, Ordering::Relaxed);
    plane.stats.piggybacked.store(5, Ordering::Relaxed);
    plane.stats.estimate_served.store(3, Ordering::Relaxed);
    plane.stats.budget_forced.store(1, Ordering::Relaxed);
    plane.stats.note_bytes(500.0, 9_500.0);
    plane.estimates().record(
        ShardKey::new(TestbedId::Xsede, SizeClass::Large),
        1,
        3,
        0.42,
        1.0,
        2,
        ProbeOcc::default(),
    );
    metrics.attach_probe(plane);

    // Link plane: one scripted registration plus an ambient convoy.
    let links = Arc::new(LinkPlane::shared());
    let lease = links.clone().admit(TestbedId::Xsede, 7);
    lease.update(8, 24, 2_500.0);
    links.set_ambient(TestbedId::Xsede, 4_000.0, 48);
    metrics.attach_links(links);

    // Fleet health plane: scripted accuracy scores and two retained
    // flights (ids, simulated seconds, Mbps — nothing wall-clock).
    metrics.ledger.score("xsede/large", 1860.0, 2000.0);
    metrics.ledger.score("xsede/large", 1500.0, 2000.0);
    metrics.ledger.score("didclab/small", 80.0, 100.0);
    metrics.recorder.push(FlightRecord {
        id: 1,
        optimizer: "ASM",
        shard: "xsede/large".to_string(),
        probe_mode: Some("led"),
        kb_generation: 3,
        borrowed: false,
        samples: 3,
        retunes: 1,
        total_mb: 1000.0,
        transfer_s: 4.0,
        achieved_mbps: 1860.0,
        optimal_mbps: 2000.0,
    });
    metrics.recorder.push(FlightRecord {
        id: 2,
        optimizer: "GO",
        shard: "didclab/small".to_string(),
        probe_mode: None,
        kb_generation: 3,
        borrowed: true,
        samples: 0,
        retunes: 0,
        total_mb: 250.0,
        transfer_s: 4.0,
        achieved_mbps: 80.0,
        optimal_mbps: 100.0,
    });

    let snap = metrics.export_snapshot();
    let prom = export::to_prometheus(&snap);
    let json = format!("{}\n", export::to_json(&snap).to_string_compact());

    drop(lease);
    fabric.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    // The export side of the determinism contract, independent of the
    // fixtures: no wall-clock family ever enters a snapshot.
    for name in snap.values.keys() {
        assert!(
            !name.contains("wall_ns") && !name.contains("refresh_ns") && !name.ends_with("flushes"),
            "wall-clock or scheduler-dependent family '{name}' leaked into the export"
        );
    }

    let update = std::env::var("DTOPT_UPDATE_GOLDEN").is_ok();
    let mut missing = Vec::new();
    check("handbuilt.prom", &prom, update, &mut missing);
    check("handbuilt.json", &json, update, &mut missing);
    if !missing.is_empty() {
        eprintln!(
            "obs_golden: no fixture yet for {missing:?}; bootstrap with \
             DTOPT_UPDATE_GOLDEN=1 cargo test --test obs_golden"
        );
    }
}

#[test]
fn export_snapshot_is_deterministic_across_cuts() {
    // Two snapshots of the same unchanged metrics must render
    // byte-identically in both formats — the property the CI
    // obs-conformance job enforces end to end over a full scenario.
    let metrics = Metrics::new();
    metrics.record("ASM", 2000.0, 1000.0, 4.0, 2, 10_000);
    metrics.ledger.score("xsede/large", 1860.0, 2000.0);
    let (a, b) = (metrics.export_snapshot(), metrics.export_snapshot());
    assert_eq!(export::to_prometheus(&a), export::to_prometheus(&b));
    assert_eq!(
        export::to_json(&a).to_string_compact(),
        export::to_json(&b).to_string_compact()
    );
}
