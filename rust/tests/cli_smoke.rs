//! CLI contract smoke tests: unknown or missing experiment/scenario
//! names must exit non-zero (listing what *is* available on stderr), so
//! scripts and CI can gate on the exit code instead of scraping output.

use std::process::{Command, Output};

fn dtopt(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dtopt"))
        .args(args)
        .output()
        .expect("spawning the dtopt binary")
}

#[test]
fn help_exits_zero_and_lists_scenario() {
    let out = dtopt(&["help"]);
    assert!(out.status.success(), "help must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("experiment"), "{stdout}");
    assert!(stdout.contains("scenario"), "{stdout}");
    assert!(stdout.contains("trace"), "{stdout}");
    assert!(stdout.contains("obs"), "{stdout}");
    assert!(stdout.contains("logs compact"), "{stdout}");
    assert!(stdout.contains("ingest"), "help lists the ingest experiment: {stdout}");
}

#[test]
fn logs_compact_rejects_bad_input_nonzero() {
    // Missing action, unknown action, missing directory, and a
    // nonexistent directory all exit non-zero — the last one *before*
    // opening the store, which would otherwise create the typo'd path.
    let missing_action = dtopt(&["logs"]);
    assert!(!missing_action.status.success(), "missing logs action must exit non-zero");
    let stderr = String::from_utf8_lossy(&missing_action.stderr);
    assert!(stderr.contains("logs compact"), "usage on stderr: {stderr}");

    let unknown = dtopt(&["logs", "defrag"]);
    assert!(!unknown.status.success(), "unknown logs action must exit non-zero");
    let stderr = String::from_utf8_lossy(&unknown.stderr);
    assert!(stderr.contains("defrag"), "{stderr}");

    let missing_dir = dtopt(&["logs", "compact"]);
    assert!(!missing_dir.status.success(), "missing directory must exit non-zero");

    let bad = dtopt(&["logs", "compact", "/no/such/dtopt/log/dir"]);
    assert!(!bad.status.success(), "nonexistent directory must exit non-zero");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("no such log directory"), "{stderr}");
    assert!(
        !std::path::Path::new("/no/such/dtopt/log/dir").exists(),
        "a failed compact must not create the directory"
    );
}

#[test]
fn logs_compact_migrates_and_is_idempotent() {
    let dir = std::env::temp_dir().join(format!("dtopt_cli_compact_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let seeded = dtopt(&["gen-logs", "--testbed", "xsede", "--days", "2", "--out",
        dir.to_str().unwrap(), "--rate", "5", "--seed", "9"]);
    assert!(seeded.status.success(), "{}", String::from_utf8_lossy(&seeded.stderr));

    let first = dtopt(&["logs", "compact", dir.to_str().unwrap()]);
    assert!(first.status.success(), "{}", String::from_utf8_lossy(&first.stderr));
    let stdout = String::from_utf8_lossy(&first.stdout);
    assert!(stdout.contains("2 partition(s) migrated"), "{stdout}");
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(names.iter().all(|n| n.ends_with(".dtc")), "originals removed: {names:?}");

    // Re-running is a no-op reporting everything already columnar.
    let second = dtopt(&["logs", "compact", dir.to_str().unwrap()]);
    assert!(second.status.success(), "{}", String::from_utf8_lossy(&second.stderr));
    let stdout = String::from_utf8_lossy(&second.stdout);
    assert!(stdout.contains("0 partition(s) migrated"), "{stdout}");
    assert!(stdout.contains("2 already columnar"), "{stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn obs_rejects_unknown_flags_nonzero() {
    // The shared parser swallows unknown `--flags`; obs validates
    // strictly so a typo can't silently print the default export.
    let out = dtopt(&["obs", "--bogus"]);
    assert!(!out.status.success(), "unknown obs flag must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown flag"), "{stderr}");
    assert!(stderr.contains("--bogus"), "{stderr}");
}

#[test]
fn obs_rejects_unknown_options_and_positionals_nonzero() {
    let with_value = dtopt(&["obs", "--bogus", "value"]);
    assert!(!with_value.status.success(), "unknown obs option must exit non-zero");
    let positional = dtopt(&["obs", "flash-crowd"]);
    assert!(!positional.status.success(), "obs takes --scenario, not a positional");
    let stderr = String::from_utf8_lossy(&positional.stderr);
    assert!(stderr.contains("--scenario"), "{stderr}");
}

#[test]
fn obs_rejects_unknown_scenario_nonzero() {
    let out = dtopt(&["obs", "--scenario", "no-such-scenario"]);
    assert!(!out.status.success(), "unknown obs scenario must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = dtopt(&["frobnicate"]);
    assert!(!out.status.success(), "unknown command must exit non-zero");
}

#[test]
fn missing_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment"]);
    assert!(!out.status.success(), "missing experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
    assert!(stderr.contains("fig5"), "{stderr}");
}

#[test]
fn unknown_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment", "fig99"]);
    assert!(!out.status.success(), "unknown experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
}

#[test]
fn missing_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario"]);
    assert!(!out.status.success(), "missing scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("flash-crowd"), "{stderr}");
}

#[test]
fn unknown_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario", "no-such-scenario"]);
    assert!(!out.status.success(), "unknown scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("convoy"), "{stderr}");
}

#[test]
fn scenario_list_prints_bundled_names_and_exits_zero() {
    let out = dtopt(&["scenario", "--list"]);
    assert!(out.status.success(), "--list is a successful query, not an error");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let names: Vec<&str> = stdout.lines().collect();
    assert_eq!(
        names,
        vec!["flash-crowd", "brownout", "stale-kb", "probe-famine", "shard-churn", "convoy"],
        "{stdout}"
    );
}

#[test]
fn missing_trace_scenario_exits_nonzero() {
    let out = dtopt(&["trace"]);
    assert!(!out.status.success(), "missing trace scenario must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("flash-crowd"), "{stderr}");
}

#[test]
fn unknown_trace_scenario_exits_nonzero_like_scenario() {
    // `trace` resolves its argument through the same path as
    // `scenario`, so an unknown name yields the same error text (modulo
    // exit status both non-zero).
    let trace = dtopt(&["trace", "no-such-scenario"]);
    let scenario = dtopt(&["scenario", "no-such-scenario"]);
    assert!(!trace.status.success(), "unknown trace scenario must exit non-zero");
    assert!(!scenario.status.success());
    let trace_err = String::from_utf8_lossy(&trace.stderr);
    assert!(trace_err.contains("bundled"), "{trace_err}");
    assert!(trace_err.contains("convoy"), "{trace_err}");
    assert_eq!(trace_err, String::from_utf8_lossy(&scenario.stderr));
}

#[test]
fn trace_rejects_unknown_flags_options_and_extra_positionals_nonzero() {
    // `trace` validates strictly like `obs`: a typo exits non-zero
    // before any replay starts, instead of silently replaying with the
    // option ignored.
    let flag = dtopt(&["trace", "flash-crowd", "--bogus"]);
    assert!(!flag.status.success(), "unknown trace flag must exit non-zero");
    let stderr = String::from_utf8_lossy(&flag.stderr);
    assert!(stderr.contains("unknown flag"), "{stderr}");
    assert!(stderr.contains("--bogus"), "{stderr}");

    let option = dtopt(&["trace", "flash-crowd", "--bogus", "value"]);
    assert!(!option.status.success(), "unknown trace option must exit non-zero");
    let stderr = String::from_utf8_lossy(&option.stderr);
    assert!(stderr.contains("unknown option"), "{stderr}");

    // `--metrics-out` without a path parses as a flag: rejected.
    let dangling = dtopt(&["trace", "flash-crowd", "--metrics-out"]);
    assert!(!dangling.status.success(), "--metrics-out without a path must exit non-zero");

    let extra = dtopt(&["trace", "flash-crowd", "stale-kb"]);
    assert!(!extra.status.success(), "two scenario positionals must exit non-zero");
    let stderr = String::from_utf8_lossy(&extra.stderr);
    assert!(stderr.contains("one scenario"), "{stderr}");
}

#[test]
fn trace_metrics_out_picks_format_by_extension() {
    // Satellite of the sentry plane: `dtopt trace --metrics-out F`
    // exports the replay's registry snapshot — Prometheus text for
    // `.prom`, compact JSON otherwise — exactly like scenario/serve.
    let dir = std::env::temp_dir().join(format!("dtopt_cli_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let prom = dir.join("metrics.prom");
    let json = dir.join("metrics.json");

    let out = dtopt(&["trace", "flash-crowd", "--request", "0", "--metrics-out",
        prom.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let prom_text = std::fs::read_to_string(&prom).expect("prom export written");
    assert!(prom_text.contains("sentry_ticks"), "prom names are sanitized: {prom_text}");
    assert!(prom_text.contains("recorder_capacity"), "{prom_text}");

    let out = dtopt(&["trace", "flash-crowd", "--request", "0", "--metrics-out",
        json.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json_text = std::fs::read_to_string(&json).expect("json export written");
    assert!(json_text.starts_with('{'), "{json_text}");
    assert!(json_text.contains("sentry.ticks"), "json keeps raw names: {json_text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn obs_alerts_json_is_empty_for_the_quiet_default_scenario() {
    // flash-crowd is fault-free and declares expect-quiet: the sentry
    // must raise nothing, so the machine-readable alert timeline is an
    // empty array.
    let out = dtopt(&["obs", "--alerts", "--json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.trim(), "[]", "{stdout}");
}

#[test]
fn missing_scenario_listing_matches_experiment_listing_behavior() {
    // Both subcommands answer a missing name the same way: non-zero
    // exit, the available set on stderr.
    let scenario = dtopt(&["scenario"]);
    let experiment = dtopt(&["experiment"]);
    assert!(!scenario.status.success());
    assert!(!experiment.status.success());
    let scenario_err = String::from_utf8_lossy(&scenario.stderr);
    let experiment_err = String::from_utf8_lossy(&experiment.stderr);
    assert!(scenario_err.contains("convoy"), "{scenario_err}");
    assert!(experiment_err.contains("convoy"), "{experiment_err}");
}
