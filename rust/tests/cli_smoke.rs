//! CLI contract smoke tests: unknown or missing experiment/scenario
//! names must exit non-zero (listing what *is* available on stderr), so
//! scripts and CI can gate on the exit code instead of scraping output.

use std::process::{Command, Output};

fn dtopt(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dtopt"))
        .args(args)
        .output()
        .expect("spawning the dtopt binary")
}

#[test]
fn help_exits_zero_and_lists_scenario() {
    let out = dtopt(&["help"]);
    assert!(out.status.success(), "help must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("experiment"), "{stdout}");
    assert!(stdout.contains("scenario"), "{stdout}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = dtopt(&["frobnicate"]);
    assert!(!out.status.success(), "unknown command must exit non-zero");
}

#[test]
fn missing_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment"]);
    assert!(!out.status.success(), "missing experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
    assert!(stderr.contains("fig5"), "{stderr}");
}

#[test]
fn unknown_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment", "fig99"]);
    assert!(!out.status.success(), "unknown experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
}

#[test]
fn missing_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario"]);
    assert!(!out.status.success(), "missing scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("flash-crowd"), "{stderr}");
}

#[test]
fn unknown_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario", "no-such-scenario"]);
    assert!(!out.status.success(), "unknown scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
}
