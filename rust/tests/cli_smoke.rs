//! CLI contract smoke tests: unknown or missing experiment/scenario
//! names must exit non-zero (listing what *is* available on stderr), so
//! scripts and CI can gate on the exit code instead of scraping output.

use std::process::{Command, Output};

fn dtopt(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dtopt"))
        .args(args)
        .output()
        .expect("spawning the dtopt binary")
}

#[test]
fn help_exits_zero_and_lists_scenario() {
    let out = dtopt(&["help"]);
    assert!(out.status.success(), "help must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("experiment"), "{stdout}");
    assert!(stdout.contains("scenario"), "{stdout}");
    assert!(stdout.contains("trace"), "{stdout}");
    assert!(stdout.contains("obs"), "{stdout}");
}

#[test]
fn obs_rejects_unknown_flags_nonzero() {
    // The shared parser swallows unknown `--flags`; obs validates
    // strictly so a typo can't silently print the default export.
    let out = dtopt(&["obs", "--bogus"]);
    assert!(!out.status.success(), "unknown obs flag must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown flag"), "{stderr}");
    assert!(stderr.contains("--bogus"), "{stderr}");
}

#[test]
fn obs_rejects_unknown_options_and_positionals_nonzero() {
    let with_value = dtopt(&["obs", "--bogus", "value"]);
    assert!(!with_value.status.success(), "unknown obs option must exit non-zero");
    let positional = dtopt(&["obs", "flash-crowd"]);
    assert!(!positional.status.success(), "obs takes --scenario, not a positional");
    let stderr = String::from_utf8_lossy(&positional.stderr);
    assert!(stderr.contains("--scenario"), "{stderr}");
}

#[test]
fn obs_rejects_unknown_scenario_nonzero() {
    let out = dtopt(&["obs", "--scenario", "no-such-scenario"]);
    assert!(!out.status.success(), "unknown obs scenario must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = dtopt(&["frobnicate"]);
    assert!(!out.status.success(), "unknown command must exit non-zero");
}

#[test]
fn missing_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment"]);
    assert!(!out.status.success(), "missing experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
    assert!(stderr.contains("fig5"), "{stderr}");
}

#[test]
fn unknown_experiment_name_exits_nonzero() {
    let out = dtopt(&["experiment", "fig99"]);
    assert!(!out.status.success(), "unknown experiment name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("available"), "stderr lists what exists: {stderr}");
}

#[test]
fn missing_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario"]);
    assert!(!out.status.success(), "missing scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("flash-crowd"), "{stderr}");
}

#[test]
fn unknown_scenario_name_exits_nonzero() {
    let out = dtopt(&["scenario", "no-such-scenario"]);
    assert!(!out.status.success(), "unknown scenario name must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("convoy"), "{stderr}");
}

#[test]
fn scenario_list_prints_bundled_names_and_exits_zero() {
    let out = dtopt(&["scenario", "--list"]);
    assert!(out.status.success(), "--list is a successful query, not an error");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let names: Vec<&str> = stdout.lines().collect();
    assert_eq!(
        names,
        vec!["flash-crowd", "brownout", "stale-kb", "probe-famine", "shard-churn", "convoy"],
        "{stdout}"
    );
}

#[test]
fn missing_trace_scenario_exits_nonzero() {
    let out = dtopt(&["trace"]);
    assert!(!out.status.success(), "missing trace scenario must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bundled"), "stderr lists the bundled library: {stderr}");
    assert!(stderr.contains("flash-crowd"), "{stderr}");
}

#[test]
fn unknown_trace_scenario_exits_nonzero_like_scenario() {
    // `trace` resolves its argument through the same path as
    // `scenario`, so an unknown name yields the same error text (modulo
    // exit status both non-zero).
    let trace = dtopt(&["trace", "no-such-scenario"]);
    let scenario = dtopt(&["scenario", "no-such-scenario"]);
    assert!(!trace.status.success(), "unknown trace scenario must exit non-zero");
    assert!(!scenario.status.success());
    let trace_err = String::from_utf8_lossy(&trace.stderr);
    assert!(trace_err.contains("bundled"), "{trace_err}");
    assert!(trace_err.contains("convoy"), "{trace_err}");
    assert_eq!(trace_err, String::from_utf8_lossy(&scenario.stderr));
}

#[test]
fn missing_scenario_listing_matches_experiment_listing_behavior() {
    // Both subcommands answer a missing name the same way: non-zero
    // exit, the available set on stderr.
    let scenario = dtopt(&["scenario"]);
    let experiment = dtopt(&["experiment"]);
    assert!(!scenario.status.success());
    assert!(!experiment.status.success());
    let scenario_err = String::from_utf8_lossy(&scenario.stderr);
    let experiment_err = String::from_utf8_lossy(&experiment.stderr);
    assert!(scenario_err.contains("convoy"), "{scenario_err}");
    assert!(experiment_err.contains("convoy"), "{experiment_err}");
}
