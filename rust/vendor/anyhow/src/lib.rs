//! A minimal, dependency-free stand-in for the `anyhow` crate, vendored
//! so the repository builds with no registry access. It implements the
//! exact subset dtopt uses: [`Error`], [`Result`], the [`Context`]
//! extension trait (on both `Result` and `Option`), and the `anyhow!`,
//! `bail!`, and `ensure!` macros.
//!
//! Error values are stored as a flattened message chain (outermost
//! context first). `{}` displays the outermost message, `{:#}` the full
//! `outer: inner: root` chain — matching the real crate's formatting
//! closely enough for every call site in this repository. Downcasting
//! and backtraces are intentionally not supported.

use std::error::Error as StdError;
use std::fmt;

/// An error wrapper holding a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context message.
    pub fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coexist with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring the real crate.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7u32).context("x").unwrap(), 7);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too large: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("coded {}", 42);
        assert_eq!(e.to_string(), "coded 42");
    }

    #[test]
    fn with_context_on_result_of_error() {
        let e: Result<()> = Err(anyhow!("root"));
        let e = e.with_context(|| format!("layer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "layer 1: root");
    }
}
