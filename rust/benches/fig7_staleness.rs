//! Bench/regenerator for Fig. 7: accuracy vs offline-analysis period
//! (the additive-refresh staleness sweep).

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::fig7;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("fig7: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let (eval_days, periods): (u64, &[u64]) =
        if full { (20, &[1, 2, 5, 10]) } else { (6, &[1, 3]) };
    let start = std::time::Instant::now();
    let result = fig7::run(&world, eval_days, periods);
    let elapsed = start.elapsed();
    println!("== Fig. 7: accuracy vs offline-analysis refresh period ==");
    print!("{}", fig7::render(&result));
    for (desc, ok) in fig7::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");
}
