//! Bench/regenerator for Fig. 6: prediction accuracy vs number of
//! sample transfers for the online-sampling models.

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::fig6;

fn main() {
    let config = config_from_args();
    let mut backend = default_backend();
    eprintln!("fig6: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let start = std::time::Instant::now();
    let result = fig6::run(&world);
    let elapsed = start.elapsed();
    println!("== Fig. 6: prediction accuracy vs sample transfers ==");
    print!("{}", fig6::render(&result));
    for (desc, ok) in fig6::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");
}
