//! Bench/regenerator for the zero-copy ingest bake-off: lazy JSONL
//! field scanning and columnar `.dtc` partitions vs the tree-parsing
//! baseline they replaced, plus the hard cross-format equivalence gate
//! (scanned suff rows and the additively refreshed KB must be
//! byte-identical across JSONL, columnar, and in-memory paths).
//!
//! Quick mode by default (CI smoke runs this; the equivalence gate is
//! the pass/fail signal — timing ratios are advisory, machine load
//! moves them). Set `DTOPT_FULL=1` or pass `--full` for the full-size
//! history.

use dtopt::experiments::ingest;

fn main() {
    let full = std::env::var("DTOPT_FULL").is_ok()
        || std::env::args().any(|a| a == "--full");
    let dir = std::env::temp_dir().join(format!("dtopt_ingest_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let start = std::time::Instant::now();
    let result = ingest::run(!full, &dir).expect("ingest bake-off");
    let elapsed = start.elapsed();
    let _ = std::fs::remove_dir_all(&dir);
    println!("== Zero-copy ingest: scan/columnar vs tree parsing ==");
    print!("{}", ingest::render(&result));
    for (desc, ok) in ingest::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: bake-off {elapsed:.2?}");
}
