//! Bench/regenerator for Fig. 5 (the headline bake-off): one table of
//! achievable throughput per (network × class × period × model).
//! `cargo bench --bench fig5_throughput` (quick) — set DTOPT_FULL=1 for
//! the paper-scale sweep.

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::fig5;

fn main() {
    let config = config_from_args();
    let mut backend = default_backend();
    eprintln!("fig5: preparing world ({} backend, {config:?})...", backend.name());
    let start = std::time::Instant::now();
    let world = World::prepare(config, &mut backend);
    let prep = start.elapsed();
    let run_start = std::time::Instant::now();
    let result = fig5::run(&world, 4);
    let run = run_start.elapsed();
    println!("== Fig. 5: achievable throughput (Gbps) ==");
    print!("{}", fig5::render(&result));
    for (desc, ok) in fig5::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: world prep {prep:.2?}, sweep {run:.2?}");
}
