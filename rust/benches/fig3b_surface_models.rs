//! Bench/regenerator for Fig. 3: (a) the Gaussian throughput spread
//! under identical load; (b) held-out accuracy of the three surface-
//! construction methods (quadratic / cubic / piecewise cubic spline),
//! plus fit-time comparison.

use dtopt::experiments::fig3;
use dtopt::util::timer::bench;

fn main() {
    let full = std::env::var("DTOPT_FULL").is_ok();
    let (reps, test_points) = if full { (4, 512) } else { (2, 128) };

    println!("== Fig. 3a: throughput distribution under identical load ==");
    print!("{}", fig3::render_3a(&fig3::run_3a(if full { 1000 } else { 300 }, 13)));

    println!("\n== Fig. 3b: surface-model held-out accuracy ==");
    let start = std::time::Instant::now();
    let r = fig3::run_3b(reps, test_points, 14);
    let elapsed = start.elapsed();
    print!("{}", fig3::render_3b(&r));
    for (desc, ok) in fig3::headline_checks_3b(&r) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");

    // Fit-cost microbench: the paper argues spline construction is an
    // offline cost; show it is milliseconds.
    let stats = fig3_fit_bench();
    println!("spline surface build: {stats}");
}

fn fig3_fit_bench() -> dtopt::util::timer::BenchStats {
    use dtopt::offline::surface::{SurfaceModel, SurfaceStats};
    use dtopt::sim::dataset::Dataset;
    use dtopt::sim::params::{Params, PP_LEVELS};
    use dtopt::sim::testbed::Testbed;
    use dtopt::sim::transfer::NetState;
    use dtopt::util::rng::Rng;

    let tb = Testbed::xsede();
    let dataset = Dataset::new(100, 64.0);
    let state = NetState::with_load(0.25);
    let mut rng = Rng::new(21);
    let mut stats = SurfaceStats::new();
    for &p in &dtopt::logs::PARAM_KNOTS {
        for &cc in &dtopt::logs::PARAM_KNOTS {
            for &pp in &PP_LEVELS {
                let out =
                    tb.path.transfer(&dataset, &Params::new(cc, p, pp), &state, Some(&mut rng));
                stats.push(p, cc, pp, out.steady_mbps);
            }
        }
    }
    bench(3, 30, || SurfaceModel::build(&stats, 0.25).unwrap())
}
