//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Covers every layer: the simulator's steady-state model (L3 inner
//! loop), spline/bicubic fits and argmax (offline), knowledge-base
//! query + ASM decision (online, the paper's "constant time" claim),
//! k-means assignment native vs PJRT, and the surface-eval artifact
//! native vs PJRT.

use dtopt::experiments::common::{default_backend, ExpConfig, World};
use dtopt::logs::generate::PARAM_KNOTS;
use dtopt::math::bicubic::BicubicSurface;
use dtopt::math::spline::CubicSpline;
use dtopt::offline::kmeans::{AssignBackend, NativeAssign};
use dtopt::offline::knowledge::RequestInfo;
use dtopt::sim::dataset::Dataset;
use dtopt::sim::params::Params;
use dtopt::sim::testbed::Testbed;
use dtopt::sim::transfer::NetState;
use dtopt::util::rng::Rng;
use dtopt::util::timer::bench;

fn main() {
    let mut rng = Rng::new(0xBE);

    // --- L3: simulator steady-state model -------------------------------
    let tb = Testbed::xsede();
    let dataset = Dataset::new(100, 64.0);
    let state = NetState::with_load(0.3);
    let params = Params::new(8, 4, 4);
    let s = bench(100, 20_000, || tb.path.steady_rate_mbps(&dataset, &params, &state));
    println!("sim steady_rate_mbps:        {s}");
    let s = bench(5, 200, || tb.path.optimal(&dataset, &state, 16));
    println!("sim optimal (16×16×6 grid):  {s}");

    // --- math: spline + bicubic -----------------------------------------
    let xs: Vec<f64> = (0..32).map(|i| i as f64).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (x * 0.3).sin() * 50.0 + 100.0).collect();
    let s = bench(10, 5_000, || CubicSpline::fit(&xs, &ys).unwrap());
    println!("cubic spline fit (32 knots): {s}");
    let knots: Vec<f64> = PARAM_KNOTS.iter().map(|&k| k as f64).collect();
    let z: Vec<f64> = (0..64).map(|_| rng.range_f64(0.0, 5000.0)).collect();
    let s = bench(10, 2_000, || BicubicSurface::fit(&knots, &knots, &z).unwrap());
    println!("bicubic fit (8×8 knots):     {s}");
    let surf = BicubicSurface::fit(&knots, &knots, &z).unwrap();
    let s = bench(10, 20_000, || surf.eval(7.3, 9.1));
    println!("bicubic eval:                {s}");
    let s = bench(5, 500, || surf.eval_grid(56, 56));
    println!("bicubic eval_grid 56×56:     {s}");

    // --- offline: k-means assignment, native vs PJRT ---------------------
    let n = 1024;
    let d = 6;
    let k = 8;
    let points: Vec<f64> = (0..n * d).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    let centroids: Vec<f64> = (0..k * d).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    let mut assign = vec![0u32; n];
    let s = bench(5, 500, || {
        NativeAssign.assign(&points, n, d, &centroids, k, &mut assign).unwrap()
    });
    println!("kmeans assign native 1024×6×8:  {s}");
    let mut backend = default_backend();
    #[cfg(feature = "pjrt")]
    {
        use dtopt::runtime::{Backend, PjrtAssign};
        if let Backend::Pjrt(reg) = &mut backend {
            let mut pjrt = PjrtAssign { registry: reg };
            let s =
                bench(3, 100, || pjrt.assign(&points, n, d, &centroids, k, &mut assign).unwrap());
            println!("kmeans assign pjrt   1024×6×8:  {s}");
            let surfaces: Vec<&BicubicSurface> = vec![&surf];
            let s = bench(3, 100, || reg.surface_eval_batch(&surfaces).unwrap());
            println!("surface_eval pjrt (1 surface):  {s}");
            let s = bench(2, 30, || {
                let many: Vec<&BicubicSurface> = (0..64).map(|_| &surf).collect();
                reg.surface_eval_batch(&many).unwrap()
            });
            println!("surface_eval pjrt (64 surfaces): {s}");
        } else {
            println!("kmeans assign pjrt: skipped (artifacts not built)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("kmeans assign pjrt: skipped (built without the `pjrt` feature)");
    let s = bench(2, 50, || surf.eval_grid(56, 56));
    println!("surface_eval native (1 surface, 56×56): {s}");

    // --- online: KB query + full ASM decision ---------------------------
    let world = World::prepare(ExpConfig::quick(), &mut backend);
    let request = RequestInfo {
        rtt_ms: 40.0,
        bandwidth_mbps: 10_000.0,
        tcp_buffer_mb: 48.0,
        disk_mbps: 1_200.0,
        avg_file_mb: 100.0,
        num_files: 200,
    };
    let s = bench(100, 50_000, || world.kb.query(&request).is_some());
    println!("knowledge-base query:        {s}");
    let s = bench(3, 200, || {
        use dtopt::baselines::{Optimizer, TransferEnv};
        let mut env = TransferEnv::new(
            Testbed::xsede(),
            Dataset::new(200, 100.0),
            NetState::with_load(0.3),
            9,
        );
        dtopt::online::asm::AdaptiveSampling::new(&world.kb).run(&mut env)
    });
    println!("ASM full request (sim time excluded is virtual): {s}");
}
