//! Bench/regenerator for the live closed-loop sweep: prediction
//! accuracy of a continuously refreshing KB (ingest → additive refresh
//! → hot swap) versus a frozen snapshot under shifting contention.
//! Companion to `fig7_staleness.rs`, which sweeps the same staleness
//! axis as a batch simulation.

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::live;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("live_refresh: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let eval_days = if full { 12 } else { 4 };
    let dir = std::env::temp_dir()
        .join(format!("dtopt_live_refresh_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let start = std::time::Instant::now();
    let result = live::run(&world, eval_days, &dir).expect("live refresh sweep");
    let elapsed = start.elapsed();
    let _ = std::fs::remove_dir_all(&dir);
    println!("== Live refresh: closed-loop KB vs frozen snapshot ==");
    print!("{}", live::render(&result));
    for (desc, ok) in live::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");
}
