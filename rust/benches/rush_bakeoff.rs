//! Bench/regenerator for the rush-hour bake-off: the shared probe
//! plane (single-flight coalesced sampling, decaying network-state
//! estimates, per-shard probe budgets) versus independent per-request
//! sampling under a synchronized burst of concurrent requests on one
//! network. Companion to `fleet_bakeoff.rs` (which scales the *storage*
//! side of the loop the same way this scales the *probing* side).

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::rush;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("rush_bakeoff: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let (burst, workers) = if full { (64, 8) } else { (24, 6) };
    let start = std::time::Instant::now();
    let result = rush::run(&world, burst, workers);
    let elapsed = start.elapsed();
    println!("== Rush bake-off: shared probe plane vs independent sampling ==");
    print!("{}", rush::render(&result));
    for (desc, ok) in rush::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: burst x2 {elapsed:.2?}");
}
