//! Bench/regenerator for the convoy bake-off: parameter decisions made
//! on the shared-link contention plane (live occupancy folded into
//! every measurement, fair-share stream allowance) versus decisions
//! made against the private-testbed fiction — both cohorts then scored
//! under identical mutual contention by the deterministic fixed-point
//! solver. Companion to `rush_bakeoff.rs` (which shares the *probe*;
//! this shares the *link itself*).

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::convoy;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("convoy_bakeoff: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let (cohort, workers) = if full { (32, 8) } else { (16, 6) };
    let start = std::time::Instant::now();
    let result = convoy::run(&world, cohort, workers);
    let elapsed = start.elapsed();
    println!("== Convoy bake-off: shared-link contention plane vs isolated fiction ==");
    print!("{}", convoy::render(&result));
    for (desc, ok) in convoy::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: cohort x2 {elapsed:.2?}");
}
