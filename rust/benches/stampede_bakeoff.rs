//! Bench/regenerator for the stampede bake-off: the concurrent
//! N-worker runner swept 1→32 over one request population, with the
//! legal-interleaving conformance audits on every point and a strict
//! sequential-match pass against the deterministic oracle. Companion
//! to `rush_bakeoff.rs` (which measures what the probe plane saves
//! under a burst; this measures whether the serve path *scales* when
//! the burst is real OS-thread concurrency).

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::stampede;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("stampede_bakeoff: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    // Full mode clears the 10^5-request bar across the sweep
    // (6 points x 17k); quick keeps CI smoke fast.
    let per_point = if full { 17_000 } else { 200 };
    let start = std::time::Instant::now();
    let result = stampede::run(&world, per_point);
    let elapsed = start.elapsed();
    println!("== Stampede bake-off: N-worker scaling under conformance ==");
    print!("{}", stampede::render(&result));
    for (desc, ok) in stampede::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");
}
