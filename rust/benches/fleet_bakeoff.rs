//! Bench/regenerator for the fleet bake-off: the sharded knowledge
//! fabric (per-network shards, cold-start borrowing, per-shard refresh)
//! versus a single global knowledge base under interleaved traffic from
//! all three networks. Companion to `live_refresh.rs`, which runs the
//! same closed loop through one global snapshot slot.

use dtopt::experiments::common::{config_from_args, default_backend, World};
use dtopt::experiments::fleet;

fn main() {
    let config = config_from_args();
    let full = std::env::var("DTOPT_FULL").is_ok();
    let mut backend = default_backend();
    eprintln!("fleet_bakeoff: preparing world ({} backend)...", backend.name());
    let world = World::prepare(config, &mut backend);
    let eval_days = if full { 8 } else { 3 };
    let dir = std::env::temp_dir()
        .join(format!("dtopt_fleet_bakeoff_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let start = std::time::Instant::now();
    let result = fleet::run(&world, eval_days, &dir).expect("fleet bake-off sweep");
    let elapsed = start.elapsed();
    let _ = std::fs::remove_dir_all(&dir);
    println!("== Fleet bake-off: sharded fabric vs single global KB ==");
    print!("{}", fleet::render(&result));
    for (desc, ok) in fleet::headline_checks(&result) {
        println!("[{}] {desc}", if ok { "ok" } else { "MISS" });
    }
    println!("\ntiming: sweep {elapsed:.2?}");
}
