//! Backend selection: the offline pipeline runs its hot loops either
//! natively (always available, the differential-test reference) or on
//! the PJRT artifacts (the L1/L2 accelerated path, `pjrt` feature).

#[cfg(feature = "pjrt")]
use super::artifacts::{ArtifactRegistry, PjrtAssign};
use crate::offline::kmeans::{AssignBackend, NativeAssign};
use anyhow::Result;
use std::path::Path;

pub enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(Box<ArtifactRegistry>),
}

impl Backend {
    /// Load the PJRT artifacts when present (and compiled in),
    /// otherwise fall back to the native implementation (and say so
    /// once).
    pub fn auto(artifacts_dir: &Path) -> Backend {
        #[cfg(feature = "pjrt")]
        if artifacts_dir.join("manifest.json").exists() {
            match ArtifactRegistry::load(artifacts_dir) {
                Ok(reg) => {
                    return Backend::Pjrt(Box::new(reg));
                }
                Err(e) => {
                    eprintln!("warning: failed to load PJRT artifacts ({e:#}); using native backend");
                }
            }
        }
        #[cfg(not(feature = "pjrt"))]
        if artifacts_dir.join("manifest.json").exists() {
            eprintln!("note: PJRT artifacts found but dtopt was built without the `pjrt` feature; using native backend");
        }
        Backend::Native
    }

    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: &Path) -> Result<Backend> {
        Ok(Backend::Pjrt(Box::new(ArtifactRegistry::load(artifacts_dir)?)))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt(_artifacts_dir: &Path) -> Result<Backend> {
        anyhow::bail!("dtopt was built without the `pjrt` feature; rebuild with --features pjrt")
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Run a closure with the appropriate `AssignBackend`.
    pub fn with_assign<T>(&mut self, f: impl FnOnce(&mut dyn AssignBackend) -> T) -> T {
        match self {
            Backend::Native => f(&mut NativeAssign),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(reg) => f(&mut PjrtAssign { registry: reg }),
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn registry(&self) -> Option<&ArtifactRegistry> {
        match self {
            Backend::Native => None,
            Backend::Pjrt(reg) => Some(reg),
        }
    }
}
