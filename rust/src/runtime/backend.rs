//! Backend selection: the offline pipeline runs its hot loops either
//! natively (always available, the differential-test reference) or on
//! the PJRT artifacts (the L1/L2 accelerated path).

use super::artifacts::{ArtifactRegistry, PjrtAssign};
use crate::offline::kmeans::{AssignBackend, NativeAssign};
use anyhow::Result;
use std::path::Path;

pub enum Backend {
    Native,
    Pjrt(Box<ArtifactRegistry>),
}

impl Backend {
    /// Load the PJRT artifacts when present, otherwise fall back to the
    /// native implementation (and say so once).
    pub fn auto(artifacts_dir: &Path) -> Backend {
        if artifacts_dir.join("manifest.json").exists() {
            match ArtifactRegistry::load(artifacts_dir) {
                Ok(reg) => {
                    return Backend::Pjrt(Box::new(reg));
                }
                Err(e) => {
                    eprintln!("warning: failed to load PJRT artifacts ({e:#}); using native backend");
                }
            }
        }
        Backend::Native
    }

    pub fn pjrt(artifacts_dir: &Path) -> Result<Backend> {
        Ok(Backend::Pjrt(Box::new(ArtifactRegistry::load(artifacts_dir)?)))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Run a closure with the appropriate `AssignBackend`.
    pub fn with_assign<T>(&mut self, f: impl FnOnce(&mut dyn AssignBackend) -> T) -> T {
        match self {
            Backend::Native => f(&mut NativeAssign),
            Backend::Pjrt(reg) => f(&mut PjrtAssign { registry: reg }),
        }
    }

    pub fn registry(&self) -> Option<&ArtifactRegistry> {
        match self {
            Backend::Native => None,
            Backend::Pjrt(reg) => Some(reg),
        }
    }
}
