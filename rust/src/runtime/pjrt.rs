//! PJRT runtime wrapper: load AOT HLO-text artifacts and execute them
//! from the rust hot path. Python never runs here — the artifacts were
//! produced once by `make artifacts` (python/compile/aot.py).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that this xla_extension (0.5.1) rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT CPU client. Creating a TfrtCpuClient is expensive
/// (~100 ms) and the underlying C++ object is thread-safe, so one per
/// process is the right shape.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedArtifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let executable = self
            .client
            .compile(&computation)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedArtifact { executable })
    }
}

/// A compiled executable with f32/i32 convenience I/O.
pub struct LoadedArtifact {
    executable: xla::PjRtLoadedExecutable,
}

/// One input buffer: data + dims.
pub struct InputF32<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

/// One output buffer, dtype-tagged.
#[derive(Debug, Clone)]
pub enum Output {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Output {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Output::F32(v) => Ok(v),
            Output::I32(_) => anyhow::bail!("output is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Output::I32(v) => Ok(v),
            Output::F32(_) => anyhow::bail!("output is f32, expected i32"),
        }
    }
}

impl LoadedArtifact {
    /// Execute with f32 inputs; outputs are the elements of the result
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[InputF32<'_>]) -> Result<Vec<Output>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let expected: i64 = inp.dims.iter().product();
                anyhow::ensure!(
                    expected as usize == inp.data.len(),
                    "input buffer {} elements, dims {:?}",
                    inp.data.len(),
                    inp.dims
                );
                Ok(xla::Literal::vec1(inp.data).reshape(inp.dims)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.executable.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let mut tuple = result.to_tuple()?;
        let mut outputs = Vec::with_capacity(tuple.len());
        for lit in tuple.drain(..) {
            let ty = lit.ty()?;
            match ty {
                xla::ElementType::F32 => outputs.push(Output::F32(lit.to_vec::<f32>()?)),
                xla::ElementType::S32 => outputs.push(Output::I32(lit.to_vec::<i32>()?)),
                other => {
                    // Convert anything else to f32 for uniformity.
                    let conv = lit.convert(xla::PrimitiveType::F32)?;
                    let _ = other;
                    outputs.push(Output::F32(conv.to_vec::<f32>()?));
                }
            }
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Runtime tests require `make artifacts` to have run; skip politely
    /// otherwise so `cargo test` works in a fresh checkout.
    pub fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping PJRT test: artifacts/ not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn loads_and_runs_pairwise_artifact() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = PjrtRuntime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        let art = rt.load_hlo_text(&dir.join("pairwise.hlo.txt")).unwrap();
        // 1024 points at origin except first; 32 centroids at origin.
        let mut points = vec![0.0f32; 1024 * 8];
        points[0] = 3.0;
        points[1] = 4.0;
        let centroids = vec![0.0f32; 32 * 8];
        let outs = art
            .run(&[
                InputF32 { data: &points, dims: &[1024, 8] },
                InputF32 { data: &centroids, dims: &[32, 8] },
            ])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let d2 = outs[0].as_f32().unwrap();
        assert_eq!(d2.len(), 1024 * 32);
        assert!((d2[0] - 25.0).abs() < 1e-4, "d2[0]={}", d2[0]);
        assert!(d2[32].abs() < 1e-6, "origin point distance {}", d2[32]);
    }

    #[test]
    fn rejects_wrong_buffer_shape() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = PjrtRuntime::cpu().unwrap();
        let art = rt.load_hlo_text(&dir.join("pairwise.hlo.txt")).unwrap();
        let bad = art.run(&[InputF32 { data: &[1.0], dims: &[2, 2] }]);
        assert!(bad.is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
