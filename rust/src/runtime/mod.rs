//! PJRT runtime layer: loads the AOT-compiled HLO-text artifacts
//! (python/compile → `artifacts/`) and exposes them to the offline
//! pipeline behind the `Backend` switch. The rust binary is fully
//! self-contained at run time — python is build-time only.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::{ArtifactRegistry, PjrtAssign};
pub use backend::Backend;
pub use pjrt::{InputF32, LoadedArtifact, Output, PjrtRuntime};
