//! PJRT runtime layer: loads the AOT-compiled HLO-text artifacts
//! (python/compile → `artifacts/`) and exposes them to the offline
//! pipeline behind the `Backend` switch. The rust binary is fully
//! self-contained at run time — python is build-time only.
//!
//! The PJRT path needs the `xla` crate and is gated behind the `pjrt`
//! cargo feature so default builds have no registry dependency; with
//! the feature off, [`Backend::auto`] always selects the native
//! reference implementations.

#[cfg(feature = "pjrt")]
pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use artifacts::{ArtifactRegistry, PjrtAssign};
pub use backend::Backend;
#[cfg(feature = "pjrt")]
pub use pjrt::{InputF32, LoadedArtifact, Output, PjrtRuntime};
