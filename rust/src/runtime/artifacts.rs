//! Artifact registry: the manifest-described set of AOT-compiled
//! computations, plus the padding/masking glue that maps arbitrary
//! problem sizes onto the fixed AOT shapes.
//!
//! Fixed shapes (must match python/compile/model.py):
//!   pairwise      (1024, 8) × (32, 8) → (1024, 32)
//!   kmeans_step   + weights (1024,) → centroids (32,8), counts (32),
//!                 inertia (1), assign (1024) i32
//!   surface_eval  (64, 7, 7, 4, 4) → (64, 56, 56)

use super::pjrt::{InputF32, LoadedArtifact, PjrtRuntime};
use crate::math::bicubic::BicubicSurface;
use crate::offline::kmeans::AssignBackend;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

pub const KM_N: usize = 1024;
pub const KM_K: usize = 32;
pub const KM_D: usize = 8;
pub const SURF_S: usize = 64;
pub const SURF_G: usize = 7;
pub const SURF_R: usize = 8;

/// Sentinel coordinate for padded centroids: squared distance ≥ 1e30
/// to any real point, so padding never wins an argmin.
pub const CENTROID_SENTINEL: f32 = 1e15;

/// The loaded artifact set.
pub struct ArtifactRegistry {
    pub runtime: PjrtRuntime,
    pub pairwise: LoadedArtifact,
    pub kmeans_step: LoadedArtifact,
    pub surface_eval: LoadedArtifact,
}

impl ArtifactRegistry {
    /// Load every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(
            manifest.req_str("format").map_err(|e| anyhow::anyhow!("{e}"))? == "hlo-text",
            "unsupported artifact format"
        );
        let arts = manifest
            .get("artifacts")
            .context("manifest missing 'artifacts'")?;
        let file_of = |name: &str| -> Result<std::path::PathBuf> {
            let entry = arts.get(name).with_context(|| format!("manifest missing {name}"))?;
            Ok(dir.join(entry.req_str("file").map_err(|e| anyhow::anyhow!("{e}"))?))
        };
        let runtime = PjrtRuntime::cpu()?;
        let pairwise = runtime.load_hlo_text(&file_of("pairwise")?)?;
        let kmeans_step = runtime.load_hlo_text(&file_of("kmeans_step")?)?;
        let surface_eval = runtime.load_hlo_text(&file_of("surface_eval")?)?;
        Ok(ArtifactRegistry { runtime, pairwise, kmeans_step, surface_eval })
    }

    /// Pairwise squared distances for arbitrary (n, d ≤ 8, k ≤ 32):
    /// pads to the AOT shape, chunks n over batches of 1024.
    pub fn pairwise_dists(
        &self,
        points: &[f64],
        n: usize,
        d: usize,
        centroids: &[f64],
        k: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(d <= KM_D, "d={d} exceeds AOT D={KM_D}");
        anyhow::ensure!(k <= KM_K, "k={k} exceeds AOT K={KM_K}");
        anyhow::ensure!(points.len() == n * d && centroids.len() == k * d, "buffer shapes");
        let mut c_pad = vec![CENTROID_SENTINEL; KM_K * KM_D];
        for c in 0..k {
            for j in 0..d {
                c_pad[c * KM_D + j] = centroids[c * d + j] as f32;
            }
            for j in d..KM_D {
                c_pad[c * KM_D + j] = 0.0;
            }
        }
        let mut out = vec![0f32; n * k];
        let mut p_pad = vec![0f32; KM_N * KM_D];
        let mut start = 0usize;
        while start < n {
            let batch = (n - start).min(KM_N);
            p_pad.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..batch {
                for j in 0..d {
                    p_pad[i * KM_D + j] = points[(start + i) * d + j] as f32;
                }
            }
            let outs = self.pairwise.run(&[
                InputF32 { data: &p_pad, dims: &[KM_N as i64, KM_D as i64] },
                InputF32 { data: &c_pad, dims: &[KM_K as i64, KM_D as i64] },
            ])?;
            let d2 = outs[0].as_f32()?;
            for i in 0..batch {
                for c in 0..k {
                    out[(start + i) * k + c] = d2[i * KM_K + c];
                }
            }
            start += batch;
        }
        Ok(out)
    }

    /// Dense evaluation of up to 64 bicubic surfaces (8×8 knots → 7×7
    /// patches) on the per-patch R×R sub-grid: returns per-surface
    /// row-major (56, 56) grids.
    pub fn surface_eval_batch(&self, surfaces: &[&BicubicSurface]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(surfaces.len() <= SURF_S, "too many surfaces ({})", surfaces.len());
        for s in surfaces {
            anyhow::ensure!(
                s.nx() == SURF_G + 1 && s.ny() == SURF_G + 1,
                "surface must be on the canonical 8×8 knot grid ({}×{})",
                s.nx(),
                s.ny()
            );
        }
        let mut coeffs = vec![0f32; SURF_S * SURF_G * SURF_G * 16];
        for (si, surf) in surfaces.iter().enumerate() {
            // rust layout: patch (i, j) at [(i*(ny-1)+j)*16], power basis
            // over the unit square — exactly the kernel's contract.
            for (ci, &c) in surf.coeffs.iter().enumerate() {
                coeffs[si * SURF_G * SURF_G * 16 + ci] = c as f32;
            }
        }
        // Vandermonde over the half-open local sub-grid t = a/R — a
        // runtime input (HLO text elides array constants; see model.py).
        let mut v = vec![0f32; SURF_R * 4];
        for (a, row) in v.chunks_mut(4).enumerate() {
            let t = a as f32 / SURF_R as f32;
            row[0] = 1.0;
            row[1] = t;
            row[2] = t * t;
            row[3] = t * t * t;
        }
        let outs = self.surface_eval.run(&[
            InputF32 {
                data: &coeffs,
                dims: &[SURF_S as i64, SURF_G as i64, SURF_G as i64, 4, 4],
            },
            InputF32 { data: &v, dims: &[SURF_R as i64, 4] },
        ])?;
        // Raw artifact output is (S, GP, GC, R, R) patch-local values;
        // stitch each surface into a row-major (GP·R, GC·R) grid here
        // (the transpose lives in rust — see python/compile/model.py).
        let raw = outs[0].as_f32()?;
        let side = SURF_G * SURF_R;
        Ok(surfaces
            .iter()
            .enumerate()
            .map(|(si, _)| {
                let mut grid = vec![0f32; side * side];
                for i in 0..SURF_G {
                    for j in 0..SURF_G {
                        let patch_base = (((si * SURF_G) + i) * SURF_G + j) * SURF_R * SURF_R;
                        for a in 0..SURF_R {
                            for b in 0..SURF_R {
                                grid[(i * SURF_R + a) * side + (j * SURF_R + b)] =
                                    raw[patch_base + a * SURF_R + b];
                            }
                        }
                    }
                }
                grid
            })
            .collect())
    }
}

/// k-means assignment backend running on the PJRT pairwise artifact.
pub struct PjrtAssign<'a> {
    pub registry: &'a ArtifactRegistry,
}

impl AssignBackend for PjrtAssign<'_> {
    fn assign(
        &mut self,
        points: &[f64],
        n: usize,
        d: usize,
        centroids: &[f64],
        k: usize,
        assign: &mut [u32],
    ) -> Result<f64> {
        let d2 = self.registry.pairwise_dists(points, n, d, centroids, k)?;
        let mut inertia = 0.0f64;
        for i in 0..n {
            let row = &d2[i * k..(i + 1) * k];
            let (mut bi, mut bv) = (0usize, f32::INFINITY);
            for (c, &v) in row.iter().enumerate() {
                if v < bv {
                    bv = v;
                    bi = c;
                }
            }
            assign[i] = bi as u32;
            inertia += bv as f64;
        }
        Ok(inertia)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
