//! The sharded knowledge fabric — per-network knowledge bases behind
//! one router.
//!
//! The paper's model is network and data agnostic: knowledge is mined
//! per network/dataset class and the online phase picks the matching
//! cluster. One global `KnowledgeBase` snapshot cannot scale that to
//! many endpoint pairs under mixed traffic, so the fabric splits the
//! closed loop by [`ShardKey`] (network × file-size class):
//!
//! ```text
//!            ┌─────────────────────────────────────────────────┐
//! request ──▶│ ShardRouter ── ShardKey ──▶ ShardMap (LRU cap)  │
//!            └──────┬──────────────────────────┬───────────────┘
//!                   │ hit                      │ miss: materialize
//!                   ▼                          ▼
//!            ┌─ Shard ────────────┐   partitions on disk?
//!            │ SnapshotSlot (pin) │   ├─ enough rows → native fit
//!            │ IngestQueue        │   └─ else → borrow nearest
//!            │ RefreshPolicy tick │        native shard's KB
//!            └────────────────────┘        (flagged `borrowed`)
//! ```
//!
//! Each shard owns the full feedback loop in miniature: a hot-swappable
//! [`SnapshotSlot`] workers pin per request, a bounded ingest queue
//! flushing into the shard's own `LogStore` partition directory, and a
//! [`RefreshPolicy`] evaluated against the shard's own drift/volume/
//! period signals. Cold shards are evicted by the map's LRU cap — their
//! queues drain to disk (the spill) and a later request rematerializes
//! them from those partitions, natively if enough rows were spilled.
//!
//! See DESIGN.md §Sharded knowledge fabric for the routing diagram and
//! the shard lifecycle (materialize → native fit → evict).
//!
//! [`SnapshotSlot`]: crate::feedback::SnapshotSlot
//! [`RefreshPolicy`]: crate::feedback::RefreshPolicy

pub mod key;
pub mod map;
pub mod router;
pub mod shard;

pub use key::ShardKey;
pub use map::{ShardMap, ShardMapConfig};
pub use router::{FabricConfig, FabricPollster, FabricStats, Routed, ShardRouter};
pub use shard::{Shard, ShardConfig};
