//! The shard router — the fabric's front door.
//!
//! Resolves each request's [`ShardKey`] to its shard's hot-swappable
//! snapshot slot, materializing missing shards lazily. A brand-new
//! shard with no history of its own *borrows* the nearest existing
//! shard's knowledge base — nearest by the same cluster-centroid
//! distance over `offline::features` that `KnowledgeBase::query`
//! minimizes — and serves it flagged `borrowed` until enough native
//! rows accrue for its own fit (HARP and the two-phase model fall back
//! to similar networks the same way when history is thin).
//!
//! The request path never blocks on refreshes or on other shards'
//! lifecycles (a map hit is a read lock plus atomics), and never fails
//! on fabric trouble: a materialization error degrades to the fallback
//! knowledge base and is retried only after a backoff, exactly like
//! the feedback loop's drop-and-count ingestion ethos. The one request
//! that materializes a new shard does pay the cold-start cost — the KB
//! build, and past the LRU cap the evicted shard's spill — which is a
//! per-shard-lifetime event, not a hot-path one.

use super::key::ShardKey;
use super::map::{ShardMap, ShardMapConfig};
use super::shard::{Shard, ShardConfig};
use crate::feedback::{KbSnapshot, SnapshotSlot};
use crate::offline::knowledge::{KnowledgeBase, RequestInfo};
use crate::sim::testbed::Testbed;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a key whose materialization failed keeps serving the
/// fallback before the expensive build is attempted again (a broken
/// partition directory must not re-run the build per request, nor hog
/// the cold-start lock every other shard's materialization shares).
const MATERIALIZE_RETRY: Duration = Duration::from_secs(5);

/// Fabric configuration: per-shard knobs plus the map's LRU cap.
#[derive(Debug, Clone, Copy, Default)]
pub struct FabricConfig {
    pub shard: ShardConfig,
    pub map: ShardMapConfig,
}

/// Fabric-wide counters (per-shard counters live on each shard's
/// `FeedbackStats`).
#[derive(Debug, Default)]
pub struct FabricStats {
    pub routed: AtomicU64,
    /// Requests served from the fallback KB because materialization
    /// failed or is in its retry backoff (never propagated to the
    /// request path).
    pub route_errors: AtomicU64,
    pub materialized: AtomicU64,
    /// Materializations that had to borrow a donor KB.
    pub borrows: AtomicU64,
    /// Borrowed shards that flipped to their own fitted KB.
    pub native_fits: AtomicU64,
    pub evictions: AtomicU64,
    /// Per-shard tick failures skipped by `tick_all` (the sweep keeps
    /// going; one broken shard never blocks the others' refreshes).
    pub tick_errors: AtomicU64,
}

/// What the router hands the request path.
pub struct Routed {
    pub key: ShardKey,
    /// Pinned for the whole transfer, like the global slot's snapshots.
    pub snapshot: Arc<KbSnapshot>,
    /// The snapshot is a borrowed (donor or fallback) KB, not the
    /// shard's own fit.
    pub borrowed: bool,
    /// `None` only on the degraded fallback path.
    pub shard: Option<Arc<Shard>>,
}

/// The sharded knowledge fabric.
pub struct ShardRouter {
    map: ShardMap,
    /// Borrow source of last resort (and the route-error fallback):
    /// typically the global KB the service booted with.
    fallback: Arc<SnapshotSlot>,
    /// Keys whose last materialization failed, and when — served from
    /// the fallback until [`MATERIALIZE_RETRY`] passes.
    failed: Mutex<HashMap<ShardKey, Instant>>,
    config: FabricConfig,
    pub stats: Arc<FabricStats>,
}

impl ShardRouter {
    /// Open the fabric rooted at `root` (shard partition directories
    /// are created under it on demand).
    pub fn open(root: &Path, fallback: Arc<KnowledgeBase>, config: FabricConfig) -> Result<ShardRouter> {
        std::fs::create_dir_all(root)?;
        Ok(ShardRouter {
            map: ShardMap::new(root, config.map),
            fallback: Arc::new(SnapshotSlot::new(fallback)),
            failed: Mutex::new(HashMap::new()),
            config,
            stats: Arc::new(FabricStats::default()),
        })
    }

    /// Resolve a request's shard, materializing it on first contact.
    /// Infallible by design: fabric trouble degrades to the fallback
    /// KB (flagged borrowed, no shard to ingest into), is counted, and
    /// backs the key off so a broken shard neither re-runs the build
    /// per request nor hogs the shared cold-start lock.
    pub fn route(&self, key: ShardKey) -> Routed {
        self.stats.routed.fetch_add(1, Ordering::Relaxed);
        if let Some(shard) = self.map.get(&key) {
            let (snapshot, borrowed) = shard.resolve();
            return Routed { key, snapshot, borrowed, shard: Some(shard) };
        }
        if self.in_retry_backoff(&key) {
            self.stats.route_errors.fetch_add(1, Ordering::Relaxed);
            return self.fallback_routed(key);
        }
        let made = self.map.get_or_materialize(key, || {
            let shard = Shard::materialize(
                key,
                &self.map.shard_dir(&key),
                || {
                    self.stats.borrows.fetch_add(1, Ordering::Relaxed);
                    self.donor_for(&key)
                },
                self.config.shard,
            )?;
            // Counted only on success, so retries of a broken key never
            // inflate the materialization total.
            self.stats.materialized.fetch_add(1, Ordering::Relaxed);
            Ok(shard)
        });
        match made {
            Ok((shard, evicted)) => {
                if evicted.is_some() {
                    // Already spilled and shut down by the map, under
                    // its materialization lock.
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                }
                self.failed.lock().expect("failed map poisoned").remove(&key);
                let (snapshot, borrowed) = shard.resolve();
                Routed { key, snapshot, borrowed, shard: Some(shard) }
            }
            Err(e) => {
                self.failed.lock().expect("failed map poisoned").insert(key, Instant::now());
                self.stats.route_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "warning: shard {key} unavailable ({e:#}); serving fallback KB for {}s",
                    MATERIALIZE_RETRY.as_secs()
                );
                self.fallback_routed(key)
            }
        }
    }

    fn in_retry_backoff(&self, key: &ShardKey) -> bool {
        match self.failed.lock().expect("failed map poisoned").get(key) {
            Some(at) => at.elapsed() < MATERIALIZE_RETRY,
            None => false,
        }
    }

    fn fallback_routed(&self, key: ShardKey) -> Routed {
        Routed { key, snapshot: self.fallback.resolve(), borrowed: true, shard: None }
    }

    /// Pick the donor KB for a brand-new shard: among live shards
    /// already serving their *own* fit (borrow chains would copy a
    /// copy), the one whose nearest cluster centroid is closest to the
    /// new shard's canonical request features; the fallback KB when no
    /// native shard exists yet.
    fn donor_for(&self, key: &ShardKey) -> (Arc<KnowledgeBase>, Option<ShardKey>) {
        let raw = canonical_request(key).raw_features();
        let mut best: Option<(f64, Arc<KnowledgeBase>, ShardKey)> = None;
        for shard in self.map.live() {
            if shard.key == *key || shard.is_borrowed() {
                continue;
            }
            let (snapshot, _) = shard.resolve();
            let d = snapshot.kb.centroid_distance(&raw);
            if best.as_ref().map_or(true, |(bd, _, _)| d < *bd) {
                best = Some((d, snapshot.kb.clone(), shard.key));
            }
        }
        match best {
            Some((_, kb, donor)) => (kb, Some(donor)),
            None => (self.fallback.resolve().kb.clone(), None),
        }
    }

    /// One refresh sweep over every live shard (what a deployment would
    /// run from a background pollster; experiments and tests drive it
    /// deterministically). A shard whose tick fails is warned about,
    /// counted, and skipped — one broken shard's partitions never block
    /// the rest of the fleet's refreshes. Returns the shards that
    /// published.
    pub fn tick_all(&self) -> Vec<(ShardKey, u64, &'static str)> {
        let mut fired = Vec::new();
        for shard in self.map.live() {
            match shard.tick() {
                Ok(Some((generation, cause))) => {
                    if cause == "native-fit" {
                        self.stats.native_fits.fetch_add(1, Ordering::Relaxed);
                    }
                    fired.push((shard.key, generation, cause));
                }
                Ok(None) => {}
                Err(e) => {
                    self.stats.tick_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warning: shard {} refresh failed: {e:#}", shard.key);
                }
            }
        }
        fired
    }

    /// Block until every shard's ingest queue drains (tests and
    /// deterministic experiments).
    pub fn flush_all(&self, timeout: Duration) -> bool {
        self.map.live().iter().all(|shard| shard.flush_barrier(timeout))
    }

    /// Fault hook: forcibly evict `key`'s shard (the scenario engine's
    /// shard-churn injection). The shard spills its queue to its
    /// partitions and leaves the map; the next route rematerializes it
    /// from that spill — natively when enough rows were banked, via a
    /// fresh borrow otherwise. Counted with the LRU's evictions.
    /// Returns whether a live shard was actually evicted.
    pub fn evict(&self, key: &ShardKey) -> bool {
        match self.map.evict(key) {
            Some(_) => {
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    pub fn live_shards(&self) -> Vec<Arc<Shard>> {
        self.map.live()
    }

    pub fn shard(&self, key: &ShardKey) -> Option<Arc<Shard>> {
        self.map.get(key)
    }

    /// Shut every shard down (spilling their queues); the router stays
    /// usable and would rematerialize on the next route.
    pub fn shutdown(&self) {
        for shard in self.map.drain() {
            shard.shutdown();
        }
    }

    /// Per-shard metrics table + fabric summary line (rendered inside
    /// the coordinator metrics block).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "shard                     state     gen  native_rows  queued  ingested  dropped  refreshes\n",
        );
        for shard in self.map.live() {
            let state = if shard.is_borrowed() {
                match shard.borrowed_from {
                    Some(donor) => format!("borrowed({donor})"),
                    None => "borrowed(fallback)".to_string(),
                }
            } else {
                "native".to_string()
            };
            out.push_str(&format!(
                "{:<25} {:<9} {:>3} {:>12} {:>7} {:>9} {:>8} {:>10}\n",
                shard.key.name(),
                state,
                shard.generation(),
                shard.native_rows(),
                shard.stats.queue_depth.load(Ordering::Relaxed),
                shard.stats.rows_flushed.load(Ordering::Relaxed),
                shard.stats.rows_dropped.load(Ordering::Relaxed),
                shard.stats.refreshes.load(Ordering::Relaxed),
            ));
        }
        out.push_str(&format!(
            "fabric: {} live shards (cap {}), {} materialized, {} borrows, {} native fits, \
             {} evictions, {} routed ({} fallback-served, {} tick errors)\n",
            self.map.len(),
            self.config.map.max_live,
            self.stats.materialized.load(Ordering::Relaxed),
            self.stats.borrows.load(Ordering::Relaxed),
            self.stats.native_fits.load(Ordering::Relaxed),
            self.stats.evictions.load(Ordering::Relaxed),
            self.stats.routed.load(Ordering::Relaxed),
            self.stats.route_errors.load(Ordering::Relaxed),
            self.stats.tick_errors.load(Ordering::Relaxed),
        ));
        out
    }
}

/// Background driver for long-lived deployments: periodically sweeps
/// [`ShardRouter::tick_all`] so borrowed shards fit and native shards
/// refresh without anyone driving the loop by hand — the fabric
/// counterpart of `feedback::Refresher`. Tests and deterministic
/// experiments skip it and call `tick_all` themselves.
pub struct FabricPollster {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl FabricPollster {
    pub fn spawn(router: Arc<ShardRouter>, poll_interval: Duration) -> FabricPollster {
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name("dtopt-fabric".into())
            .spawn(move || {
                while !thread_stop.load(Ordering::Acquire) {
                    // Per-shard failures are already warned about and
                    // counted inside the sweep.
                    let _ = router.tick_all();
                    std::thread::sleep(poll_interval);
                }
            })
            .expect("spawning fabric pollster");
        FabricPollster { stop, handle: Some(handle) }
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }

    pub fn stop(mut self) {
        self.halt();
    }
}

/// RAII guard: a pollster dropped without an explicit `stop` still
/// stops and joins its thread instead of leaking it.
impl Drop for FabricPollster {
    fn drop(&mut self) {
        self.halt();
    }
}

impl std::fmt::Debug for ShardRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardRouter")
            .field("live_shards", &self.map.len())
            .field("config", &self.config)
            .finish()
    }
}

/// Canonical request shape for a key (its network's Table-1 path plus a
/// class-representative dataset) — positions the shard in feature space
/// before it has served anything.
fn canonical_request(key: &ShardKey) -> RequestInfo {
    let testbed = Testbed::by_id(key.network);
    RequestInfo {
        rtt_ms: testbed.path.link.rtt_ms,
        bandwidth_mbps: testbed.path.link.bandwidth_mbps,
        tcp_buffer_mb: testbed.path.src.tcp_buffer_mb.min(testbed.path.dst.tcp_buffer_mb),
        disk_mbps: testbed.path.src.disk_mbps.min(testbed.path.dst.disk_mbps),
        avg_file_mb: key.representative_avg_file_mb(),
        num_files: 100,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::logs::store::LogStore;
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::TestbedId;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_router_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn kb_for(id: TestbedId, seed: u64) -> Arc<KnowledgeBase> {
        let rows = generate(
            &Testbed::by_id(id),
            &GenConfig { days: 3, arrivals_per_hour: 15.0, start_day: 0, seed },
        );
        Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap())
    }

    fn router(dir: &Path, config: FabricConfig) -> ShardRouter {
        ShardRouter::open(dir, kb_for(TestbedId::Xsede, 71), config).unwrap()
    }

    /// Seed a shard's partition directory so it materializes natively.
    fn seed_native(r: &ShardRouter, key: ShardKey, seed: u64) {
        let rows = generate(
            &Testbed::by_id(key.network),
            &GenConfig { days: 3, arrivals_per_hour: 15.0, start_day: 0, seed },
        );
        LogStore::open(r.map.shard_dir(&key)).unwrap().append(&rows).unwrap();
    }

    #[test]
    fn first_contact_borrows_fallback_and_is_flagged() {
        let dir = tmpdir("first");
        let r = router(&dir, FabricConfig::default());
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Small);
        let routed = r.route(key);
        assert_eq!(routed.key, key);
        assert!(routed.borrowed, "no native shard exists; the fallback KB is borrowed");
        let shard = routed.shard.expect("shard materialized");
        assert!(shard.is_borrowed());
        assert_eq!(shard.borrowed_from, None, "fallback borrow has no donor shard");
        assert_eq!(r.stats.borrows.load(Ordering::Relaxed), 1);
        assert_eq!(r.stats.materialized.load(Ordering::Relaxed), 1);
        // Second route reuses the live shard without rematerializing.
        let again = r.route(key);
        assert!(Arc::ptr_eq(&shard, &again.shard.unwrap()));
        assert_eq!(r.stats.materialized.load(Ordering::Relaxed), 1);
        r.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_start_borrows_the_nearest_native_shard() {
        let dir = tmpdir("nearest");
        let config = FabricConfig {
            shard: ShardConfig { min_native_rows: 10, ..Default::default() },
            ..Default::default()
        };
        let r = router(&dir, config);
        // Two native shards on very different networks.
        let xsede = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
        let didclab = ShardKey::new(TestbedId::Didclab, SizeClass::Small);
        seed_native(&r, xsede, 72);
        seed_native(&r, didclab, 73);
        assert!(!r.route(xsede).borrowed);
        assert!(!r.route(didclab).borrowed);
        // A new didclab/medium shard must borrow from the didclab
        // shard, not the 10 Gbps / 40 ms xsede one.
        let newcomer = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        let routed = r.route(newcomer);
        assert!(routed.borrowed);
        assert_eq!(routed.shard.unwrap().borrowed_from, Some(didclab));
        r.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tick_all_flips_borrowed_shards_and_counts_fits() {
        let dir = tmpdir("fits");
        let config = FabricConfig {
            shard: ShardConfig { min_native_rows: 20, ..Default::default() },
            ..Default::default()
        };
        let r = router(&dir, config);
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        let routed = r.route(key);
        assert!(routed.borrowed);
        let shard = routed.shard.unwrap();
        for row in generate(
            &Testbed::didclab(),
            &GenConfig { days: 1, arrivals_per_hour: 15.0, start_day: 0, seed: 74 },
        ) {
            shard.offer(row);
        }
        assert!(r.flush_all(Duration::from_secs(30)));
        let fired = r.tick_all();
        assert_eq!(fired, vec![(key, 1, "native-fit")]);
        assert_eq!(r.stats.native_fits.load(Ordering::Relaxed), 1);
        assert!(!r.route(key).borrowed);
        let table = r.render();
        assert!(table.contains("didclab/medium"), "{table}");
        assert!(table.contains("native"), "{table}");
        assert!(table.contains("1 native fits"), "{table}");
        r.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forced_eviction_spills_and_rematerializes_natively() {
        let dir = tmpdir("evict");
        let config = FabricConfig {
            shard: ShardConfig { min_native_rows: 25, ..Default::default() },
            ..Default::default()
        };
        let r = router(&dir, config);
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        let shard = r.route(key).shard.unwrap();
        assert!(shard.is_borrowed());
        for row in generate(
            &Testbed::didclab(),
            &GenConfig { days: 1, arrivals_per_hour: 10.0, start_day: 0, seed: 81 },
        )
        .into_iter()
        .take(30)
        {
            shard.offer(row);
        }
        assert!(r.flush_all(Duration::from_secs(30)));
        assert!(r.evict(&key), "live shard evicts");
        assert!(!r.evict(&key), "double eviction is a no-op");
        assert_eq!(r.stats.evictions.load(Ordering::Relaxed), 1);
        assert!(r.shard(&key).is_none(), "evicted shard left the map");
        // The spill banked >= min_native_rows rows, so the next route
        // rematerializes the shard natively from its own partitions.
        let again = r.route(key);
        assert!(!again.borrowed, "rematerializes natively from the spill");
        assert_eq!(again.shard.unwrap().native_rows(), 30);
        r.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pollster_flips_borrowed_shards_in_background() {
        let dir = tmpdir("pollster");
        let config = FabricConfig {
            shard: ShardConfig { min_native_rows: 20, ..Default::default() },
            ..Default::default()
        };
        let r = Arc::new(router(&dir, config));
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        let shard = r.route(key).shard.unwrap();
        assert!(shard.is_borrowed());
        let pollster = FabricPollster::spawn(r.clone(), Duration::from_millis(5));
        for row in generate(
            &Testbed::didclab(),
            &GenConfig { days: 1, arrivals_per_hour: 15.0, start_day: 0, seed: 76 },
        ) {
            shard.offer(row);
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        while shard.is_borrowed() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!shard.is_borrowed(), "pollster never fit the shard natively");
        assert!(shard.generation() >= 1);
        assert_eq!(r.stats.native_fits.load(Ordering::Relaxed), 1);
        pollster.stop();
        r.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
