//! Shard identity: which slice of the knowledge fabric owns a request.
//!
//! The paper's model is network and data agnostic — knowledge is mined
//! per network/dataset class and the online phase picks the matching
//! cluster. The fabric makes that split physical: one shard per
//! (network, file-size class) pair, so each endpoint pair's knowledge
//! base refreshes on its own traffic and its own schedule.

use crate::logs::record::TransferLog;
use crate::sim::dataset::{Dataset, SizeClass};
use crate::sim::testbed::TestbedId;

/// Identity of one knowledge shard: a network (testbed/endpoint pair)
/// crossed with a dataset size class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShardKey {
    pub network: TestbedId,
    pub class: SizeClass,
}

impl ShardKey {
    pub fn new(network: TestbedId, class: SizeClass) -> ShardKey {
        ShardKey { network, class }
    }

    /// The shard a transfer request routes to.
    pub fn of_request(network: TestbedId, dataset: &Dataset) -> ShardKey {
        ShardKey { network, class: dataset.class() }
    }

    /// The shard a completed log row belongs to; `None` when the row's
    /// endpoint pair is not a known network.
    pub fn of_log(row: &TransferLog) -> Option<ShardKey> {
        TestbedId::parse(&row.pair)
            .map(|network| ShardKey { network, class: SizeClass::classify(row.avg_file_mb) })
    }

    /// Every possible key over the known networks and classes.
    pub fn all() -> Vec<ShardKey> {
        let mut keys = Vec::with_capacity(9);
        for network in TestbedId::all() {
            for class in SizeClass::all() {
                keys.push(ShardKey { network, class });
            }
        }
        keys
    }

    /// Human-readable name, e.g. `xsede/large`.
    pub fn name(&self) -> String {
        format!("{}/{}", self.network.name(), self.class.name())
    }

    pub fn parse(s: &str) -> Option<ShardKey> {
        let (net, class) = s.split_once('/')?;
        let network = TestbedId::parse(net)?;
        let class = SizeClass::all().into_iter().find(|c| c.name() == class)?;
        Some(ShardKey { network, class })
    }

    /// Filesystem-safe directory name for the shard's log partitions,
    /// e.g. `xsede__large` (slashes would nest directories).
    pub fn dir_name(&self) -> String {
        format!("{}__{}", self.network.name(), self.class.name())
    }

    /// A representative average file size for the class (the lognormal
    /// location `sim::dataset` samples around) — used to position a
    /// brand-new shard in feature space for cold-start borrowing.
    pub fn representative_avg_file_mb(&self) -> f64 {
        self.class.location_mb()
    }
}

impl std::fmt::Display for ShardKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    #[test]
    fn covers_every_network_class_pair() {
        let keys = ShardKey::all();
        assert_eq!(keys.len(), 9);
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 9);
    }

    #[test]
    fn name_parse_roundtrip() {
        for key in ShardKey::all() {
            assert_eq!(ShardKey::parse(&key.name()), Some(key));
        }
        assert_eq!(ShardKey::parse("xsede"), None);
        assert_eq!(ShardKey::parse("nope/large"), None);
        assert_eq!(ShardKey::parse("xsede/huge"), None);
    }

    #[test]
    fn request_and_log_agree() {
        let mut row = sample_log(); // pair "xsede", avg_file_mb 128 ⇒ large
        let from_log = ShardKey::of_log(&row).unwrap();
        let from_req =
            ShardKey::of_request(TestbedId::Xsede, &Dataset::new(row.num_files, row.avg_file_mb));
        assert_eq!(from_log, from_req);
        assert_eq!(from_log.class, SizeClass::Large);
        row.pair = "not-a-testbed".into();
        assert_eq!(ShardKey::of_log(&row), None);
    }

    #[test]
    fn dir_names_are_distinct_and_slash_free() {
        let mut dirs: Vec<String> = ShardKey::all().iter().map(|k| k.dir_name()).collect();
        assert!(dirs.iter().all(|d| !d.contains('/')));
        dirs.sort();
        dirs.dedup();
        assert_eq!(dirs.len(), 9);
    }
}
