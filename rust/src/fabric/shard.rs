//! One shard of the knowledge fabric: a hot-swappable KB snapshot, a
//! bounded ingest queue flushing into the shard's own log partitions,
//! and a refresh loop that runs on the shard's own signals.
//!
//! Lifecycle (see DESIGN.md §Sharded knowledge fabric):
//!
//! * **materialize** — lazily, on the first request for the key. If the
//!   shard's log partitions already hold enough rows (a previous life
//!   before eviction), the shard fits its own KB immediately; otherwise
//!   it *borrows* the nearest existing shard's KB, flagged `borrowed`.
//! * **native fit** — once enough native rows accrue, the shard builds
//!   its own knowledge base from its partitions and publishes it as the
//!   next snapshot generation; `borrowed` flips off. From then on the
//!   per-shard [`RefreshPolicy`] drives additive refreshes exactly like
//!   the global feedback loop, but over this shard's traffic only.
//! * **evict** — a cold shard is shut down by the [`ShardMap`] LRU: the
//!   ingest queue drains into the partitions (the spill), the in-memory
//!   KB is dropped, and a later request rematerializes from disk.
//!
//! [`ShardMap`]: super::map::ShardMap

use super::key::ShardKey;
use crate::feedback::ingest::{self, IngestWorker};
use crate::feedback::refresher::RefreshEngine;
use crate::feedback::{FeedbackStats, IngestConfig, IngestQueue, KbSnapshot, RefreshPolicy, SnapshotSlot};
use crate::logs::record::TransferLog;
use crate::logs::store::LogStore;
use crate::offline::kmeans::NativeAssign;
use crate::offline::knowledge::KnowledgeBase;
use crate::offline::pipeline::{build, OfflineConfig};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-shard tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ShardConfig {
    pub ingest: IngestConfig,
    /// Refresh triggers evaluated per shard — each network's KB
    /// refreshes on its own drift/volume/period signals.
    pub policy: RefreshPolicy,
    /// Native rows a borrowed shard must accrue before it fits its own
    /// knowledge base and stops serving the donor's.
    pub min_native_rows: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            ingest: IngestConfig::default(),
            policy: RefreshPolicy::default(),
            min_native_rows: 200,
        }
    }
}

/// One live shard. Workers pin a snapshot per request via [`Shard::resolve`]
/// and never block on refreshes; refreshes publish into the shard's
/// private [`SnapshotSlot`].
pub struct Shard {
    pub key: ShardKey,
    pub slot: Arc<SnapshotSlot>,
    pub stats: Arc<FeedbackStats>,
    /// The donor this shard borrowed from at materialization (`None`
    /// when it fit natively from its own partitions right away).
    pub borrowed_from: Option<ShardKey>,
    store: Arc<LogStore>,
    config: ShardConfig,
    /// Serving a borrowed KB until the native fit (lock-free mirror of
    /// `engine.is_none()` for the request path).
    borrowed: AtomicBool,
    /// Rows already in the partitions at materialization (count toward
    /// the native-fit threshold alongside freshly flushed rows).
    initial_rows: u64,
    queue: Mutex<Option<IngestQueue>>,
    worker: Mutex<Option<IngestWorker>>,
    closing: Arc<AtomicBool>,
    /// The shard's own additive-refresh engine (the same machinery the
    /// global feedback service runs) — `None` while the shard still
    /// serves a borrowed KB, created by the native fit.
    engine: Mutex<Option<RefreshEngine>>,
    /// Logical LRU timestamp maintained by the shard map.
    pub(crate) last_used: AtomicU64,
}

/// Read every partition, remembering per-day lengths so the cursor can
/// be set to exactly what was read (no refresh/ingest race).
fn read_all_with_cursor(store: &LogStore) -> Result<(Vec<TransferLog>, BTreeMap<u64, usize>)> {
    let mut rows = Vec::new();
    let mut cursor = BTreeMap::new();
    for day in store.days()? {
        let day_rows = store.read_day(day)?;
        cursor.insert(day, day_rows.len());
        rows.extend(day_rows);
    }
    Ok((rows, cursor))
}

impl Shard {
    /// Materialize the shard for `key` at `dir` (its private log-store
    /// partition directory). If the partitions already hold at least
    /// `min_native_rows` rows — a previous life before eviction — the
    /// shard fits its own KB immediately; otherwise `donor` is consulted
    /// once for a KB to borrow until enough native rows accrue.
    pub(crate) fn materialize(
        key: ShardKey,
        dir: &Path,
        donor: impl FnOnce() -> (Arc<KnowledgeBase>, Option<ShardKey>),
        config: ShardConfig,
    ) -> Result<Shard> {
        let store = Arc::new(LogStore::open(dir)?);
        let (existing, cursor) = read_all_with_cursor(&store)?;
        let initial_rows = existing.len() as u64;
        let (kb, borrowed, borrowed_from) = if initial_rows >= config.min_native_rows.max(1) {
            let kb = build(&existing, &OfflineConfig::default(), &mut NativeAssign)?;
            (Arc::new(kb), false, None)
        } else {
            let (donor_kb, donor_key) = donor();
            (donor_kb, true, donor_key)
        };
        let slot = Arc::new(SnapshotSlot::new(kb));
        let stats = Arc::new(FeedbackStats::default());
        let closing = Arc::new(AtomicBool::new(false));
        let (queue, worker) =
            ingest::spawn(store.clone(), stats.clone(), closing.clone(), config.ingest);
        // A native shard refreshes through the same engine the global
        // feedback service runs, with the cursor set to exactly the
        // rows its KB was just built from.
        let engine = if borrowed {
            None
        } else {
            Some(RefreshEngine::with_cursor(
                slot.clone(),
                store.clone(),
                stats.clone(),
                config.policy,
                cursor,
            ))
        };
        Ok(Shard {
            key,
            slot,
            stats,
            borrowed_from,
            store,
            config,
            borrowed: AtomicBool::new(borrowed),
            initial_rows,
            queue: Mutex::new(Some(queue)),
            worker: Mutex::new(Some(worker)),
            closing,
            engine: Mutex::new(engine),
            last_used: AtomicU64::new(0),
        })
    }

    /// Pin the shard's current snapshot plus its borrow status. The
    /// flag is read *before* the snapshot: observing `borrowed ==
    /// false` means the native fit's publish happened-before the flag's
    /// Release store, so the snapshot read next is the native KB — a
    /// request can never claim `borrowed = false` while actually
    /// holding the donor's KB. (The opposite race — a freshly published
    /// native KB still labeled borrowed for an instant — is the
    /// conservative direction and allowed.)
    pub fn resolve(&self) -> (Arc<KbSnapshot>, bool) {
        let borrowed = self.borrowed.load(Ordering::Acquire);
        (self.slot.resolve(), borrowed)
    }

    pub fn is_borrowed(&self) -> bool {
        self.borrowed.load(Ordering::Acquire)
    }

    pub fn generation(&self) -> u64 {
        self.slot.generation()
    }

    /// Rows of this shard's own traffic: what the partitions held at
    /// materialization plus everything flushed since.
    pub fn native_rows(&self) -> u64 {
        self.initial_rows + self.stats.rows_flushed.load(Ordering::Acquire)
    }

    /// The shard store's ingest counters (rows/bytes written, scanned,
    /// parsed) since materialization — the fabric metrics collector
    /// sums these over live shards into the `logs.ingest.*` families.
    pub fn ingest_stats(&self) -> Arc<crate::logs::store::IngestStats> {
        self.store.stats()
    }

    /// Offer one completed-transfer row to the shard's ingest queue.
    /// Non-blocking; after shutdown (eviction) the row is dropped and
    /// counted, same as a full queue.
    pub fn offer(&self, row: TransferLog) -> bool {
        match &*self.queue.lock().expect("shard queue poisoned") {
            Some(queue) => queue.offer(row),
            None => {
                self.stats.rows_dropped.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// One refresh evaluation. A borrowed shard checks the native-fit
    /// threshold (the borrowed KB itself stays frozen at the donor's
    /// version); a native shard delegates to its [`RefreshEngine`] —
    /// the same policy-driven additive refresh the global feedback
    /// service runs, over this shard's partitions only. Returns the
    /// published generation and the cause when something fired.
    pub fn tick(&self) -> Result<Option<(u64, &'static str)>> {
        let mut engine = self.engine.lock().expect("shard engine poisoned");
        if let Some(native) = engine.as_ref() {
            return Ok(native.tick()?.map(|(generation, reason)| (generation, reason.name())));
        }
        if self.native_rows() >= self.config.min_native_rows.max(1) {
            let generation = self.fit_native(&mut *engine)?;
            return Ok(Some((generation, "native-fit")));
        }
        Ok(None)
    }

    /// Build the shard's own KB from everything in its partitions,
    /// publish it, and install the refresh engine; the shard stops
    /// serving the donor's knowledge.
    fn fit_native(&self, engine: &mut Option<RefreshEngine>) -> Result<u64> {
        let started = Instant::now();
        let (rows, cursor) = read_all_with_cursor(&self.store)?;
        anyhow::ensure!(!rows.is_empty(), "shard {}: native fit with empty store", self.key);
        let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign)?;
        let generation = self.slot.publish(Arc::new(kb));
        // The engine's cursor is exactly the rows just fitted, so later
        // ticks fold in only what arrives afterwards.
        *engine = Some(RefreshEngine::with_cursor(
            self.slot.clone(),
            self.store.clone(),
            self.stats.clone(),
            self.config.policy,
            cursor,
        ));
        // Publish-then-flip, paired with resolve()'s flag-then-snapshot
        // read order: whoever observes the cleared flag also observes
        // the already-published native KB — never
        // native-claimed-but-borrowed.
        self.borrowed.store(false, Ordering::Release);
        let refresh_ns = started.elapsed().as_nanos() as u64;
        self.stats.refreshes.fetch_add(1, Ordering::Relaxed);
        self.stats.rows_consumed.fetch_add(rows.len() as u64, Ordering::Relaxed);
        self.stats.last_refresh_ns.store(refresh_ns, Ordering::Relaxed);
        self.stats.total_refresh_ns.fetch_add(refresh_ns, Ordering::Relaxed);
        self.stats.kb_generation.store(generation, Ordering::Release);
        Ok(generation)
    }

    /// Block until every row offered so far is flushed or dropped (or
    /// the timeout passes). For tests and deterministic experiments.
    pub fn flush_barrier(&self, timeout: std::time::Duration) -> bool {
        self.stats.flush_barrier(timeout)
    }

    /// Shut the shard down (eviction spill): close and drop the ingest
    /// queue so the flusher drains every buffered row into the
    /// partitions, then join it. Idempotent; later `offer`s drop and
    /// count. In-flight requests keep serving their pinned snapshots.
    pub(crate) fn shutdown(&self) {
        self.closing.store(true, Ordering::Release);
        drop(self.queue.lock().expect("shard queue poisoned").take());
        if let Some(worker) = self.worker.lock().expect("shard worker poisoned").take() {
            worker.join();
        }
    }
}

impl std::fmt::Debug for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Shard")
            .field("key", &self.key)
            .field("generation", &self.generation())
            .field("borrowed", &self.is_borrowed())
            .field("native_rows", &self.native_rows())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::{Testbed, TestbedId};
    use std::path::PathBuf;
    use std::time::Duration;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_shard_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn rows(testbed: &Testbed, days: u64, seed: u64) -> Vec<TransferLog> {
        generate(testbed, &GenConfig { days, arrivals_per_hour: 15.0, start_day: 0, seed })
    }

    fn quick_config(min_native_rows: u64) -> ShardConfig {
        ShardConfig {
            ingest: IngestConfig {
                capacity: 1024,
                flush_batch: 8,
                flush_interval: Duration::from_millis(2),
            },
            policy: RefreshPolicy {
                min_new_rows: 1,
                min_interval: Duration::ZERO,
                ..Default::default()
            },
            min_native_rows,
        }
    }

    #[test]
    fn preseeded_store_fits_natively_without_a_donor() {
        let dir = tmpdir("native");
        let history = rows(&Testbed::xsede(), 3, 41);
        LogStore::open(&dir).unwrap().append(&history).unwrap();
        let key = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
        let shard = Shard::materialize(
            key,
            &dir,
            || panic!("donor must not be consulted when the store has enough rows"),
            quick_config(10),
        )
        .unwrap();
        assert!(!shard.is_borrowed());
        assert_eq!(shard.generation(), 0);
        assert_eq!(shard.native_rows(), history.len() as u64);
        let (snapshot, borrowed) = shard.resolve();
        assert!(!borrowed);
        assert!(!snapshot.kb.clusters.is_empty());
        // Nothing new ⇒ no refresh fires.
        assert_eq!(shard.tick().unwrap(), None);
        shard.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn borrowed_shard_accrues_rows_then_fits_natively() {
        let dir = tmpdir("borrow");
        let donor_kb = {
            let h = rows(&Testbed::xsede(), 3, 43);
            Arc::new(build(&h, &OfflineConfig::default(), &mut NativeAssign).unwrap())
        };
        let donor_key = ShardKey::new(TestbedId::Xsede, SizeClass::Large);
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        let shard =
            Shard::materialize(key, &dir, || (donor_kb.clone(), Some(donor_key)), quick_config(30))
                .unwrap();
        assert!(shard.is_borrowed());
        assert_eq!(shard.borrowed_from, Some(donor_key));
        assert_eq!(shard.generation(), 0);
        // Below the threshold: the borrowed KB stays frozen.
        let native = rows(&Testbed::didclab(), 2, 44);
        assert!(native.len() > 40, "need enough traffic for the fit ({})", native.len());
        for row in native.iter().take(10).cloned() {
            assert!(shard.offer(row));
        }
        assert!(shard.flush_barrier(Duration::from_secs(30)));
        assert_eq!(shard.tick().unwrap(), None);
        assert!(shard.is_borrowed());
        // Threshold reached: the shard fits its own KB and flips.
        for row in native.iter().skip(10).cloned() {
            shard.offer(row);
        }
        assert!(shard.flush_barrier(Duration::from_secs(30)));
        assert_eq!(shard.tick().unwrap(), Some((1, "native-fit")));
        assert!(!shard.is_borrowed());
        let (snapshot, borrowed) = shard.resolve();
        assert!(!borrowed);
        assert_eq!(snapshot.generation, 1);
        let fitted_rows: u64 = snapshot.kb.clusters.iter().map(|c| c.n_rows).sum();
        assert_eq!(fitted_rows, shard.native_rows(), "fit consumed exactly the native rows");
        // From here on, the per-shard policy drives additive refreshes.
        for row in rows(&Testbed::didclab(), 1, 45) {
            shard.offer(row);
        }
        assert!(shard.flush_barrier(Duration::from_secs(30)));
        assert_eq!(shard.tick().unwrap(), Some((2, "row-threshold")));
        shard.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shutdown_spills_queue_and_later_offers_drop() {
        let dir = tmpdir("spill");
        let donor_kb = {
            let h = rows(&Testbed::xsede(), 2, 47);
            Arc::new(build(&h, &OfflineConfig::default(), &mut NativeAssign).unwrap())
        };
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Small);
        let shard =
            Shard::materialize(key, &dir, || (donor_kb, None), quick_config(1_000_000)).unwrap();
        let native = rows(&Testbed::didclab(), 1, 48);
        let offered = native.len() as u64;
        for row in native {
            assert!(shard.offer(row));
        }
        shard.shutdown();
        // Every offered row reached the partitions (the eviction spill).
        assert_eq!(shard.stats.rows_flushed.load(Ordering::Relaxed), offered);
        assert_eq!(LogStore::open(&dir).unwrap().read_all().unwrap().len() as u64, offered);
        // Post-shutdown offers never block; they drop and count.
        let dropped_before = shard.stats.rows_dropped.load(Ordering::Relaxed);
        assert!(!shard.offer(crate::logs::record::tests::sample_log()));
        assert_eq!(shard.stats.rows_dropped.load(Ordering::Relaxed), dropped_before + 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
