//! The shard map: lazy shard materialization bookkeeping plus an LRU
//! cap that evicts cold shards.
//!
//! The map itself never builds knowledge — the [`ShardRouter`] decides
//! how a missing shard gets seeded (native fit vs cold-start borrow)
//! and passes the recipe to [`ShardMap::get_or_materialize`]. Eviction
//! selects the coldest shard and shuts it down under the
//! materialization lock: its ingest queue drains into its log
//! partitions (the spill) before the same key could possibly
//! rematerialize from that directory.
//!
//! [`ShardRouter`]: super::router::ShardRouter

use super::key::ShardKey;
use super::shard::Shard;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Map tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ShardMapConfig {
    /// Maximum shards held in memory; inserting beyond it evicts the
    /// least-recently-used shard. A full KB per shard is the expensive
    /// part of the fabric, so this is the fabric's memory ceiling.
    pub max_live: usize,
}

impl Default for ShardMapConfig {
    fn default() -> Self {
        ShardMapConfig { max_live: 64 }
    }
}

/// Live shards keyed by [`ShardKey`], with LRU accounting.
pub struct ShardMap {
    root: PathBuf,
    shards: RwLock<HashMap<ShardKey, Arc<Shard>>>,
    /// Logical clock stamped into `Shard::last_used` on every hit.
    clock: AtomicU64,
    /// Serializes cold-start materializations so concurrent requests
    /// for the same missing key build its KB once, not once per worker.
    materialize_lock: Mutex<()>,
    config: ShardMapConfig,
}

impl ShardMap {
    pub fn new(root: &Path, config: ShardMapConfig) -> ShardMap {
        ShardMap {
            root: root.to_path_buf(),
            shards: RwLock::new(HashMap::new()),
            clock: AtomicU64::new(1),
            materialize_lock: Mutex::new(()),
            config,
        }
    }

    /// The shard's private log-partition directory under the fabric
    /// root (this is where evicted shards spill to and rematerialize
    /// from).
    pub fn shard_dir(&self, key: &ShardKey) -> PathBuf {
        self.root.join(key.dir_name())
    }

    fn touch(&self, shard: &Shard) {
        shard.last_used.store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Look up a live shard, refreshing its LRU stamp.
    pub fn get(&self, key: &ShardKey) -> Option<Arc<Shard>> {
        let shards = self.shards.read().expect("shard map poisoned");
        let shard = shards.get(key)?.clone();
        self.touch(&shard);
        Some(shard)
    }

    /// Look up a live shard, materializing it with `make` on a miss.
    /// `make` runs outside the map lock but under a dedicated
    /// materialization mutex, so the request path of *other* shards
    /// never stalls behind a cold-start KB build and the same key is
    /// never built twice. When the LRU cap forces a shard out, it is
    /// shut down here — its queue spilled to its partitions and its
    /// flusher joined — *before* the materialization lock is released,
    /// so a rematerialization of the same key can never race the spill
    /// (two flushers appending to one partition directory, or a
    /// half-written tail read back mid-build). The evicted shard is
    /// returned for the caller's accounting.
    pub fn get_or_materialize(
        &self,
        key: ShardKey,
        make: impl FnOnce() -> anyhow::Result<Shard>,
    ) -> anyhow::Result<(Arc<Shard>, Option<Arc<Shard>>)> {
        if let Some(shard) = self.get(&key) {
            return Ok((shard, None));
        }
        let _guard = self.materialize_lock.lock().expect("materialize lock poisoned");
        // Double-check: another request may have materialized it while
        // we waited for the lock.
        if let Some(shard) = self.get(&key) {
            return Ok((shard, None));
        }
        let shard = Arc::new(make()?);
        let evicted = {
            let mut shards = self.shards.write().expect("shard map poisoned");
            let evicted = if shards.len() >= self.config.max_live.max(1) {
                let coldest = shards
                    .iter()
                    .min_by_key(|(_, s)| s.last_used.load(Ordering::Relaxed))
                    .map(|(k, _)| *k);
                coldest.and_then(|k| shards.remove(&k))
            } else {
                None
            };
            self.touch(&shard);
            shards.insert(key, shard.clone());
            evicted
        };
        // Spill outside the map lock (other lookups proceed) but inside
        // the materialization lock (the evicted key cannot come back
        // until its partitions are quiescent).
        if let Some(cold) = &evicted {
            cold.shutdown();
        }
        Ok((shard, evicted))
    }

    /// Forcibly evict one shard (fault injection — the scenario
    /// engine's shard-churn events; the LRU cap evicts organically).
    /// The shard is removed from the map and shut down — its queue
    /// spilled to its partitions — under the materialization lock, so a
    /// concurrent rematerialization of the same key can never race the
    /// spill. Returns the evicted shard, `None` when the key was not
    /// live.
    pub fn evict(&self, key: &ShardKey) -> Option<Arc<Shard>> {
        let _guard = self.materialize_lock.lock().expect("materialize lock poisoned");
        let shard = self.shards.write().expect("shard map poisoned").remove(key);
        if let Some(cold) = &shard {
            cold.shutdown();
        }
        shard
    }

    /// Snapshot of every live shard (metrics, tick sweeps), sorted by
    /// key for stable rendering.
    pub fn live(&self) -> Vec<Arc<Shard>> {
        let mut shards: Vec<Arc<Shard>> =
            self.shards.read().expect("shard map poisoned").values().cloned().collect();
        shards.sort_by_key(|s| s.key);
        shards
    }

    pub fn len(&self) -> usize {
        self.shards.read().expect("shard map poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove every shard (fabric shutdown); the caller shuts each down.
    pub fn drain(&self) -> Vec<Arc<Shard>> {
        self.shards.write().expect("shard map poisoned").drain().map(|(_, s)| s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::shard::ShardConfig;
    use crate::logs::generate::{generate, GenConfig};
    use crate::logs::store::LogStore;
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::{Testbed, TestbedId};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_shardmap_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn donor_kb(seed: u64) -> Arc<crate::offline::knowledge::KnowledgeBase> {
        let rows = generate(
            &Testbed::xsede(),
            &GenConfig { days: 2, arrivals_per_hour: 15.0, start_day: 0, seed },
        );
        Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap())
    }

    fn materialize(map: &ShardMap, key: ShardKey, kb: &Arc<crate::offline::knowledge::KnowledgeBase>) -> (Arc<Shard>, Option<Arc<Shard>>) {
        let kb = kb.clone();
        map.get_or_materialize(key, || {
            Shard::materialize(key, &map.shard_dir(&key), || (kb, None), ShardConfig::default())
        })
        .unwrap()
    }

    #[test]
    fn materializes_lazily_and_reuses() {
        let dir = tmpdir("lazy");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 8 });
        let kb = donor_kb(61);
        assert!(map.is_empty());
        let key = ShardKey::new(TestbedId::Xsede, SizeClass::Small);
        let (a, evicted) = materialize(&map, key, &kb);
        assert!(evicted.is_none());
        assert_eq!(map.len(), 1);
        let (b, _) = materialize(&map, key, &kb);
        assert!(Arc::ptr_eq(&a, &b), "second lookup reuses the live shard");
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_cap_evicts_the_coldest_shard() {
        let dir = tmpdir("lru");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 2 });
        let kb = donor_kb(62);
        let k1 = ShardKey::new(TestbedId::Xsede, SizeClass::Small);
        let k2 = ShardKey::new(TestbedId::Didclab, SizeClass::Small);
        let k3 = ShardKey::new(TestbedId::DidclabToXsede, SizeClass::Small);
        materialize(&map, k1, &kb);
        materialize(&map, k2, &kb);
        // Touch k1 so k2 is the coldest.
        assert!(map.get(&k1).is_some());
        let (_, evicted) = materialize(&map, k3, &kb);
        let evicted = evicted.expect("cap of 2 must evict on the third insert");
        assert_eq!(evicted.key, k2);
        // Already shut down by the map: post-eviction offers drop.
        assert!(!evicted.offer(crate::logs::record::tests::sample_log()));
        assert_eq!(map.len(), 2);
        assert!(map.get(&k1).is_some());
        assert!(map.get(&k2).is_none(), "evicted shard left the map");
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn evicted_shard_rematerializes_natively_from_its_spill() {
        let dir = tmpdir("respawn");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 8 });
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        // Seed the shard's partition directory as a previous life's
        // spill would have.
        let native = generate(
            &Testbed::didclab(),
            &GenConfig { days: 2, arrivals_per_hour: 15.0, start_day: 0, seed: 63 },
        );
        LogStore::open(map.shard_dir(&key)).unwrap().append(&native).unwrap();
        let (shard, _) = map
            .get_or_materialize(key, || {
                Shard::materialize(
                    key,
                    &map.shard_dir(&key),
                    || panic!("spilled shard must rematerialize natively"),
                    ShardConfig { min_native_rows: 10, ..Default::default() },
                )
            })
            .unwrap();
        assert!(!shard.is_borrowed());
        assert_eq!(shard.native_rows(), native.len() as u64);
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
