//! The shard map: lazy shard materialization bookkeeping plus an LRU
//! cap that evicts cold shards.
//!
//! The map itself never builds knowledge — the [`ShardRouter`] decides
//! how a missing shard gets seeded (native fit vs cold-start borrow)
//! and passes the recipe to [`ShardMap::get_or_materialize`]. Eviction
//! selects the coldest shard and shuts it down under *that key's*
//! materialization guard: its ingest queue drains into its log
//! partitions (the spill) before the same key could possibly
//! rematerialize from that directory.
//!
//! ## Per-key materialization guards
//!
//! Materialization used to serialize under one global mutex, which
//! meant a cold-start KB build for `xsede/large` stalled an unrelated
//! `didclab/small` build behind it — unacceptable under the stampede
//! plane's genuinely concurrent workers. The map now keeps one guard
//! *per key* (the guard table is bounded by the key space: networks ×
//! size classes). The safety property the global lock provided is
//! preserved per key: every build of key K and every spill of key K
//! run under K's guard, so a rematerialization can never read
//! half-written partitions, and the same key is never built twice
//! concurrently. No code path ever holds two per-key guards at once,
//! so the guards cannot deadlock against each other.
//!
//! [`ShardRouter`]: super::router::ShardRouter

use super::key::ShardKey;
use super::shard::Shard;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Map tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ShardMapConfig {
    /// Maximum shards held in memory; inserting beyond it evicts the
    /// least-recently-used shard. A full KB per shard is the expensive
    /// part of the fabric, so this is the fabric's memory ceiling.
    pub max_live: usize,
}

impl Default for ShardMapConfig {
    fn default() -> Self {
        ShardMapConfig { max_live: 64 }
    }
}

/// Live shards keyed by [`ShardKey`], with LRU accounting.
pub struct ShardMap {
    root: PathBuf,
    shards: RwLock<HashMap<ShardKey, Arc<Shard>>>,
    /// Logical clock stamped into `Shard::last_used` on every hit.
    clock: AtomicU64,
    /// One materialization guard per key: builds and spills of the
    /// same key serialize, unrelated keys proceed in parallel. The
    /// table lock is only ever held long enough to clone a guard out —
    /// never while a guard is being locked.
    guards: Mutex<HashMap<ShardKey, Arc<Mutex<()>>>>,
    config: ShardMapConfig,
}

impl ShardMap {
    pub fn new(root: &Path, config: ShardMapConfig) -> ShardMap {
        ShardMap {
            root: root.to_path_buf(),
            shards: RwLock::new(HashMap::new()),
            clock: AtomicU64::new(1),
            guards: Mutex::new(HashMap::new()),
            config,
        }
    }

    /// The shard's private log-partition directory under the fabric
    /// root (this is where evicted shards spill to and rematerialize
    /// from).
    pub fn shard_dir(&self, key: &ShardKey) -> PathBuf {
        self.root.join(key.dir_name())
    }

    fn touch(&self, shard: &Shard) {
        shard.last_used.store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
    }

    /// The materialization guard for one key, created on first contact.
    /// The guard table is never locked while holding a per-key guard.
    fn guard_for(&self, key: ShardKey) -> Arc<Mutex<()>> {
        self.guards
            .lock()
            .expect("guard table poisoned")
            .entry(key)
            .or_insert_with(|| Arc::new(Mutex::new(())))
            .clone()
    }

    /// Look up a live shard, refreshing its LRU stamp.
    pub fn get(&self, key: &ShardKey) -> Option<Arc<Shard>> {
        let shards = self.shards.read().expect("shard map poisoned");
        let shard = shards.get(key)?.clone();
        self.touch(&shard);
        Some(shard)
    }

    /// Look up a live shard, materializing it with `make` on a miss.
    /// `make` runs outside the map lock and under *this key's* guard,
    /// so the request path of other shards never stalls behind a
    /// cold-start KB build — unrelated keys materialize in parallel —
    /// and the same key is never built twice. When the LRU cap forces a
    /// shard out, the victim is shut down under *its own* key's guard —
    /// its queue spilled to its partitions and its flusher joined —
    /// so a rematerialization of the victim key blocks on that guard
    /// and can never race the spill (two flushers appending to one
    /// partition directory, or a half-written tail read back
    /// mid-build). The evicted shard is returned for the caller's
    /// accounting.
    pub fn get_or_materialize(
        &self,
        key: ShardKey,
        make: impl FnOnce() -> anyhow::Result<Shard>,
    ) -> anyhow::Result<(Arc<Shard>, Option<Arc<Shard>>)> {
        if let Some(shard) = self.get(&key) {
            return Ok((shard, None));
        }
        let guard = self.guard_for(key);
        let over_cap = {
            let _held = guard.lock().expect("materialize guard poisoned");
            // Double-check: another request may have materialized it
            // while we waited for the guard.
            if let Some(shard) = self.get(&key) {
                return Ok((shard, None));
            }
            let shard = Arc::new(make()?);
            let mut shards = self.shards.write().expect("shard map poisoned");
            self.touch(&shard);
            shards.insert(key, shard.clone());
            let over = shards.len() > self.config.max_live.max(1);
            drop(shards);
            if !over {
                return Ok((shard, None));
            }
            Some(shard)
        };
        // Over the cap: evict the coldest shard *after* releasing this
        // key's guard, so the victim's guard is taken with no other
        // guard held (two concurrent materializations evicting each
        // other's keys would otherwise deadlock).
        let shard = over_cap.expect("over-cap path always carries the shard");
        let evicted = self.evict_coldest(&key);
        Ok((shard, evicted))
    }

    /// Evict the least-recently-used shard other than `keep`, shutting
    /// it down under its own key's guard. Between candidate selection
    /// and removal the victim may be touched by a concurrent lookup —
    /// the LRU is approximate under contention, which only costs a
    /// rebuild, never a lost row.
    fn evict_coldest(&self, keep: &ShardKey) -> Option<Arc<Shard>> {
        let victim_key = {
            let shards = self.shards.read().expect("shard map poisoned");
            shards
                .iter()
                .filter(|(k, _)| *k != keep)
                .min_by_key(|(_, s)| s.last_used.load(Ordering::Relaxed))
                .map(|(k, _)| *k)?
        };
        self.evict(&victim_key)
    }

    /// Forcibly evict one shard (fault injection — the scenario
    /// engine's shard-churn events; the LRU cap evicts organically).
    /// The shard is removed from the map and shut down — its queue
    /// spilled to its partitions — under its key's materialization
    /// guard, so a concurrent rematerialization of the same key can
    /// never race the spill. Returns the evicted shard, `None` when
    /// the key was not live.
    pub fn evict(&self, key: &ShardKey) -> Option<Arc<Shard>> {
        let guard = self.guard_for(*key);
        let _held = guard.lock().expect("materialize guard poisoned");
        let shard = self.shards.write().expect("shard map poisoned").remove(key);
        if let Some(cold) = &shard {
            cold.shutdown();
        }
        shard
    }

    /// Snapshot of every live shard (metrics, tick sweeps), sorted by
    /// key for stable rendering.
    pub fn live(&self) -> Vec<Arc<Shard>> {
        let mut shards: Vec<Arc<Shard>> =
            self.shards.read().expect("shard map poisoned").values().cloned().collect();
        shards.sort_by_key(|s| s.key);
        shards
    }

    pub fn len(&self) -> usize {
        self.shards.read().expect("shard map poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove every shard (fabric shutdown); the caller shuts each down.
    pub fn drain(&self) -> Vec<Arc<Shard>> {
        self.shards.write().expect("shard map poisoned").drain().map(|(_, s)| s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::shard::ShardConfig;
    use crate::logs::generate::{generate, GenConfig};
    use crate::logs::store::LogStore;
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::{Testbed, TestbedId};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_shardmap_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn donor_kb(seed: u64) -> Arc<crate::offline::knowledge::KnowledgeBase> {
        let rows = generate(
            &Testbed::xsede(),
            &GenConfig { days: 2, arrivals_per_hour: 15.0, start_day: 0, seed },
        );
        Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap())
    }

    fn materialize(map: &ShardMap, key: ShardKey, kb: &Arc<crate::offline::knowledge::KnowledgeBase>) -> (Arc<Shard>, Option<Arc<Shard>>) {
        let kb = kb.clone();
        map.get_or_materialize(key, || {
            Shard::materialize(key, &map.shard_dir(&key), || (kb, None), ShardConfig::default())
        })
        .unwrap()
    }

    #[test]
    fn materializes_lazily_and_reuses() {
        let dir = tmpdir("lazy");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 8 });
        let kb = donor_kb(61);
        assert!(map.is_empty());
        let key = ShardKey::new(TestbedId::Xsede, SizeClass::Small);
        let (a, evicted) = materialize(&map, key, &kb);
        assert!(evicted.is_none());
        assert_eq!(map.len(), 1);
        let (b, _) = materialize(&map, key, &kb);
        assert!(Arc::ptr_eq(&a, &b), "second lookup reuses the live shard");
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_cap_evicts_the_coldest_shard() {
        let dir = tmpdir("lru");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 2 });
        let kb = donor_kb(62);
        let k1 = ShardKey::new(TestbedId::Xsede, SizeClass::Small);
        let k2 = ShardKey::new(TestbedId::Didclab, SizeClass::Small);
        let k3 = ShardKey::new(TestbedId::DidclabToXsede, SizeClass::Small);
        materialize(&map, k1, &kb);
        materialize(&map, k2, &kb);
        // Touch k1 so k2 is the coldest.
        assert!(map.get(&k1).is_some());
        let (_, evicted) = materialize(&map, k3, &kb);
        let evicted = evicted.expect("cap of 2 must evict on the third insert");
        assert_eq!(evicted.key, k2);
        // Already shut down by the map: post-eviction offers drop.
        assert!(!evicted.offer(crate::logs::record::tests::sample_log()));
        assert_eq!(map.len(), 2);
        assert!(map.get(&k1).is_some());
        assert!(map.get(&k2).is_none(), "evicted shard left the map");
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn evicted_shard_rematerializes_natively_from_its_spill() {
        let dir = tmpdir("respawn");
        let map = ShardMap::new(&dir, ShardMapConfig { max_live: 8 });
        let key = ShardKey::new(TestbedId::Didclab, SizeClass::Medium);
        // Seed the shard's partition directory as a previous life's
        // spill would have.
        let native = generate(
            &Testbed::didclab(),
            &GenConfig { days: 2, arrivals_per_hour: 15.0, start_day: 0, seed: 63 },
        );
        LogStore::open(map.shard_dir(&key)).unwrap().append(&native).unwrap();
        let (shard, _) = map
            .get_or_materialize(key, || {
                Shard::materialize(
                    key,
                    &map.shard_dir(&key),
                    || panic!("spilled shard must rematerialize natively"),
                    ShardConfig { min_native_rows: 10, ..Default::default() },
                )
            })
            .unwrap();
        assert!(!shard.is_borrowed());
        assert_eq!(shard.native_rows(), native.len() as u64);
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The spill/rematerialize window ISSUE 9 closes: a worker holding
    /// an `Arc<Shard>` keeps offering rows while the map evicts that
    /// shard and a third thread rematerializes the same key. Every row
    /// whose `offer` returned `true` must survive into the key's
    /// partition directory (shutdown drains the queue); offers that
    /// arrive after shutdown return `false` and are counted dropped,
    /// never silently lost. Regression for the per-key guard refactor —
    /// under the old global lock the interleaving could not happen at
    /// all; under per-key guards it must happen *safely*.
    #[test]
    fn eviction_under_live_offers_never_loses_accepted_rows() {
        use std::sync::atomic::AtomicUsize;

        let dir = tmpdir("evict_race");
        let map = Arc::new(ShardMap::new(&dir, ShardMapConfig { max_live: 8 }));
        let kb = donor_kb(64);
        let key = ShardKey::new(TestbedId::Xsede, SizeClass::Medium);
        let (shard, _) = materialize(&map, key, &kb);

        let accepted = Arc::new(AtomicUsize::new(0));
        let offerer = {
            let shard = shard.clone();
            let accepted = accepted.clone();
            std::thread::spawn(move || {
                for i in 0..400u64 {
                    let mut row = crate::logs::record::tests::sample_log();
                    row.id = 10_000 + i;
                    if shard.offer(row) {
                        accepted.fetch_add(1, Ordering::SeqCst);
                    }
                    if i % 16 == 0 {
                        std::thread::yield_now();
                    }
                }
            })
        };
        // Evict mid-stream: shutdown (inside) spills the queue to the
        // key's partitions while the offerer still holds its Arc.
        std::thread::sleep(std::time::Duration::from_millis(2));
        let evicted = map.evict(&key).expect("shard was live");
        assert_eq!(evicted.key, key);
        offerer.join().unwrap();

        let accepted = accepted.load(Ordering::SeqCst);
        assert!(accepted > 0, "the race never materialized: no offer landed before eviction");
        let spilled = LogStore::open(map.shard_dir(&key)).unwrap().read_all().unwrap().len();
        assert!(
            spilled >= accepted,
            "accepted {accepted} rows but only {spilled} reached the spill partitions"
        );
        // The same key rematerializes from quiescent partitions and
        // serves again (the guard ordered spill before rebuild).
        let (reborn, _) = map
            .get_or_materialize(key, || {
                Shard::materialize(
                    key,
                    &map.shard_dir(&key),
                    || (donor_kb(65), None),
                    ShardConfig { min_native_rows: 10, ..Default::default() },
                )
            })
            .unwrap();
        assert!(reborn.native_rows() >= accepted as u64);
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Two threads racing to materialize the same missing key must
    /// build it exactly once (the per-key guard preserves the global
    /// lock's single-build property).
    #[test]
    fn concurrent_materialization_of_one_key_builds_once() {
        use std::sync::atomic::AtomicUsize;

        let dir = tmpdir("once");
        let map = Arc::new(ShardMap::new(&dir, ShardMapConfig { max_live: 8 }));
        let kb = donor_kb(66);
        let key = ShardKey::new(TestbedId::DidclabToXsede, SizeClass::Large);
        let builds = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let map = map.clone();
                let kb = kb.clone();
                let builds = builds.clone();
                std::thread::spawn(move || {
                    let (shard, _) = map
                        .get_or_materialize(key, || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            Shard::materialize(
                                key,
                                &map.shard_dir(&key),
                                || (kb, None),
                                ShardConfig::default(),
                            )
                        })
                        .unwrap();
                    shard
                })
            })
            .collect();
        let shards: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(builds.load(Ordering::SeqCst), 1, "same key built more than once");
        for pair in shards.windows(2) {
            assert!(Arc::ptr_eq(&pair[0], &pair[1]), "racers got different shards");
        }
        for shard in map.drain() {
            shard.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
