//! # dtopt — Data Transfer Optimization via Offline Knowledge Discovery
//! # and Adaptive Real-time Sampling
//!
//! A reproduction of Nine et al. (2017). The library is organized as a
//! three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: transfer service, the online
//!   Adaptive Sampling Module, six baseline optimizers, the offline
//!   knowledge-discovery pipeline, the knowledge lifecycle service that
//!   closes the loop between them, and the simulated network/testbed
//!   substrate that stands in for the paper's XSEDE/DIDCLAB testbeds.
//! * **L2 (python/compile/model.py, build-time)** — JAX compute graphs
//!   for the offline-analysis hot spots (k-means Lloyd steps, batched
//!   bicubic surface evaluation), AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels for
//!   the innermost tiles (pairwise distances, bicubic patch Horner
//!   evaluation), lowered inside the L2 graphs.
//!
//! `crate::runtime` loads the artifacts through the PJRT C API (`xla`
//! crate, behind the `pjrt` feature) so the rust binary is
//! self-contained at run time — python never executes on the request
//! path.
//!
//! ## The feedback loop (`crate::feedback`)
//!
//! The paper's design is circular: offline analysis mines logs into a
//! knowledge base, the online ASM serves from it, and completed
//! transfers become new logs that are folded back in *additively*. The
//! [`feedback`] subsystem runs that loop live, split four ways:
//!
//! * [`feedback::snapshot`] — versioned, atomically hot-swappable KB
//!   snapshots; each transfer pins one consistent generation while the
//!   next publishes concurrently.
//! * [`feedback::ingest`] — a bounded, never-blocking ingestion queue
//!   with batched flush into `LogStore` day partitions (drops counted).
//! * [`feedback::refresher`] — a background thread running the offline
//!   pipeline's additive `update` over only the new partitions, then
//!   publishing the next snapshot generation.
//! * [`feedback::policy`] — refresh triggers: new-row volume,
//!   wall-clock period, and the drift-rate signal from the online
//!   monitor's mid-transfer re-tunes.
//!
//! ## Zero-copy ingest (`crate::logs`)
//!
//! Every loop above bottoms out in [`logs::LogStore`] day partitions,
//! so their parse cost bounds the whole service. The ingest layer
//! keeps that cost off the hot paths: [`logs::scan`] is a lazy JSONL
//! scanner yielding borrowed [`logs::LogRowView`]s — one pass over the
//! partition bytes, sufficient-statistics fields only, no `Json` tree,
//! no per-row allocation, in exact (property-tested) agreement with
//! the tree parser on both values and errors — and [`logs::columnar`]
//! is a compact little-endian columnar partition format
//! (`day_<n>.dtc`, selected via [`logs::StoreFormat`]) that stores f64
//! bit patterns verbatim. Mixed-format directories dispatch per
//! partition by extension; `dtopt logs compact <dir>` migrates in
//! place (idempotent, verified before originals are removed). The
//! refresher and fabric consume partitions through
//! `offline::pipeline::update_suff`, whose result is byte-identical to
//! the owned-row `update` path — `tests/ingest_conformance.rs`, the
//! `ingest` experiment, and CI's ingest-conformance job enforce the
//! equivalence; the store's `IngestStats` export as the
//! `logs.ingest.*` registry families.
//!
//! ## The sharded knowledge fabric (`crate::fabric`)
//!
//! One global knowledge base cannot scale the loop to many endpoint
//! pairs under mixed traffic. The [`fabric`] subsystem shards it by
//! (network × file-size class): a [`fabric::ShardRouter`] resolves each
//! request to its own shard — lazily materialized, LRU-capped with
//! spill to per-shard log partitions — and each shard runs the feedback
//! loop privately (own ingest queue, own refresh policy, own
//! hot-swappable snapshot slot). A brand-new shard cold-starts by
//! borrowing the nearest existing shard's KB (cluster-centroid distance
//! over `offline::features`), flagged `borrowed` until enough native
//! rows accrue to fit its own surfaces.
//!
//! ## The shared probe plane (`crate::probe`)
//!
//! Real-time sampling is the expensive part the knowledge base exists
//! to minimize — yet independent per-request sampling re-probes a
//! network once per concurrent request. The [`probe`] subsystem makes
//! the online probe a scarce shared resource per shard: a decaying
//! network-state estimate (last converged surface + load intensity)
//! short-circuits the ladder when fresh, single-flight coalescing lets
//! one leader sample while concurrent followers piggyback, and a
//! token-bucket probe budget caps the fraction of bytes spent sampling.
//! The ASM gains a warm-start mode (begin bisection at the estimated
//! surface; skip sampling entirely when confidence clears the
//! threshold), and every response reports its `probe_mode`.
//!
//! ## The shared-link contention plane (`crate::netplane`)
//!
//! A coordinator that hands every request a private testbed scores
//! decisions against a fiction: self-traffic is invisible. The
//! [`netplane`] subsystem tracks live link occupancy per network — a
//! worker registers each transfer's (procs × streams, offered rate) on
//! admission through a [`netplane::LinkLease`], every chunk re-reads
//! its neighbors (plus any scripted ambient convoy) and folds them
//! into the transfer's contention, and a fair-share stream allowance
//! caps cc×p while two or more transfers share the link. Estimates the
//! probe plane records carry the occupancy observed at admission, so
//! knowledge learned under heavy self-traffic is never reused as
//! quiet-network truth. [`netplane::LinkPlane::isolated`] keeps the
//! pre-plane behaviour selectable; `experiments::convoy` scores both
//! against the mutual-contention fixed point (`netplane::cohort`).
//!
//! ## The scenario engine (`crate::scenario`)
//!
//! The hard cases for all of the above are *regime changes*: load
//! shifts, stale history, contention spikes, churned shards. The
//! [`scenario`] subsystem composes them deterministically: a scripted
//! workload trace (plain-text fixture files under `rust/scenarios/`)
//! replays through the full stack — coordinator → fabric → probe plane
//! → ASM — while timed faults hit each layer through its own fault
//! hook (`sim::fault::FaultBoard`, probe-budget starvation, forced
//! shard eviction, forced/paused refresh). The runner records a
//! structured event timeline (byte-identical across same-seed runs)
//! and cross-cutting invariant checkers judge it: estimate
//! cluster/generation guards, piggyback-leader match, monotone shard
//! generations, non-negative budgets, bounded goodput degradation
//! against a fault-free control replay. `dtopt scenario <name|file>`
//! runs one; `tests/scenario_conformance.rs` runs the bundled library.
//!
//! ## Decision-provenance telemetry (`crate::telemetry`)
//!
//! Nothing above can *explain* a single decision after the fact — which
//! KB cluster, estimate, piggybacked ladder, or allowance clamp
//! produced a given θ. The [`telemetry`] subsystem makes attribution a
//! first-class artifact: every served request can carry a
//! [`telemetry::DecisionTrace`] — one typed event per layer hop
//! (routing, fault consult, link + probe admission, ladder steps,
//! allowance clamps, lease release, settlement), each stamped with the
//! [`telemetry::Provenance`] of the knowledge it consumed. Traces are
//! byte-identical under the same seed; the scenario engine appends a
//! `trace-complete` invariant and `dtopt trace <scenario>` prints the
//! "why this θ" chain for any request. The same subsystem provides the
//! bounded [`telemetry::LogHistogram`] behind every metrics
//! distribution (mergeable, ≤1% quantile error, constant memory) and
//! `Metrics::render_json` for machine-readable export.
//!
//! ## The fleet health plane (`crate::telemetry` — registry, health, recorder, export)
//!
//! The traces answer per-request questions; the fleet-wide complement
//! is the unified [`telemetry::Registry`]: one typed, lock-sharded
//! metrics namespace (counters, gauges, mergeable histograms under
//! hierarchical names, registered once at construction) that every
//! subsystem publishes into — feedback, fabric, probe plane, link
//! plane, coordinator. From one deterministic
//! [`telemetry::Snapshot`] cut, [`telemetry::export`] renders
//! Prometheus text and JSON byte-identically across same-seed runs (no
//! wall-clock family ever enters an export). On top sit two always-on
//! health instruments: the [`telemetry::AccuracyLedger`] scores every
//! completed transfer against the sim oracle's optimal — the paper's
//! "93% of optimal" headline as a continuously tracked per-shard
//! quantile, with a per-replay floor invariant in the scenario engine
//! — and the bounded [`telemetry::FlightRecorder`] retains the last N
//! flight summaries. `dtopt obs [--prom|--json|--recent N]` is the
//! viewer; `--metrics-out` on scenario/serve/experiment runs writes
//! the same export to disk (CI diffs two same-seed runs bytewise).
//!
//! ## The sentry plane (`crate::telemetry` — window, sentry)
//!
//! The registry tells an operator the numbers; the sentry plane tells
//! them something is *wrong*, and since when. A [`telemetry::WindowRing`]
//! folds registry snapshots into fixed-width virtual-time windows
//! (bounded retention, per-window accuracy histograms), and the
//! [`telemetry::Sentry`] evaluates five deterministic detectors over it
//! at every settlement — accuracy-below-floor, probe-budget-famine,
//! occupancy-leak, stale-knowledge, allowance-thrash — emitting typed,
//! edge-triggered [`telemetry::Alert`] raise/clear events in virtual
//! time. Every detector input is replay-stable, so same-seed replays
//! produce byte-identical alert timelines; scenarios declare the alerts
//! their faults must provoke (`expect-alert <detector> [after T]`,
//! `expect-quiet`) and the scenario engine's `alert-conformance`
//! invariant enforces them, pinning fault-free control replays to zero
//! alerts. `dtopt obs --alerts [--json]` and `dtopt scenario --alerts`
//! print the timeline; golden fixtures under
//! `rust/tests/fixtures/alerts/` pin the exact bytes.
//!
//! ## The stampede plane (`crate::stampede`)
//!
//! Everything above executes deterministically — one thread or a pool
//! fed one request at a time — but the coordinator is a *service*:
//! requests arrive together, and snapshot swaps, single-flight
//! leads/piggybacks, link-lease epochs, and shard materializations
//! race for real. The [`stampede`] subsystem is that execution mode: a
//! [`stampede::StampedeRunner`] drives 1→32 OS-thread workers (each a
//! cloned [`coordinator::ServeHandle`]) over a shared request cursor,
//! and [`stampede::conformance`] asserts every concurrent timeline is
//! a *legal interleaving* the sequential oracle could have produced —
//! generation causality, one leader per cohort, occupancy balance,
//! budget conservation, plus a per-request `sequential-match` replay.
//! Wall-clock concurrent runs are exempt from byte-determinism; the
//! conformance suite is the contract instead. `dtopt experiment
//! stampede` sweeps the worker counts and gates p99 decision latency;
//! `tests/stampede_races.rs` holds the seeded race suite.
//!
//! See `DESIGN.md` (repo root) for the layering diagram, the feedback
//! dataflow, the fabric's routing diagram and shard lifecycle, the
//! probe-plane dataflow, the scenario engine's dataflow and scenario
//! library, and the experiment index.

pub mod logs;
pub mod math;
pub mod offline;
pub mod online;
pub mod runtime;
pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod fabric;
pub mod feedback;
pub mod netplane;
pub mod probe;
pub mod scenario;
pub mod sim;
pub mod stampede;
pub mod telemetry;
pub mod util;
