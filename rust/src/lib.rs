//! # dtopt — Data Transfer Optimization via Offline Knowledge Discovery
//! # and Adaptive Real-time Sampling
//!
//! A reproduction of Nine et al. (2017). The library is organized as a
//! three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: transfer service, the online
//!   Adaptive Sampling Module, six baseline optimizers, the offline
//!   knowledge-discovery pipeline, and the simulated network/testbed
//!   substrate that stands in for the paper's XSEDE/DIDCLAB testbeds.
//! * **L2 (python/compile/model.py, build-time)** — JAX compute graphs
//!   for the offline-analysis hot spots (k-means Lloyd steps, batched
//!   bicubic surface evaluation), AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels for
//!   the innermost tiles (pairwise distances, bicubic patch Horner
//!   evaluation), lowered inside the L2 graphs.
//!
//! `crate::runtime` loads the artifacts through the PJRT C API (`xla`
//! crate) so the rust binary is self-contained at run time — python
//! never executes on the request path.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod logs;
pub mod math;
pub mod offline;
pub mod online;
pub mod runtime;
pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod sim;
pub mod util;
