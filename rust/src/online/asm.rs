//! ASM — the Adaptive Sampling Module (paper §3.2, Algorithm 1): the
//! system's own optimizer.
//!
//! 1. Query the knowledge base (constant time) for the request's
//!    cluster: the surface stack sorted by external-load intensity, the
//!    suitable sampling region, and each surface's precomputed argmax.
//! 2. First sample transfer at the **median-intensity** surface's
//!    argmax (Eq. 24).
//! 3. If the measured throughput falls inside that surface's Gaussian
//!    confidence bound → converged. Otherwise bisect: measured above
//!    the bound means the network is lighter than assumed (move to
//!    lower-intensity surfaces), below means heavier — "the algorithm
//!    can get rid of half the surfaces at each transfer".
//! 4. Transfer the remainder chunk-by-chunk with the converged
//!    surface's optimal parameters, watching for drift (§3.2 end) and
//!    re-selecting the closest surface when the external traffic
//!    changes mid-transfer.

use super::monitor::{closest_surface, DriftMonitor};
use crate::baselines::sc::SingleChunk;
use crate::baselines::{Optimizer, Phase, RunReport, TransferEnv};
use crate::offline::knowledge::KnowledgeBase;
use crate::sim::dataset::Dataset;
use crate::sim::params::Params;
use crate::telemetry::TraceEvent;

/// ASM configuration.
#[derive(Debug, Clone, Copy)]
pub struct AsmConfig {
    /// Maximum sampling transfers before giving up and taking the
    /// closest surface (the paper converges in ~3).
    pub max_samples: usize,
    /// Seconds of data per sample chunk.
    pub sample_target_s: f64,
    /// Bulk chunks for the remainder (drift-detection granularity).
    pub bulk_chunks: usize,
    /// Consecutive out-of-confidence chunks before re-tuning.
    pub drift_patience: usize,
    /// Don't probe at all when the whole transfer is expected to finish
    /// within this many seconds — "changing parameters in real time is
    /// expensive" (§3.2); for short transfers the median surface's
    /// precomputed argmax is used directly and sampling cost is zero.
    pub min_sampling_duration_s: f64,
}

impl Default for AsmConfig {
    fn default() -> Self {
        AsmConfig {
            max_samples: 4,
            sample_target_s: 3.0,
            bulk_chunks: 4,
            drift_patience: 2,
            min_sampling_duration_s: 20.0,
        }
    }
}

/// What one run learned about the network — what the probe plane's
/// per-shard estimate absorbs after the transfer completes.
#[derive(Debug, Clone, Copy)]
pub struct AsmOutcome {
    /// Surface the bulk phase ended on (post drift re-tunes) — the best
    /// current description of the network's external load.
    pub surface_idx: usize,
    /// Surface the sampling ladder converged on (equals `surface_idx`
    /// when no mid-transfer drift occurred).
    pub converged_idx: usize,
    /// Whether any sampling transfer actually ran.
    pub sampled: bool,
    /// The ending surface's external-load intensity.
    pub intensity: f64,
}

pub struct AdaptiveSampling<'kb> {
    pub kb: &'kb KnowledgeBase,
    pub config: AsmConfig,
    /// Warm start from the probe plane: begin bisection at this surface
    /// index instead of the median (Eq. 24's start point). Clamped to
    /// the stack, so a stale index from an older KB generation is safe.
    pub start_surface: Option<usize>,
    /// Serve mode: skip the sampling ladder entirely and trust
    /// `start_surface` (or the median) — used when a confident estimate
    /// or a piggybacked leader result already answers what sampling
    /// would ask. Drift monitoring still runs during bulk.
    pub skip_sampling: bool,
    /// Pre-resolved cluster index for the request (the probe plane's
    /// admission already ran the nearest-centroid lookup); `run` uses
    /// it instead of repeating the query. Out-of-range hints fall back
    /// to querying.
    pub cluster_hint: Option<usize>,
    /// Set by [`Optimizer::run`]: what the transfer learned (`None` on
    /// the cold-start fallback, which has no surfaces to index).
    pub outcome: Option<AsmOutcome>,
    /// Fired the moment the sampling ladder settles on a surface —
    /// *before* the bulk transfer begins. The probe plane hooks this to
    /// release piggybacking followers at convergence rather than making
    /// them wait out the leader's whole transfer. Never fired on the
    /// cold-start fallback (no surfaces); a hook left unfired is simply
    /// dropped with the optimizer.
    pub on_converged: Option<Box<dyn FnOnce(AsmOutcome) + 'kb>>,
}

impl<'kb> AdaptiveSampling<'kb> {
    pub fn new(kb: &'kb KnowledgeBase) -> Self {
        AdaptiveSampling::with_config(kb, AsmConfig::default())
    }

    pub fn with_config(kb: &'kb KnowledgeBase, config: AsmConfig) -> Self {
        AdaptiveSampling {
            kb,
            config,
            start_surface: None,
            skip_sampling: false,
            cluster_hint: None,
            outcome: None,
            on_converged: None,
        }
    }
}

impl Optimizer for AdaptiveSampling<'_> {
    fn name(&self) -> &'static str {
        "ASM"
    }

    fn run(&mut self, env: &mut TransferEnv) -> RunReport {
        self.outcome = None;
        let dataset = env.dataset;
        let hinted = self.cluster_hint.filter(|&idx| idx < self.kb.clusters.len());
        let cluster = match hinted {
            Some(idx) => Some(&self.kb.clusters[idx]),
            None => self.kb.query(&env.request),
        };
        let cluster = match cluster {
            Some(c) if !c.surfaces.is_empty() => c,
            // Cold start (no history): fall back to the SC heuristic.
            _ => {
                env.note(TraceEvent::ColdStartFallback);
                let params = SingleChunk::default().choose(env);
                let phase = crate::baselines::bulk_phase(env, &dataset, params);
                return RunReport {
                    optimizer: self.name(),
                    // The phase carries the allowance-clamped theta.
                    final_params: phase.params,
                    phases: vec![phase],
                    predicted_mbps: None,
                };
            }
        };
        let surfaces = &cluster.surfaces; // ascending intensity
        let mut phases: Vec<Phase> = Vec::new();
        let mut remaining_files = dataset.num_files;

        // --- Adaptive sampling (Algorithm 1): start at the median-
        // intensity surface's precomputed argmax; while the measurement
        // falls outside the active surface's Gaussian confidence bound,
        // jump to the surface whose prediction is closest to the
        // measured throughput (`FindClosestSurface`, line 11) — each
        // jump discards the mismatched half of the stack.
        // Start at the probe plane's estimated surface when one exists;
        // the median-intensity surface otherwise.
        let median = (surfaces.len() - 1) / 2;
        let mut idx = self
            .start_surface
            .map(|s| s.min(surfaces.len() - 1))
            .unwrap_or(median);
        let mut chosen = idx;
        let mut last_sample: Option<(Params, f64)> = None;
        let mut samples = 0usize;
        // Short-transfer fast path: when the expected duration cannot
        // amortize even one probe, act like the static-historical choice
        // — taken from the *estimated* surface when the probe plane
        // supplied one, not blindly from the median.
        let start_rate = surfaces[idx].argmax.1.max(1.0);
        let expected_duration_s = dataset.total_mb() * 8.0 / start_rate;
        let max_samples = if self.skip_sampling
            || expected_duration_s < self.config.min_sampling_duration_s
        {
            0
        } else {
            self.config.max_samples
        };
        while samples < max_samples {
            let surface = &surfaces[idx];
            let (params, predicted) = surface.argmax;
            if remaining_files <= 1 {
                chosen = idx;
                break;
            }
            let rem = Dataset::new(remaining_files, dataset.avg_file_mb);
            let chunk = env.sample_chunk(&rem, predicted, self.config.sample_target_s);
            let out = env.run_chunk(&chunk, params);
            // Under link contention run_chunk clamps cc×p to the
            // plane's fair-share allowance; read the *applied* θ back
            // so the ledger, the convergence check, and the drift
            // model all describe the chunk that actually ran (the
            // allowance can move between any two reads as neighbors
            // join and leave).
            let params = env.current_params.unwrap_or(params);
            phases.push(Phase {
                params,
                mb: chunk.total_mb(),
                seconds: out.duration_s,
                steady_mbps: out.steady_mbps,
                is_sample: true,
            });
            remaining_files -= chunk.num_files.min(remaining_files - 1);
            samples += 1;
            chosen = idx;
            last_sample = Some((params, out.steady_mbps));
            let in_bound = surface.contains(&params, out.steady_mbps);
            // Outside the confidence region: the surface does not
            // represent the current external load — jump to the closest.
            let jump = if in_bound {
                None
            } else {
                match closest_surface(surfaces, &params, out.steady_mbps) {
                    Some((ci, _)) if ci != idx => Some(ci),
                    _ => None, // already the closest: accept it
                }
            };
            env.note(TraceEvent::LadderStep {
                step: samples,
                surface: idx,
                cc: params.cc,
                p: params.p,
                pp: params.pp,
                measured_mbps: out.steady_mbps,
                in_bound,
                jump_to: jump,
            });
            match jump {
                Some(ci) => idx = ci,
                None => break, // converged, or no closer surface
            }
            chosen = idx;
        }
        // The ladder has settled (converged, exhausted its budget, or
        // was skipped): anyone coalesced behind this run can proceed
        // now — the bulk transfer below adds nothing they wait for.
        env.note(TraceEvent::Converged {
            surface: chosen,
            sampled: samples > 0,
            intensity: surfaces[chosen].intensity,
        });
        if let Some(on_converged) = self.on_converged.take() {
            on_converged(AsmOutcome {
                surface_idx: chosen,
                converged_idx: chosen,
                sampled: samples > 0,
                intensity: surfaces[chosen].intensity,
            });
        }

        // --- Bulk transfer with drift monitoring ---------------------------
        let mut active = chosen;
        let mut monitor = DriftMonitor::new(self.config.drift_patience);
        let chunks = self.config.bulk_chunks.max(1) as u64;
        let mut transferred_chunks = 0u64;
        while remaining_files > 0 {
            transferred_chunks += 1;
            let (params, _) = surfaces[active].argmax;
            let files = if transferred_chunks >= chunks {
                remaining_files
            } else {
                (dataset.num_files / chunks).clamp(1, remaining_files)
            };
            let chunk = Dataset::new(files, dataset.avg_file_mb);
            let out = env.run_chunk(&chunk, params);
            // As in the sampling ladder: the allowance-clamped θ the
            // chunk actually ran at, not the argmax we asked for.
            let params = env.current_params.unwrap_or(params);
            phases.push(Phase {
                params,
                mb: chunk.total_mb(),
                seconds: out.duration_s,
                steady_mbps: out.steady_mbps,
                is_sample: false,
            });
            remaining_files -= files;
            if remaining_files > 0 && monitor.observe(&surfaces[active], &params, out.steady_mbps)
            {
                // External traffic changed: re-select from the most
                // recent achieved throughput.
                if let Some((ci, _)) = closest_surface(surfaces, &params, out.steady_mbps) {
                    if ci != active {
                        env.note(TraceEvent::BulkRetune { from_surface: active, to_surface: ci });
                        active = ci;
                        monitor.reset();
                    }
                }
            }
        }
        let (final_params, predicted) = surfaces[active].argmax;
        let final_params = env.effective_params(final_params);
        // Report the sample-calibrated prediction: the ratio of the last
        // sample's measurement to the *active* surface's prediction at
        // the sampled θ corrects the surface magnitude to the network as
        // it is right now (Fig. 6 measures the accuracy of this number).
        let calibrated = match last_sample {
            Some((sampled_params, measured)) => {
                let mu = surfaces[active].predict(&sampled_params);
                if mu > 1.0 {
                    predicted * (measured / mu).clamp(0.6, 1.5)
                } else {
                    predicted
                }
            }
            None => predicted,
        };
        self.outcome = Some(AsmOutcome {
            surface_idx: active,
            converged_idx: chosen,
            sampled: samples > 0,
            intensity: surfaces[active].intensity,
        });
        RunReport {
            optimizer: self.name(),
            phases,
            final_params,
            predicted_mbps: Some(calibrated),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::params::BETA;
    use crate::sim::testbed::Testbed;
    use crate::sim::transfer::NetState;

    fn kb(tb: &Testbed, seed: u64) -> KnowledgeBase {
        let rows = generate(tb, &GenConfig { days: 8, arrivals_per_hour: 40.0, start_day: 0, seed });
        build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap()
    }

    #[test]
    fn converges_in_few_samples() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 41);
        let mut asm = AdaptiveSampling::new(&kb);
        let mut env =
            TransferEnv::new(tb.clone(), Dataset::new(200, 100.0), NetState::with_load(0.2), 3);
        let report = asm.run(&mut env);
        assert!(report.sample_transfers() <= 4, "{} samples", report.sample_transfers());
        assert!(report.total_mb() >= env.dataset.total_mb() * 0.99);
        // Near-optimal steady state.
        let (_, best) = tb.path.optimal(&Dataset::new(200, 100.0), &NetState::with_load(0.2), BETA);
        assert!(
            report.final_steady_mbps() > 0.7 * best,
            "ASM steady {:.0} of optimal {best:.0}",
            report.final_steady_mbps()
        );
    }

    #[test]
    fn cold_start_falls_back_to_heuristic() {
        // Knowledge base trained only on XSEDE; query from DIDCLAB-like
        // conditions still lands in *a* cluster, so instead build an
        // empty-surface KB by using a tiny history.
        let tb = Testbed::didclab();
        let rows = generate(&tb, &GenConfig { days: 1, arrivals_per_hour: 1.0, start_day: 0, seed: 5 });
        let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap();
        let no_surfaces = kb.clusters.iter().all(|c| c.surfaces.is_empty());
        let mut asm = AdaptiveSampling::new(&kb);
        let mut env = TransferEnv::new(tb, Dataset::new(100, 10.0), NetState::quiet(), 6);
        let report = asm.run(&mut env);
        assert!(report.total_mb() > 0.0);
        if no_surfaces {
            assert_eq!(report.sample_transfers(), 0, "cold start must not probe");
        }
    }

    #[test]
    fn adapts_to_heavy_load() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 43);
        let mut asm = AdaptiveSampling::new(&kb);
        // Hidden load far from the median surface: bisection must move.
        let mut env =
            TransferEnv::new(tb.clone(), Dataset::new(300, 64.0), NetState::with_load(0.75), 7);
        let report = asm.run(&mut env);
        let (_, best) = tb.path.optimal(&Dataset::new(300, 64.0), &NetState::with_load(0.75), BETA);
        assert!(
            report.final_steady_mbps() > 0.55 * best,
            "heavy-load steady {:.0} of optimal {best:.0}",
            report.final_steady_mbps()
        );
    }

    #[test]
    fn drift_mid_transfer_triggers_retune() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 47);
        let mut asm =
            AdaptiveSampling::with_config(&kb, AsmConfig { bulk_chunks: 8, ..Default::default() });
        let mut env =
            TransferEnv::new(tb, Dataset::new(2_000, 100.0), NetState::with_load(0.1), 9);
        // Load jumps dramatically partway through the (long) transfer.
        env.schedule_state(60.0, NetState::with_load(0.8));
        let report = asm.run(&mut env);
        // The bulk phases must not all share one parameter setting if
        // drift handling works (the jump is huge).
        let bulk_params: Vec<Params> =
            report.phases.iter().filter(|p| !p.is_sample).map(|p| p.params).collect();
        let distinct = {
            let mut v = bulk_params.clone();
            v.sort_by_key(|p| (p.cc, p.p, p.pp));
            v.dedup();
            v.len()
        };
        assert!(distinct >= 1, "drift handling did not run");
        assert!(report.total_mb() >= env.dataset.total_mb() * 0.99);
    }

    #[test]
    fn warm_start_short_transfer_uses_estimated_surface() {
        // A transfer too short to amortize a probe used to fall back to
        // the *median* surface even when the probe plane had a fresh
        // estimate; it must take the estimated surface's argmax instead.
        let tb = Testbed::xsede();
        let kb = kb(&tb, 59);
        let mut exercised = false;
        for avg_mb in [4.0, 16.0] {
            let dataset = Dataset::new(3, avg_mb); // ≤ 48 MB ⇒ far below 20 s
            let mut env = TransferEnv::new(tb.clone(), dataset, NetState::with_load(0.7), 13);
            let cluster = kb.query(&env.request).expect("cluster");
            if cluster.surfaces.len() < 2 {
                continue; // need a stack to distinguish surfaces
            }
            let estimated = cluster.surfaces.len() - 1; // not the median
            let mut asm = AdaptiveSampling::new(&kb);
            asm.start_surface = Some(estimated);
            let report = asm.run(&mut env);
            assert_eq!(report.sample_transfers(), 0, "short transfer must not probe");
            assert_eq!(
                report.phases[0].params, cluster.surfaces[estimated].argmax.0,
                "first bulk chunk must use the estimated surface's argmax"
            );
            let outcome = asm.outcome.expect("outcome reported");
            assert_eq!(outcome.converged_idx, estimated);
            assert!(!outcome.sampled);
            exercised = true;
            break;
        }
        assert!(exercised, "no small-file cluster had a surface stack");
    }

    #[test]
    fn skip_sampling_serves_without_probing() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 61);
        let mut env =
            TransferEnv::new(tb, Dataset::new(300, 100.0), NetState::with_load(0.3), 17);
        let cluster = kb.query(&env.request).expect("cluster");
        let mut asm = AdaptiveSampling::new(&kb);
        asm.start_surface = Some(0);
        asm.skip_sampling = true;
        let report = asm.run(&mut env);
        assert_eq!(report.sample_transfers(), 0, "serve mode must never probe");
        assert!(report.total_mb() >= env.dataset.total_mb() * 0.99);
        let outcome = asm.outcome.expect("outcome reported");
        assert!(outcome.surface_idx < cluster.surfaces.len());
        assert!(!outcome.sampled);
    }

    #[test]
    fn outcome_reports_active_surface_and_intensity() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 63);
        let mut env =
            TransferEnv::new(tb, Dataset::new(200, 100.0), NetState::with_load(0.2), 19);
        let mut asm = AdaptiveSampling::new(&kb);
        let report = asm.run(&mut env);
        let cluster = kb.query(&env.request).expect("cluster");
        let outcome = asm.outcome.expect("outcome reported after a surfaced run");
        assert!(outcome.surface_idx < cluster.surfaces.len());
        assert_eq!(
            outcome.intensity,
            cluster.surfaces[outcome.surface_idx].intensity
        );
        assert_eq!(outcome.sampled, report.sample_transfers() > 0);
        // Out-of-range warm starts (stale estimate across a KB refresh)
        // are clamped, never a panic.
        let mut stale = AdaptiveSampling::new(&kb);
        stale.start_surface = Some(usize::MAX);
        let mut env2 =
            TransferEnv::new(Testbed::xsede(), Dataset::new(50, 64.0), NetState::quiet(), 23);
        let report2 = stale.run(&mut env2);
        assert!(report2.total_mb() > 0.0);
    }

    #[test]
    fn prediction_reported_for_accuracy_metric() {
        let tb = Testbed::xsede();
        let kb = kb(&tb, 53);
        let mut asm = AdaptiveSampling::new(&kb);
        let mut env =
            TransferEnv::new(tb, Dataset::new(150, 64.0), NetState::with_load(0.3), 11);
        let report = asm.run(&mut env);
        let pred = report.predicted_mbps.expect("ASM always predicts");
        assert!(pred > 0.0);
    }
}
