//! Drift detection for long-running transfers (paper §3.2, end: "For
//! very large scale transfers ... external traffic could change during
//! the transfer. If algorithm detects such deviation, it uses most
//! recently achieved throughput value to choose the suitable surface").

use crate::offline::surface::SurfaceModel;
use crate::sim::params::Params;

/// Watches measured chunk throughputs against the active surface's
/// Gaussian confidence region; trips after `patience` consecutive
/// out-of-bound observations (one noisy chunk must not cause a costly
/// re-tune).
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    pub patience: usize,
    consecutive_out: usize,
}

impl DriftMonitor {
    pub fn new(patience: usize) -> DriftMonitor {
        DriftMonitor { patience: patience.max(1), consecutive_out: 0 }
    }

    /// Feed one measurement; returns `true` when drift is confirmed.
    pub fn observe(&mut self, surface: &SurfaceModel, params: &Params, measured: f64) -> bool {
        if surface.contains(params, measured) {
            self.consecutive_out = 0;
            false
        } else {
            self.consecutive_out += 1;
            if self.consecutive_out >= self.patience {
                self.consecutive_out = 0;
                true
            } else {
                false
            }
        }
    }

    pub fn reset(&mut self) {
        self.consecutive_out = 0;
    }
}

/// Pick the surface whose prediction at `params` is closest to the most
/// recent measurement — the paper's `FindClosestSurface`.
pub fn closest_surface<'a>(
    surfaces: &'a [SurfaceModel],
    params: &Params,
    measured: f64,
) -> Option<(usize, &'a SurfaceModel)> {
    surfaces
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            let da = (a.predict(params) - measured).abs();
            let db = (b.predict(params) - measured).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, s)| (i, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::surface::tests::stats_from_simulator;
    use crate::sim::dataset::Dataset;

    fn surfaces() -> Vec<SurfaceModel> {
        let d = Dataset::new(100, 64.0);
        vec![
            SurfaceModel::build(&stats_from_simulator(0.1, &d, 2, 31), 0.1).unwrap(),
            SurfaceModel::build(&stats_from_simulator(0.5, &d, 2, 32), 0.5).unwrap(),
            SurfaceModel::build(&stats_from_simulator(0.8, &d, 2, 33), 0.8).unwrap(),
        ]
    }

    #[test]
    fn patience_filters_single_outliers() {
        let s = &surfaces()[0];
        let params = Params::new(8, 4, 4);
        let mut mon = DriftMonitor::new(2);
        let inlier = s.predict(&params);
        let outlier = inlier * 0.2;
        assert!(!mon.observe(s, &params, outlier), "first outlier must not trip");
        assert!(!mon.observe(s, &params, inlier), "inlier resets");
        assert!(!mon.observe(s, &params, outlier));
        assert!(mon.observe(s, &params, outlier), "second consecutive outlier trips");
    }

    #[test]
    fn closest_surface_tracks_load() {
        let stack = surfaces();
        let params = Params::new(8, 4, 4);
        // A measurement near the heavy-load surface's prediction selects it.
        let heavy_pred = stack[2].predict(&params);
        let (idx, _) = closest_surface(&stack, &params, heavy_pred).unwrap();
        assert_eq!(idx, 2);
        let light_pred = stack[0].predict(&params);
        let (idx, _) = closest_surface(&stack, &params, light_pred).unwrap();
        assert_eq!(idx, 0);
    }

    #[test]
    fn empty_stack_is_none() {
        assert!(closest_surface(&[], &Params::new(1, 1, 1), 100.0).is_none());
    }
}
