//! Online phase (paper §3.2): the Adaptive Sampling Module and its
//! drift monitor for long transfers.

pub mod asm;
pub mod monitor;

pub use asm::{AdaptiveSampling, AsmConfig, AsmOutcome};
pub use monitor::DriftMonitor;
