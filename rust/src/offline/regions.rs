//! Suitable-sampling-region identification (paper §3.1.4, Eq. 21–23).
//!
//! `R_m` — neighbourhoods (radius `r_d` in knot steps) around every
//! surface's maximum: where the payoff lives.
//! `R_c` — the points where the surface stack is most *distinguishable*:
//! uniform-sample the parameter space, score each point by the minimum
//! pairwise |f_i − f_j| across surfaces (Eq. 22), keep the top-λ — one
//! sample transfer there tells the online module which surface the
//! network is currently on.
//! `R_s = R_m ∪ R_c` (Eq. 23).

use super::surface::SurfaceModel;
use crate::sim::params::{Params, BETA, PP_LEVELS};
use crate::util::rng::Rng;

/// Configuration for region extraction.
#[derive(Debug, Clone, Copy)]
pub struct RegionConfig {
    /// Neighbourhood radius r_d (in integer parameter steps).
    pub radius: u32,
    /// Number of uniform samples γ.
    pub gamma: usize,
    /// Number of separating points λ to keep.
    pub lambda: usize,
}

impl Default for RegionConfig {
    fn default() -> Self {
        RegionConfig { radius: 1, gamma: 256, lambda: 8 }
    }
}

/// The sampling region for one cluster.
#[derive(Debug, Clone, Default)]
pub struct SamplingRegion {
    /// Maxima neighbourhoods R_m.
    pub maxima_points: Vec<Params>,
    /// Max-min separating points R_c with their separation score.
    pub separating_points: Vec<(Params, f64)>,
}

impl SamplingRegion {
    /// R_s = R_m ∪ R_c, deduplicated.
    pub fn union(&self) -> Vec<Params> {
        let mut out: Vec<Params> = self.maxima_points.clone();
        out.extend(self.separating_points.iter().map(|(p, _)| *p));
        out.sort_by_key(|p| (p.cc, p.p, p.pp));
        out.dedup();
        out
    }
}

/// Extract the sampling region from a cluster's surface stack.
pub fn extract(surfaces: &[SurfaceModel], config: &RegionConfig, rng: &mut Rng) -> SamplingRegion {
    let mut region = SamplingRegion::default();
    if surfaces.is_empty() {
        return region;
    }

    // --- R_m: argmax neighbourhoods --------------------------------------
    for s in surfaces {
        let (opt, _) = s.argmax;
        let r = config.radius as i64;
        for dcc in -r..=r {
            for dp in -r..=r {
                let cc = (opt.cc as i64 + dcc).clamp(1, BETA as i64) as u32;
                let p = (opt.p as i64 + dp).clamp(1, BETA as i64) as u32;
                region.maxima_points.push(Params::new(cc, p, opt.pp));
            }
        }
    }
    region.maxima_points.sort_by_key(|p| (p.cc, p.p, p.pp));
    region.maxima_points.dedup();

    // --- R_c: max-min separating points (Eq. 21–22) -----------------------
    if surfaces.len() >= 2 {
        let mut scored: Vec<(Params, f64)> = Vec::with_capacity(config.gamma);
        for _ in 0..config.gamma {
            let params = Params::new(
                rng.range_u(1, BETA as u64) as u32,
                rng.range_u(1, BETA as u64) as u32,
                PP_LEVELS[rng.index(PP_LEVELS.len())],
            );
            let mut min_sep = f64::INFINITY;
            for i in 0..surfaces.len() {
                for j in 0..i {
                    let sep = (surfaces[i].predict(&params) - surfaces[j].predict(&params)).abs();
                    min_sep = min_sep.min(sep);
                }
            }
            scored.push((params, min_sep));
        }
        // Descending by separation; keep λ distinct points.
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.dedup_by_key(|(p, _)| *p);
        scored.truncate(config.lambda);
        region.separating_points = scored;
    }
    region
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::surface::tests::stats_from_simulator;
    use crate::offline::surface::SurfaceModel;
    use crate::sim::dataset::Dataset;

    fn stack() -> Vec<SurfaceModel> {
        let d = Dataset::new(100, 64.0);
        vec![
            SurfaceModel::build(&stats_from_simulator(0.1, &d, 2, 1), 0.1).unwrap(),
            SurfaceModel::build(&stats_from_simulator(0.5, &d, 2, 2), 0.5).unwrap(),
            SurfaceModel::build(&stats_from_simulator(0.8, &d, 2, 3), 0.8).unwrap(),
        ]
    }

    #[test]
    fn region_contains_each_argmax() {
        let surfaces = stack();
        let mut rng = Rng::new(4);
        let region = extract(&surfaces, &RegionConfig::default(), &mut rng);
        for s in &surfaces {
            let (opt, _) = s.argmax;
            assert!(
                region.maxima_points.contains(&opt),
                "R_m missing argmax {opt} of intensity {}",
                s.intensity
            );
        }
    }

    #[test]
    fn separating_points_have_positive_scores_sorted() {
        let surfaces = stack();
        let mut rng = Rng::new(5);
        let region = extract(&surfaces, &RegionConfig::default(), &mut rng);
        assert!(!region.separating_points.is_empty());
        for w in region.separating_points.windows(2) {
            assert!(w[0].1 >= w[1].1, "scores must be descending");
        }
        // Surfaces at very different loads must be separable somewhere.
        assert!(region.separating_points[0].1 > 0.0);
    }

    #[test]
    fn union_deduplicates() {
        let surfaces = stack();
        let mut rng = Rng::new(6);
        let region = extract(&surfaces, &RegionConfig::default(), &mut rng);
        let u = region.union();
        let mut sorted = u.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), u.len());
        assert!(u.len() >= region.maxima_points.len());
    }

    #[test]
    fn empty_and_single_surface_edge_cases() {
        let mut rng = Rng::new(7);
        let empty = extract(&[], &RegionConfig::default(), &mut rng);
        assert!(empty.union().is_empty());
        let d = Dataset::new(100, 64.0);
        let one = vec![SurfaceModel::build(&stats_from_simulator(0.2, &d, 2, 9), 0.2).unwrap()];
        let region = extract(&one, &RegionConfig::default(), &mut rng);
        assert!(!region.maxima_points.is_empty());
        assert!(region.separating_points.is_empty(), "no pairs to separate");
    }

    #[test]
    fn radius_zero_keeps_only_argmaxes() {
        let surfaces = stack();
        let mut rng = Rng::new(8);
        let cfg = RegionConfig { radius: 0, gamma: 0, lambda: 0 };
        let region = extract(&surfaces, &cfg, &mut rng);
        assert!(region.maxima_points.len() <= surfaces.len());
    }
}
