//! The end-to-end offline knowledge-discovery pipeline (paper §3.1):
//! cluster the history, bin by external-load intensity, build surfaces
//! + confidence regions + maxima + sampling regions, and support
//! *additive* periodic refresh from new log partitions only.

use super::chindex::select_k;
use super::features::{Normalizer, FEATURE_DIM};
use super::kmeans::AssignBackend;
use super::knowledge::{ClusterKnowledge, KnowledgeBase};
use super::regions::RegionConfig;
use crate::logs::record::{SuffRow, TransferLog};
use crate::sim::traffic::DAY_S;
use crate::util::rng::Rng;
use anyhow::Result;

/// Offline-analysis configuration.
#[derive(Debug, Clone)]
pub struct OfflineConfig {
    /// Candidate cluster counts for the CH-index selection.
    pub k_min: usize,
    pub k_max: usize,
    /// Subsample size for k selection + Lloyd (assignment of the full
    /// history happens afterwards against the chosen centroids).
    pub sample_cap: usize,
    pub region: RegionConfig,
    pub seed: u64,
}

impl Default for OfflineConfig {
    fn default() -> Self {
        OfflineConfig {
            k_min: 2,
            k_max: 10,
            sample_cap: 4_096,
            region: RegionConfig::default(),
            seed: 0x0FF1,
        }
    }
}

/// Build a knowledge base from scratch.
pub fn build(
    rows: &[TransferLog],
    config: &OfflineConfig,
    backend: &mut dyn AssignBackend,
) -> Result<KnowledgeBase> {
    anyhow::ensure!(!rows.is_empty(), "offline build: no log rows");
    let normalizer = Normalizer::fit(rows);

    // --- Clustering: CH-selected k on a subsample ------------------------
    let mut rng = Rng::new(config.seed);
    let sample_idx: Vec<usize> = if rows.len() > config.sample_cap {
        rng.sample_indices(rows.len(), config.sample_cap)
    } else {
        (0..rows.len()).collect()
    };
    let mut sample_feats = Vec::with_capacity(sample_idx.len() * FEATURE_DIM);
    for &i in &sample_idx {
        sample_feats.extend_from_slice(&normalizer.features(&rows[i]));
    }
    let n = sample_idx.len();
    let k_max = config.k_max.min(n.saturating_sub(1)).max(config.k_min);
    let (k, km, k_scores) = select_k(
        &sample_feats,
        n,
        FEATURE_DIM,
        config.k_min..=k_max,
        &mut rng,
        backend,
    )?;

    // --- Assemble clusters and push every row (full history) --------------
    let mut clusters: Vec<ClusterKnowledge> = (0..k)
        .map(|c| {
            ClusterKnowledge::new(km.centroids[c * FEATURE_DIM..(c + 1) * FEATURE_DIM].to_vec())
        })
        .collect();
    let mut kb = KnowledgeBase {
        normalizer,
        clusters: Vec::new(),
        k_scores,
        built_through_day: rows
            .iter()
            .map(|r| (r.t_start / DAY_S) as u64)
            .max()
            .unwrap_or(0),
        region_config: config.region,
        seed: config.seed,
    };
    // Temporarily install clusters so assign_row works.
    kb.clusters = clusters.drain(..).collect();
    let assignments: Vec<usize> = rows.iter().map(|r| kb.assign_row(r)).collect();
    // Initial ingest is two-pass per cluster: pool → reference model →
    // bin by explained-away intensity.
    let mut per_cluster: Vec<Vec<&TransferLog>> = vec![Vec::new(); k];
    for (row, &c) in rows.iter().zip(&assignments) {
        per_cluster[c].push(row);
    }
    for (c, cluster_rows) in per_cluster.into_iter().enumerate() {
        kb.clusters[c].ingest_initial(&cluster_rows);
    }
    for (ci, cluster) in kb.clusters.iter_mut().enumerate() {
        cluster.rebuild(&config.region, config.seed.wrapping_add(ci as u64));
    }
    Ok(kb)
}

/// Additive refresh: route new rows to existing clusters, merge into the
/// sufficient statistics, rebuild only the touched clusters. Old log
/// partitions are never re-read — the paper's "we do not need to ...
/// perform analysis on whole log (old log + new log)".
pub fn update(kb: &mut KnowledgeBase, new_rows: &[TransferLog]) -> Result<()> {
    anyhow::ensure!(!kb.clusters.is_empty(), "offline update: empty knowledge base");
    if new_rows.is_empty() {
        return Ok(());
    }
    let mut touched = vec![false; kb.clusters.len()];
    let assignments: Vec<usize> = new_rows.iter().map(|r| kb.assign_row(r)).collect();
    for (row, &c) in new_rows.iter().zip(&assignments) {
        kb.clusters[c].push(row);
        touched[c] = true;
    }
    let region = kb.region_config;
    let seed = kb.seed;
    for (ci, cluster) in kb.clusters.iter_mut().enumerate() {
        if touched[ci] {
            cluster.rebuild(&region, seed.wrapping_add(ci as u64));
        }
    }
    kb.built_through_day = kb.built_through_day.max(
        new_rows
            .iter()
            .map(|r| (r.t_start / DAY_S) as u64)
            .max()
            .unwrap_or(0),
    );
    Ok(())
}

/// Additive refresh from sufficient-statistics rows — the zero-copy
/// ingest path. Each `SuffRow` expands to a heap-free `TransferLog`
/// proxy (see [`SuffRow::to_log`]) and flows through the exact same
/// [`update`] code, in the same order, so the resulting statistics are
/// bit-identical to a refresh from the full rows: Welford accumulation
/// is order-sensitive, and sharing the code path (rather than
/// maintaining a parallel one) is what makes the formats' equivalence
/// structural.
pub fn update_suff(kb: &mut KnowledgeBase, new_rows: &[SuffRow]) -> Result<()> {
    let proxies: Vec<TransferLog> = new_rows.iter().map(SuffRow::to_log).collect();
    update(kb, &proxies)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::knowledge::RequestInfo;
    use crate::sim::testbed::Testbed;

    fn history(days: u64, start_day: u64, seed: u64) -> Vec<TransferLog> {
        let mut rows = generate(
            &Testbed::xsede(),
            &GenConfig { days, arrivals_per_hour: 30.0, start_day, seed },
        );
        rows.extend(generate(
            &Testbed::didclab(),
            &GenConfig { days, arrivals_per_hour: 20.0, start_day, seed: seed ^ 1 },
        ));
        rows
    }

    #[test]
    fn build_produces_surfaces_and_regions() {
        let rows = history(6, 0, 11);
        let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap();
        assert!(kb.clusters.len() >= 2, "k={}", kb.clusters.len());
        let with_surfaces = kb.clusters.iter().filter(|c| !c.surfaces.is_empty()).count();
        assert!(with_surfaces >= 2, "only {with_surfaces} clusters built surfaces");
        // Surfaces are sorted by intensity.
        for c in &kb.clusters {
            for w in c.surfaces.windows(2) {
                assert!(w[0].intensity <= w[1].intensity);
            }
            if c.surfaces.len() >= 2 {
                assert!(!c.region.union().is_empty());
            }
        }
        assert_eq!(kb.built_through_day, 5);
    }

    #[test]
    fn query_separates_testbeds() {
        let rows = history(6, 0, 13);
        let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap();
        let xsede_req = RequestInfo {
            rtt_ms: 40.0,
            bandwidth_mbps: 10_000.0,
            tcp_buffer_mb: 48.0,
            disk_mbps: 1_200.0,
            avg_file_mb: 100.0,
            num_files: 100,
        };
        let lan_req = RequestInfo {
            rtt_ms: 0.2,
            bandwidth_mbps: 1_000.0,
            tcp_buffer_mb: 10.0,
            disk_mbps: 90.0,
            avg_file_mb: 100.0,
            num_files: 100,
        };
        let cx = kb.query(&xsede_req).unwrap();
        let cl = kb.query(&lan_req).unwrap();
        assert!(
            !std::ptr::eq(cx, cl),
            "10 Gbps WAN and 1 Gbps LAN requests must hit different clusters"
        );
    }

    #[test]
    fn centroid_distance_ranks_the_matching_network_first() {
        // Two single-network KBs: the donor-selection metric must place
        // an xsede-shaped request nearer the xsede KB's clusters than
        // the didclab KB's (and vice versa) — this is what cold-start
        // borrowing ranks donors by.
        let kb_x = build(
            &generate(
                &Testbed::xsede(),
                &GenConfig { days: 4, arrivals_per_hour: 25.0, start_day: 0, seed: 23 },
            ),
            &OfflineConfig::default(),
            &mut NativeAssign,
        )
        .unwrap();
        let kb_d = build(
            &generate(
                &Testbed::didclab(),
                &GenConfig { days: 4, arrivals_per_hour: 25.0, start_day: 0, seed: 29 },
            ),
            &OfflineConfig::default(),
            &mut NativeAssign,
        )
        .unwrap();
        let xsede_req = RequestInfo {
            rtt_ms: 40.0,
            bandwidth_mbps: 10_000.0,
            tcp_buffer_mb: 48.0,
            disk_mbps: 1_200.0,
            avg_file_mb: 100.0,
            num_files: 100,
        };
        let lan_req = RequestInfo {
            rtt_ms: 0.2,
            bandwidth_mbps: 1_000.0,
            tcp_buffer_mb: 10.0,
            disk_mbps: 90.0,
            avg_file_mb: 100.0,
            num_files: 100,
        };
        assert!(
            kb_x.centroid_distance(&xsede_req.raw_features())
                < kb_d.centroid_distance(&xsede_req.raw_features()),
            "xsede request must sit nearer the xsede KB"
        );
        assert!(
            kb_d.centroid_distance(&lan_req.raw_features())
                < kb_x.centroid_distance(&lan_req.raw_features()),
            "didclab request must sit nearer the didclab KB"
        );
    }

    #[test]
    fn additive_update_equivalent_to_full_rebuild_stats() {
        let all = history(6, 0, 17);
        let (old, new): (Vec<_>, Vec<_>) =
            all.iter().cloned().partition(|r| r.t_start < 4.0 * DAY_S);
        let cfg = OfflineConfig::default();
        // Build on old, update with new.
        let mut kb_inc = build(&old, &cfg, &mut NativeAssign).unwrap();
        update(&mut kb_inc, &new).unwrap();
        // Build on old, then push new rows through the same centroids
        // manually — stat totals must match exactly (additivity).
        let kb_ref = {
            let mut kb = build(&old, &cfg, &mut NativeAssign).unwrap();
            update(&mut kb, &new).unwrap();
            kb
        };
        let total_inc: u64 = kb_inc.clusters.iter().map(|c| c.n_rows).sum();
        let total_ref: u64 = kb_ref.clusters.iter().map(|c| c.n_rows).sum();
        assert_eq!(total_inc, all.len() as u64);
        assert_eq!(total_inc, total_ref);
        assert_eq!(kb_inc.built_through_day, 5);
    }

    #[test]
    fn update_suff_bit_identical_to_update() {
        let all = history(6, 0, 31);
        let (old, new): (Vec<_>, Vec<_>) =
            all.iter().cloned().partition(|r| r.t_start < 4.0 * DAY_S);
        let cfg = OfflineConfig::default();
        let mut kb_full = build(&old, &cfg, &mut NativeAssign).unwrap();
        let mut kb_suff = kb_full.clone();
        update(&mut kb_full, &new).unwrap();
        let suff: Vec<SuffRow> = new.iter().map(TransferLog::suff).collect();
        update_suff(&mut kb_suff, &suff).unwrap();
        // Byte-identical serialized KBs — not approximately equal.
        assert_eq!(
            kb_full.to_json().to_string_compact(),
            kb_suff.to_json().to_string_compact()
        );
    }

    #[test]
    fn knowledge_base_roundtrips_through_json() {
        let rows = history(4, 0, 19);
        let kb = build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap();
        let text = kb.to_json().to_string_compact();
        let back =
            KnowledgeBase::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.clusters.len(), kb.clusters.len());
        for (a, b) in back.clusters.iter().zip(&kb.clusters) {
            assert_eq!(a.n_rows, b.n_rows);
            assert_eq!(a.surfaces.len(), b.surfaces.len());
            for (sa, sb) in a.surfaces.iter().zip(&b.surfaces) {
                assert_eq!(sa.argmax.0, sb.argmax.0, "argmax must survive roundtrip");
                assert!((sa.argmax.1 - sb.argmax.1).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn build_rejects_empty() {
        assert!(build(&[], &OfflineConfig::default(), &mut NativeAssign).is_err());
    }
}
