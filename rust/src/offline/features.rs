//! Log-row → feature-vector mapping for clustering.
//!
//! The paper clusters historical logs by transfer characteristics; we
//! use the network and dataset attributes (NOT the tunable parameters —
//! rows with different θ must land in the same cluster so the surface
//! over θ can be built from them).

use crate::logs::record::TransferLog;

/// Feature dimensionality (also the `D` of the PJRT pairwise artifact).
pub const FEATURE_DIM: usize = 6;

/// Raw (unnormalized) features. Heavy-tailed quantities are logged.
pub fn raw_features(log: &TransferLog) -> [f64; FEATURE_DIM] {
    let bdp_mb = log.bandwidth_mbps * 1e6 * (log.rtt_ms / 1e3) / 8.0 / 1e6;
    [
        log.avg_file_mb.max(1e-3).ln(),
        (log.num_files as f64).max(1.0).ln(),
        log.rtt_ms.max(1e-3).ln(),
        log.bandwidth_mbps.max(1.0).ln(),
        (log.tcp_buffer_mb / bdp_mb.max(1e-6)).max(1e-6).ln(),
        log.disk_mbps.max(1.0).ln(),
    ]
}

/// Per-dimension z-score normalizer (fit once on the training history;
/// stored in the knowledge base so online queries normalize the same
/// way).
#[derive(Debug, Clone, PartialEq)]
pub struct Normalizer {
    pub mean: [f64; FEATURE_DIM],
    pub std: [f64; FEATURE_DIM],
}

impl Normalizer {
    pub fn fit(rows: &[TransferLog]) -> Normalizer {
        let mut mean = [0.0; FEATURE_DIM];
        let mut m2 = [0.0; FEATURE_DIM];
        let mut count = 0.0;
        for row in rows {
            count += 1.0;
            let f = raw_features(row);
            for d in 0..FEATURE_DIM {
                let delta = f[d] - mean[d];
                mean[d] += delta / count;
                m2[d] += delta * (f[d] - mean[d]);
            }
        }
        let mut std = [1.0; FEATURE_DIM];
        if count > 1.0 {
            for d in 0..FEATURE_DIM {
                let s = (m2[d] / count).sqrt();
                std[d] = if s > 1e-9 { s } else { 1.0 };
            }
        }
        Normalizer { mean, std }
    }

    pub fn apply(&self, raw: &[f64; FEATURE_DIM]) -> [f64; FEATURE_DIM] {
        let mut out = [0.0; FEATURE_DIM];
        for d in 0..FEATURE_DIM {
            out[d] = (raw[d] - self.mean[d]) / self.std[d];
        }
        out
    }

    pub fn features(&self, log: &TransferLog) -> [f64; FEATURE_DIM] {
        self.apply(&raw_features(log))
    }

    /// Flatten a batch into a row-major `n × FEATURE_DIM` buffer.
    pub fn feature_matrix(&self, rows: &[TransferLog]) -> Vec<f64> {
        let mut out = Vec::with_capacity(rows.len() * FEATURE_DIM);
        for row in rows {
            out.extend_from_slice(&self.features(row));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    #[test]
    fn params_do_not_affect_features() {
        let mut a = sample_log();
        let mut b = sample_log();
        a.cc = 1;
        a.p = 1;
        a.pp = 1;
        b.cc = 16;
        b.p = 16;
        b.pp = 32;
        // Throughput also must not leak into clustering features.
        a.throughput_mbps = 10.0;
        b.throughput_mbps = 9_000.0;
        assert_eq!(raw_features(&a), raw_features(&b));
    }

    #[test]
    fn normalizer_zero_mean_unit_std() {
        let mut rows = Vec::new();
        for i in 0..50 {
            let mut r = sample_log();
            r.avg_file_mb = 1.0 + i as f64;
            r.num_files = 10 + i;
            rows.push(r);
        }
        let norm = Normalizer::fit(&rows);
        let feats = norm.feature_matrix(&rows);
        for d in 0..2 {
            // Varying dims only.
            let vals: Vec<f64> = (0..rows.len()).map(|i| feats[i * FEATURE_DIM + d]).collect();
            assert!(crate::util::stats::mean(&vals).abs() < 1e-9);
            assert!((crate::util::stats::std_pop(&vals) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn constant_dims_do_not_blow_up() {
        let rows = vec![sample_log(), sample_log(), sample_log()];
        let norm = Normalizer::fit(&rows);
        let f = norm.features(&rows[0]);
        assert!(f.iter().all(|x| x.is_finite()));
    }
}
