//! Calinski–Harabasz index for selecting the number of clusters m
//! (paper Eq. 3–5): CH(m) = [Φ_between/(m−1)] / [Φ_within/(n−m)],
//! larger is better.

use super::kmeans::{kmeans_pp, AssignBackend, KMeansResult};
use crate::util::rng::Rng;
use anyhow::Result;

/// CH score for a given flat clustering.
pub fn ch_score(points: &[f64], n: usize, d: usize, result: &KMeansResult) -> f64 {
    let k = result.k;
    if k < 2 || n <= k {
        return 0.0;
    }
    // Overall mean.
    let mut overall = vec![0.0; d];
    for i in 0..n {
        for j in 0..d {
            overall[j] += points[i * d + j] / n as f64;
        }
    }
    // Within = inertia (sum of squared distances to assigned centroid);
    // Between = Σ_k n_k·|c_k − x̄|².
    let mut counts = vec![0usize; k];
    for &a in &result.assignments {
        counts[a as usize] += 1;
    }
    let mut between = 0.0;
    for c in 0..k {
        let mut dist = 0.0;
        for j in 0..d {
            let diff = result.centroids[c * d + j] - overall[j];
            dist += diff * diff;
        }
        between += counts[c] as f64 * dist;
    }
    let within = result.inertia;
    if within <= 1e-18 {
        return f64::INFINITY;
    }
    (between / (k - 1) as f64) / (within / (n - k) as f64)
}

/// Run k-means over `k_range` and return (best_k, best_result,
/// all_scores). The paper: "Largest CH(m) score is preferable".
pub fn select_k(
    points: &[f64],
    n: usize,
    d: usize,
    k_range: std::ops::RangeInclusive<usize>,
    rng: &mut Rng,
    backend: &mut dyn AssignBackend,
) -> Result<(usize, KMeansResult, Vec<(usize, f64)>)> {
    let mut best: Option<(usize, KMeansResult, f64)> = None;
    let mut scores = Vec::new();
    for k in k_range {
        if k < 2 || k > n {
            continue;
        }
        let res = kmeans_pp(points, n, d, k, rng, backend, 60)?;
        let score = ch_score(points, n, d, &res);
        scores.push((k, score));
        let better = match &best {
            None => true,
            Some((_, _, s)) => score > *s,
        };
        if better {
            best = Some((k, res, score));
        }
    }
    let (k, res, _) = best.ok_or_else(|| anyhow::anyhow!("select_k: empty k range"))?;
    Ok((k, res, scores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::kmeans::tests::blobs;
    use crate::offline::kmeans::NativeAssign;

    #[test]
    fn ch_peaks_at_true_k() {
        let mut rng = Rng::new(21);
        let (pts, n, d) = blobs(&mut rng, 50);
        let (k, _, scores) = select_k(&pts, n, d, 2..=8, &mut rng, &mut NativeAssign).unwrap();
        assert_eq!(k, 3, "scores: {scores:?}");
    }

    #[test]
    fn ch_score_zero_for_degenerate() {
        let mut rng = Rng::new(2);
        let (pts, n, d) = blobs(&mut rng, 10);
        let res = kmeans_pp(&pts, n, d, 1, &mut rng, &mut NativeAssign, 10).unwrap();
        assert_eq!(ch_score(&pts, n, d, &res), 0.0);
    }

    #[test]
    fn empty_range_errors() {
        let mut rng = Rng::new(2);
        let (pts, n, d) = blobs(&mut rng, 5);
        assert!(select_k(&pts, n, d, 9..=8, &mut rng, &mut NativeAssign).is_err());
    }
}
