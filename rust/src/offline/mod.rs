//! Offline knowledge discovery (paper §3.1): clustering of the transfer
//! history, throughput-surface construction, Gaussian confidence
//! regions, surface maxima, contending-transfer accounting, and
//! suitable-sampling-region extraction — persisted as an additive
//! knowledge base the online module queries in constant time.

pub mod chindex;
pub mod contending;
pub mod features;
pub mod hac;
pub mod kmeans;
pub mod knowledge;
pub mod maxima;
pub mod pipeline;
pub mod regions;
pub mod surface;

pub use knowledge::{ClusterKnowledge, KnowledgeBase, RequestInfo};
pub use pipeline::{build, update, OfflineConfig};
pub use surface::{SurfaceModel, SurfaceStats, NUM_LOAD_BINS};
