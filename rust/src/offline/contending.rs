//! Known-contending-transfer accounting (paper §3.1.3).
//!
//! Classifies the five overlap categories, explains away their rates
//! from the observed throughput, and reduces the residual to the
//! external-load intensity heuristic I_s = (bw − th_out)/bw (Eq. 20) —
//! the quantity surfaces are binned by and the online module bisects
//! over.

use crate::logs::record::TransferLog;
use crate::sim::traffic::ContendKind;
use crate::util::stats::mean;

/// Per-category aggregate over a set of rows (reporting + diagnostics).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ContentionSummary {
    /// Mean aggregate rate per category (Mbps).
    pub mean_rate_mbps: [f64; 5],
    /// Fraction of rows with non-zero contention per category.
    pub presence: [f64; 5],
    pub rows: usize,
}

pub fn summarize(rows: &[TransferLog]) -> ContentionSummary {
    let mut s = ContentionSummary { rows: rows.len(), ..Default::default() };
    if rows.is_empty() {
        return s;
    }
    for k in 0..5 {
        let rates: Vec<f64> = rows.iter().map(|r| r.contending_mbps[k]).collect();
        s.mean_rate_mbps[k] = mean(&rates);
        s.presence[k] =
            rows.iter().filter(|r| r.contending_mbps[k] > 0.0).count() as f64 / rows.len() as f64;
    }
    s
}

/// The per-row intensity after explaining away known contenders
/// (Assumption 2: residual fluctuation ⇐ external load).
pub fn intensity(row: &TransferLog) -> f64 {
    row.load_intensity()
}

/// Mean intensity over rows (used to refine a load bin's representative
/// intensity away from the raw bin center).
pub fn mean_intensity(rows: &[TransferLog]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    mean(&rows.iter().map(intensity).collect::<Vec<f64>>())
}

/// Human-readable category table.
pub fn render_summary(s: &ContentionSummary) -> String {
    let mut out = String::from("category    mean_rate(Mbps)  presence\n");
    for (i, kind) in ContendKind::all().iter().enumerate() {
        out.push_str(&format!(
            "{:<11} {:>15.1} {:>9.2}\n",
            kind.name(),
            s.mean_rate_mbps[i],
            s.presence[i]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    #[test]
    fn summary_aggregates_categories() {
        let mut a = sample_log();
        a.contending_mbps = [100.0, 0.0, 0.0, 0.0, 0.0];
        let mut b = sample_log();
        b.contending_mbps = [300.0, 50.0, 0.0, 0.0, 0.0];
        let s = summarize(&[a, b]);
        assert_eq!(s.rows, 2);
        assert!((s.mean_rate_mbps[0] - 200.0).abs() < 1e-9);
        assert!((s.mean_rate_mbps[1] - 25.0).abs() < 1e-9);
        assert_eq!(s.presence[0], 1.0);
        assert_eq!(s.presence[1], 0.5);
        assert_eq!(s.presence[2], 0.0);
    }

    #[test]
    fn intensity_decreases_with_explained_contention() {
        let mut quiet = sample_log();
        quiet.throughput_mbps = 3_000.0;
        quiet.contending_mbps = [0.0; 5];
        let mut contended = quiet.clone();
        contended.contending_mbps = [4_000.0, 0.0, 0.0, 0.0, 0.0];
        // Same achieved throughput, but the contended row explains the
        // missing bandwidth with a *known* transfer ⇒ lower inferred
        // external intensity.
        assert!(intensity(&contended) < intensity(&quiet));
    }

    #[test]
    fn render_has_all_five_rows() {
        let s = summarize(&[sample_log()]);
        let text = render_summary(&s);
        for kind in ContendKind::all() {
            assert!(text.contains(kind.name()), "missing {}", kind.name());
        }
    }

    #[test]
    fn empty_rows_mean_zero() {
        assert_eq!(mean_intensity(&[]), 0.0);
        assert_eq!(summarize(&[]).rows, 0);
    }
}
