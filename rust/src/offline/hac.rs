//! Hierarchical Agglomerative Clustering with UPGMA linkage — the
//! paper's clustering alternative (2), kept as a cross-check for the
//! k-means++ pipeline (§3.1, Eq. 2).
//!
//! UPGMA: the distance between clusters is the *unweighted average* of
//! pairwise point distances; implemented with the standard
//! Lance–Williams update on the proximity matrix, O(n³) worst case —
//! fine for the sub-sampled validation use (n ≤ ~1000).

use anyhow::Result;

/// A merge step: clusters `a` and `b` (ids) merged at `height` into a
/// new cluster with id `n + step`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Merge {
    pub a: usize,
    pub b: usize,
    pub height: f64,
}

/// Full UPGMA dendrogram over `n` points.
#[derive(Debug, Clone)]
pub struct Dendrogram {
    pub n: usize,
    pub merges: Vec<Merge>,
}

/// Run UPGMA on row-major `points` (`n × d`), Euclidean metric.
pub fn upgma(points: &[f64], n: usize, d: usize) -> Result<Dendrogram> {
    anyhow::ensure!(n >= 1, "hac: empty input");
    anyhow::ensure!(points.len() == n * d, "hac: bad buffer shape");
    // Active cluster list: (id, size). Proximity matrix as a dense
    // lower-triangular map over active indices.
    let mut active: Vec<(usize, usize)> = (0..n).map(|i| (i, 1usize)).collect();
    let mut prox = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..i {
            let mut dist = 0.0;
            for t in 0..d {
                let diff = points[i * d + t] - points[j * d + t];
                dist += diff * diff;
            }
            let dist = dist.sqrt();
            prox[i * n + j] = dist;
            prox[j * n + i] = dist;
        }
    }
    // Map from active slot → row in prox (rows are reused in place).
    let mut rows: Vec<usize> = (0..n).collect();
    let mut merges = Vec::with_capacity(n.saturating_sub(1));
    let mut next_id = n;
    while active.len() > 1 {
        // Find the closest active pair.
        let m = active.len();
        let (mut bi, mut bj, mut bd) = (0usize, 1usize, f64::INFINITY);
        for i in 0..m {
            for j in 0..i {
                let dist = prox[rows[i] * n + rows[j]];
                if dist < bd {
                    bd = dist;
                    bi = i;
                    bj = j;
                }
            }
        }
        let (id_i, sz_i) = active[bi];
        let (id_j, sz_j) = active[bj];
        merges.push(Merge { a: id_i, b: id_j, height: bd });
        // Lance–Williams UPGMA update into row of bi:
        // d(new, k) = (sz_i·d(i,k) + sz_j·d(j,k)) / (sz_i + sz_j)
        let (ri, rj) = (rows[bi], rows[bj]);
        for t in 0..m {
            if t == bi || t == bj {
                continue;
            }
            let rt = rows[t];
            let dnew = (sz_i as f64 * prox[ri * n + rt] + sz_j as f64 * prox[rj * n + rt])
                / (sz_i + sz_j) as f64;
            prox[ri * n + rt] = dnew;
            prox[rt * n + ri] = dnew;
        }
        active[bi] = (next_id, sz_i + sz_j);
        next_id += 1;
        active.swap_remove(bj);
        rows.swap_remove(bj);
    }
    Ok(Dendrogram { n, merges })
}

impl Dendrogram {
    /// Cut the tree into `k` flat clusters; returns per-point labels in
    /// `[0, k)`.
    pub fn cut(&self, k: usize) -> Vec<usize> {
        let k = k.clamp(1, self.n.max(1));
        // Union-find over the first n−k merges.
        let total = self.n + self.merges.len();
        let mut parent: Vec<usize> = (0..total).collect();
        fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        let take = self.n.saturating_sub(k);
        for (step, m) in self.merges.iter().take(take).enumerate() {
            let new_id = self.n + step;
            let ra = find(&mut parent, m.a);
            let rb = find(&mut parent, m.b);
            parent[ra] = new_id;
            parent[rb] = new_id;
        }
        // Compact root ids to 0..k.
        let mut labels = vec![0usize; self.n];
        let mut map: std::collections::BTreeMap<usize, usize> = Default::default();
        for i in 0..self.n {
            let root = find(&mut parent, i);
            let next = map.len();
            let label = *map.entry(root).or_insert(next);
            labels[i] = label;
        }
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::kmeans::tests::blobs;
    use crate::util::rng::Rng;

    #[test]
    fn merge_heights_nondecreasing() {
        let mut rng = Rng::new(2);
        let (pts, n, d) = blobs(&mut rng, 15);
        let tree = upgma(&pts, n, d).unwrap();
        assert_eq!(tree.merges.len(), n - 1);
        for w in tree.merges.windows(2) {
            assert!(w[1].height >= w[0].height - 1e-9, "heights must be monotone (UPGMA)");
        }
    }

    #[test]
    fn cut_recovers_blobs() {
        let mut rng = Rng::new(8);
        let (pts, n, d) = blobs(&mut rng, 25);
        let tree = upgma(&pts, n, d).unwrap();
        let labels = tree.cut(3);
        assert_eq!(labels.len(), n);
        for blob in 0..3 {
            let members = &labels[blob * 25..(blob + 1) * 25];
            assert!(members.iter().all(|&l| l == members[0]), "blob {blob} split by HAC");
        }
        // The three blobs get three distinct labels.
        let mut distinct: Vec<usize> = labels.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert_eq!(distinct.len(), 3);
    }

    #[test]
    fn agrees_with_kmeans_on_separated_data() {
        use crate::offline::kmeans::{kmeans_pp, NativeAssign};
        let mut rng = Rng::new(12);
        let (pts, n, d) = blobs(&mut rng, 20);
        let tree = upgma(&pts, n, d).unwrap();
        let hac_labels = tree.cut(3);
        let km = kmeans_pp(&pts, n, d, 3, &mut rng, &mut NativeAssign, 50).unwrap();
        // Same partition up to label permutation: check pairwise
        // co-membership agreement.
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..n {
            for j in 0..i {
                let same_hac = hac_labels[i] == hac_labels[j];
                let same_km = km.assignments[i] == km.assignments[j];
                total += 1;
                if same_hac == same_km {
                    agree += 1;
                }
            }
        }
        assert_eq!(agree, total, "HAC and k-means disagree on separated blobs");
    }

    #[test]
    fn single_point_and_k_one() {
        let tree = upgma(&[1.0, 2.0], 1, 2).unwrap();
        assert!(tree.merges.is_empty());
        assert_eq!(tree.cut(1), vec![0]);
        let tree2 = upgma(&[0.0, 0.0, 5.0, 5.0], 2, 2).unwrap();
        assert_eq!(tree2.cut(1), vec![0, 0]);
        assert_eq!(tree2.cut(2), vec![0, 1]);
    }
}
