//! Throughput-surface construction (paper §3.1.1).
//!
//! Per (cluster × external-load bin) the pipeline maintains **additive
//! sufficient statistics** — a Welford accumulator per parameter-grid
//! cell — and builds from them:
//!
//! * a **piecewise bicubic spline surface** `f(p, cc)` over the knot
//!   grid (the paper's Fig. 1 surfaces),
//! * a **1-D cubic-spline pipelining factor** `s(pp)` (Fig. 2) — the
//!   paper models pp separately from (p, cc) "due to their difference
//!   in characteristic"; we compose them multiplicatively,
//!   `th(p,cc,pp) = f(p,cc) · s(pp)` with `max s = 1`, alternately
//!   refit so the decomposition is self-consistent,
//! * a **Gaussian confidence region** (Eq. 15–17, Fig. 3a) from the
//!   pooled within-cell variance,
//! * the **precomputed argmax** over the bounded integer domain
//!   (§3.1.2).
//!
//! The quadratic/cubic regression comparators of Fig. 3b are fit via
//! `crate::math::polyfit` from the same observations.

use crate::logs::generate::PARAM_KNOTS;
use crate::logs::record::TransferLog;
use crate::math::bicubic::BicubicSurface;
use crate::math::spline::CubicSpline;
use crate::sim::params::{Params, BETA, PP_LEVELS};
use crate::util::json::{Json, JsonError};
use crate::util::stats::Welford;
use anyhow::Result;

/// Number of external-load-intensity bins per cluster — each bin gets
/// its own surface, and the online module bisects across them.
pub const NUM_LOAD_BINS: usize = 5;

/// Map an intensity in [0,1] to its bin.
pub fn load_bin(intensity: f64) -> usize {
    ((intensity.clamp(0.0, 1.0) * NUM_LOAD_BINS as f64) as usize).min(NUM_LOAD_BINS - 1)
}

/// Representative intensity of a bin (its center).
pub fn bin_center(bin: usize) -> f64 {
    (bin as f64 + 0.5) / NUM_LOAD_BINS as f64
}

fn knot_index(knots: &[u32], v: u32) -> usize {
    // Nearest knot (log rows always use exact knots; online samples may
    // not, so snap to nearest).
    let mut best = (0usize, u32::MAX);
    for (i, &k) in knots.iter().enumerate() {
        let d = k.abs_diff(v);
        if d < best.1 {
            best = (i, d);
        }
    }
    best.0
}

/// Additive per-cell statistics for one surface (one load bin).
#[derive(Debug, Clone, PartialEq)]
pub struct SurfaceStats {
    /// Welford per (p-knot, cc-knot, pp-level), row-major.
    pub cells: Vec<Welford>,
}

impl Default for SurfaceStats {
    fn default() -> Self {
        Self::new()
    }
}

impl SurfaceStats {
    pub fn new() -> SurfaceStats {
        SurfaceStats {
            cells: vec![Welford::new(); PARAM_KNOTS.len() * PARAM_KNOTS.len() * PP_LEVELS.len()],
        }
    }

    #[inline]
    pub(crate) fn idx(pi: usize, ci: usize, li: usize) -> usize {
        (pi * PARAM_KNOTS.len() + ci) * PP_LEVELS.len() + li
    }

    pub fn cell(&self, pi: usize, ci: usize, li: usize) -> &Welford {
        &self.cells[Self::idx(pi, ci, li)]
    }

    /// Record one observation.
    pub fn push(&mut self, p: u32, cc: u32, pp: u32, throughput_mbps: f64) {
        let pi = knot_index(&PARAM_KNOTS, p);
        let ci = knot_index(&PARAM_KNOTS, cc);
        let li = knot_index(&PP_LEVELS, pp);
        self.cells[Self::idx(pi, ci, li)].push(throughput_mbps);
    }

    pub fn push_log(&mut self, row: &TransferLog) {
        self.push(row.p, row.cc, row.pp, row.throughput_mbps);
    }

    /// Additive merge (the paper's periodic-offline-analysis path).
    pub fn merge(&mut self, other: &SurfaceStats) {
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            a.merge(b);
        }
    }

    pub fn total_count(&self) -> u64 {
        self.cells.iter().map(|w| w.count).sum()
    }

    pub fn to_json(&self) -> Json {
        // Compact: only non-empty cells as [idx, count, mean, m2].
        let mut arr = Vec::new();
        for (i, w) in self.cells.iter().enumerate() {
            if w.count > 0 {
                arr.push(Json::from_f64_slice(&[i as f64, w.count as f64, w.mean, w.m2]));
            }
        }
        Json::Arr(arr)
    }

    pub fn from_json(v: &Json) -> Result<SurfaceStats, JsonError> {
        let mut stats = SurfaceStats::new();
        if let Json::Arr(items) = v {
            for item in items {
                if let Json::Arr(f) = item {
                    let idx = f[0].as_f64().unwrap_or(-1.0) as usize;
                    if idx < stats.cells.len() {
                        stats.cells[idx] = Welford {
                            count: f[1].as_f64().unwrap_or(0.0) as u64,
                            mean: f[2].as_f64().unwrap_or(0.0),
                            m2: f[3].as_f64().unwrap_or(0.0),
                        };
                    }
                }
            }
        }
        Ok(stats)
    }
}

/// A built surface model for one (cluster, load-bin).
#[derive(Debug, Clone)]
pub struct SurfaceModel {
    /// Representative external-load intensity (bin center refined to the
    /// observed mean intensity when available).
    pub intensity: f64,
    /// f(p, cc) bicubic spline over the knot grid.
    pub surface: BicubicSurface,
    /// s(pp) pipelining factor spline (max ≈ 1).
    pub pp_curve: CubicSpline,
    /// Pooled within-cell measurement σ (Gaussian confidence, Eq. 17).
    pub sigma: f64,
    /// Per-cell σ over the knot grid (same indexing as `SurfaceStats`);
    /// zero where the cell lacks repeated observations. Confidence
    /// bounds prefer the local σ — the pooled value mixes regimes with
    /// very different magnitudes and over-widens the region.
    pub cell_sigma: Vec<f64>,
    /// Precomputed argmax over the bounded integer domain and its value.
    pub argmax: (Params, f64),
    pub n_obs: u64,
}

impl SurfaceModel {
    /// Build from sufficient statistics. Errors when the bin has too few
    /// observations to support a surface.
    pub fn build(stats: &SurfaceStats, intensity: f64) -> Result<SurfaceModel> {
        let np = PARAM_KNOTS.len();
        let nl = PP_LEVELS.len();
        let n_obs = stats.total_count();
        anyhow::ensure!(n_obs >= 24, "surface: too few observations ({n_obs})");

        // Multiplicative decomposition th = f(p,cc)·s(pp), alternating
        // least squares on the cell means (weights = counts).
        let mut s = vec![1.0; nl];
        let mut f_grid = vec![f64::NAN; np * np];
        for _round in 0..3 {
            // f from s.
            for pi in 0..np {
                for ci in 0..np {
                    let (mut num, mut den) = (0.0, 0.0);
                    for li in 0..nl {
                        let w = stats.cell(pi, ci, li);
                        if w.count > 0 && s[li] > 1e-9 {
                            num += w.count as f64 * w.mean / s[li];
                            den += w.count as f64;
                        }
                    }
                    f_grid[pi * np + ci] = if den > 0.0 { num / den } else { f64::NAN };
                }
            }
            // s from f.
            for (li, s_l) in s.iter_mut().enumerate() {
                let (mut num, mut den) = (0.0, 0.0);
                for pi in 0..np {
                    for ci in 0..np {
                        let w = stats.cell(pi, ci, li);
                        let f = f_grid[pi * np + ci];
                        if w.count > 0 && f.is_finite() && f > 1e-9 {
                            num += w.count as f64 * w.mean / f;
                            den += w.count as f64;
                        }
                    }
                }
                if den > 0.0 {
                    *s_l = num / den;
                }
            }
            // Normalize: max s = 1 so f carries the magnitude.
            let smax = s.iter().cloned().fold(1e-9, f64::max);
            for s_l in s.iter_mut() {
                *s_l /= smax;
            }
        }

        // Fill unobserved (p,cc) cells by iterative neighbor averaging.
        fill_missing(&mut f_grid, np, np)?;

        // Count-weighted smoothing: cells observed once or twice carry
        // mostly measurement noise, which the interpolating spline would
        // otherwise faithfully reproduce — and the argmax would chase
        // noise spikes. Shrink low-count cells toward their neighbour
        // mean (κ pseudo-counts of neighbourhood evidence).
        let mut counts_grid = vec![0.0; np * np];
        for pi in 0..np {
            for ci in 0..np {
                counts_grid[pi * np + ci] = (0..nl)
                    .map(|li| stats.cell(pi, ci, li).count as f64)
                    .sum();
            }
        }
        let kappa = 4.0;
        let snapshot = f_grid.clone();
        for pi in 0..np {
            for ci in 0..np {
                let mut nsum = 0.0;
                let mut nw = 0.0;
                let mut add = |r: isize, c: isize| {
                    if r >= 0 && r < np as isize && c >= 0 && c < np as isize {
                        nsum += snapshot[r as usize * np + c as usize];
                        nw += 1.0;
                    }
                };
                add(pi as isize - 1, ci as isize);
                add(pi as isize + 1, ci as isize);
                add(pi as isize, ci as isize - 1);
                add(pi as isize, ci as isize + 1);
                if nw > 0.0 {
                    let own_w = counts_grid[pi * np + ci];
                    let neighbor_mean = nsum / nw;
                    f_grid[pi * np + ci] = (own_w * snapshot[pi * np + ci]
                        + kappa * neighbor_mean)
                        / (own_w + kappa);
                }
            }
        }

        let p_knots: Vec<f64> = PARAM_KNOTS.iter().map(|&k| k as f64).collect();
        let surface = BicubicSurface::fit(&p_knots, &p_knots, &f_grid)?;
        let pp_x: Vec<f64> = PP_LEVELS.iter().map(|&k| k as f64).collect();
        let pp_curve = CubicSpline::fit(&pp_x, &s)?;

        // Pooled within-cell variance (paper Eq. 17) + per-cell σ.
        let (mut m2_sum, mut count_sum) = (0.0, 0.0);
        let mut cell_sigma = vec![0.0; stats.cells.len()];
        for (i, w) in stats.cells.iter().enumerate() {
            if w.count > 1 {
                m2_sum += w.m2;
                count_sum += w.count as f64;
                cell_sigma[i] = w.std_pop();
            }
        }
        let sigma = if count_sum > 0.0 { (m2_sum / count_sum).sqrt() } else { 0.0 };

        let mut model = SurfaceModel {
            intensity,
            surface,
            pp_curve,
            sigma,
            cell_sigma,
            argmax: (Params::new(1, 1, 1), 0.0),
            n_obs,
        };
        model.argmax = model.compute_argmax(BETA);
        Ok(model)
    }

    /// Predicted throughput at θ (clamped non-negative).
    pub fn predict(&self, params: &Params) -> f64 {
        let f = self.surface.eval(params.p as f64, params.cc as f64);
        let s = self.pp_curve.eval(params.pp as f64).clamp(0.0, 1.5);
        (f * s).max(0.0)
    }

    /// σ local to θ's grid cell when that cell had repeated
    /// observations; otherwise the pooled σ, floored at 6% of the
    /// prediction (the simulator's measurement noise scale) so the
    /// region never collapses to a point.
    pub fn sigma_at(&self, params: &Params) -> f64 {
        let pi = knot_index(&PARAM_KNOTS, params.p);
        let ci = knot_index(&PARAM_KNOTS, params.cc);
        let li = knot_index(&PP_LEVELS, params.pp);
        let local = self.cell_sigma[SurfaceStats::idx(pi, ci, li)];
        let base = if local > 0.0 { local } else { self.sigma };
        base.max(0.06 * self.predict(params))
    }

    /// Gaussian confidence interval around the prediction at θ:
    /// μ ± z·σ(θ) (z = 2 ≈ 95%).
    pub fn confidence(&self, params: &Params) -> (f64, f64) {
        let mu = self.predict(params);
        let half = 2.0 * self.sigma_at(params);
        ((mu - half).max(0.0), mu + half)
    }

    /// Does a measured throughput fall inside the confidence region?
    pub fn contains(&self, params: &Params, measured: f64) -> bool {
        let (lo, hi) = self.confidence(params);
        measured >= lo && measured <= hi
    }

    /// Exact argmax over the bounded integer domain Ψ (θ separable:
    /// maximize f over the (p, cc) integer box and s over pp levels).
    fn compute_argmax(&self, beta: u32) -> (Params, f64) {
        let mut best_pc = (1u32, 1u32, f64::NEG_INFINITY);
        for p in 1..=beta {
            for cc in 1..=beta {
                let v = self.surface.eval(p as f64, cc as f64);
                if v > best_pc.2 {
                    best_pc = (p, cc, v);
                }
            }
        }
        let mut best_pp = (PP_LEVELS[0], f64::NEG_INFINITY);
        for &pp in &PP_LEVELS {
            let s = self.pp_curve.eval(pp as f64);
            if s > best_pp.1 {
                best_pp = (pp, s);
            }
        }
        let params = Params::new(best_pc.1, best_pc.0, best_pp.0);
        let value = self.predict(&params);
        (params, value)
    }
}

/// Iteratively replace NaN cells with the mean of their defined 4-
/// neighbors; errors when the grid has no data at all.
pub fn fill_missing(grid: &mut [f64], rows: usize, cols: usize) -> Result<()> {
    anyhow::ensure!(grid.iter().any(|v| v.is_finite()), "fill_missing: empty grid");
    for _ in 0..(rows * cols) {
        let mut changed = false;
        let snapshot = grid.to_vec();
        for r in 0..rows {
            for c in 0..cols {
                if snapshot[r * cols + c].is_finite() {
                    continue;
                }
                let mut num = 0.0;
                let mut den = 0.0;
                let mut push = |rr: isize, cc: isize| {
                    if rr >= 0 && rr < rows as isize && cc >= 0 && cc < cols as isize {
                        let v = snapshot[rr as usize * cols + cc as usize];
                        if v.is_finite() {
                            num += v;
                            den += 1.0;
                        }
                    }
                };
                push(r as isize - 1, c as isize);
                push(r as isize + 1, c as isize);
                push(r as isize, c as isize - 1);
                push(r as isize, c as isize + 1);
                if den > 0.0 {
                    grid[r * cols + c] = num / den;
                    changed = true;
                }
            }
        }
        if !changed && grid.iter().all(|v| v.is_finite()) {
            break;
        }
        if grid.iter().all(|v| v.is_finite()) {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::sim::dataset::Dataset;
    use crate::sim::testbed::Testbed;
    use crate::sim::transfer::NetState;
    use crate::util::rng::Rng;

    /// Build stats by sweeping the simulator at fixed load — gives a
    /// ground-truth surface to verify against.
    pub fn stats_from_simulator(load: f64, dataset: &Dataset, reps: usize, seed: u64) -> SurfaceStats {
        let tb = Testbed::xsede();
        let mut rng = Rng::new(seed);
        let mut stats = SurfaceStats::new();
        let state = NetState::with_load(load);
        for &p in &PARAM_KNOTS {
            for &cc in &PARAM_KNOTS {
                for &pp in &PP_LEVELS {
                    for _ in 0..reps {
                        let out = tb.path.transfer(
                            dataset,
                            &Params::new(cc, p, pp),
                            &state,
                            Some(&mut rng),
                        );
                        stats.push(p, cc, pp, out.steady_mbps);
                    }
                }
            }
        }
        stats
    }

    #[test]
    fn bins_partition_unit_interval() {
        assert_eq!(load_bin(0.0), 0);
        assert_eq!(load_bin(0.999), NUM_LOAD_BINS - 1);
        assert_eq!(load_bin(1.0), NUM_LOAD_BINS - 1);
        for b in 0..NUM_LOAD_BINS {
            assert_eq!(load_bin(bin_center(b)), b);
        }
    }

    #[test]
    fn stats_are_additive() {
        let d = Dataset::new(100, 64.0);
        let a = stats_from_simulator(0.2, &d, 1, 1);
        let b = stats_from_simulator(0.2, &d, 1, 2);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.total_count(), a.total_count() + b.total_count());
        // Spot-check one cell mean equals the weighted mean.
        let ca = a.cell(2, 3, 1);
        let cb = b.cell(2, 3, 1);
        let cm = merged.cell(2, 3, 1);
        let want = (ca.mean * ca.count as f64 + cb.mean * cb.count as f64)
            / (ca.count + cb.count) as f64;
        assert!((cm.mean - want).abs() < 1e-9);
    }

    #[test]
    fn stats_json_roundtrip() {
        let d = Dataset::new(100, 64.0);
        let stats = stats_from_simulator(0.3, &d, 1, 3);
        let text = stats.to_json().to_string_compact();
        let back = SurfaceStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, stats);
    }

    #[test]
    fn model_predicts_simulator_well() {
        let d = Dataset::new(100, 64.0);
        let stats = stats_from_simulator(0.2, &d, 3, 5);
        let model = SurfaceModel::build(&stats, 0.2).unwrap();
        let tb = Testbed::xsede();
        let state = NetState::with_load(0.2);
        // Held-out points (not on the knot grid).
        let mut errs = Vec::new();
        for &(p, cc, pp) in &[(5u32, 5u32, 4u32), (7, 3, 8), (10, 2, 2), (3, 10, 16)] {
            let params = Params::new(cc, p, pp);
            let truth = tb.path.steady_rate_mbps(&d, &params, &state);
            let pred = model.predict(&params);
            errs.push(((pred - truth) / truth).abs());
        }
        let mean_err = crate::util::stats::mean(&errs);
        assert!(mean_err < 0.25, "mean rel err {mean_err:.3} errs={errs:?}");
    }

    #[test]
    fn argmax_close_to_true_optimum() {
        let d = Dataset::new(100, 64.0);
        let stats = stats_from_simulator(0.1, &d, 3, 7);
        let model = SurfaceModel::build(&stats, 0.1).unwrap();
        let tb = Testbed::xsede();
        let state = NetState::with_load(0.1);
        let (model_params, _) = model.argmax;
        let value_at_model = tb.path.steady_rate_mbps(&d, &model_params, &state);
        let (_, true_best) = tb.path.optimal(&d, &state, BETA);
        assert!(
            value_at_model > 0.8 * true_best,
            "model argmax {model_params} achieves {value_at_model:.0} of {true_best:.0}"
        );
    }

    #[test]
    fn confidence_contains_typical_measurements() {
        let d = Dataset::new(100, 64.0);
        let stats = stats_from_simulator(0.2, &d, 4, 9);
        let model = SurfaceModel::build(&stats, 0.2).unwrap();
        let tb = Testbed::xsede();
        let mut rng = Rng::new(31);
        let params = Params::new(8, 4, 4);
        let mut inside = 0;
        let total = 100;
        for _ in 0..total {
            let out = tb.path.transfer(&d, &params, &NetState::with_load(0.2), Some(&mut rng));
            if model.contains(&params, out.steady_mbps) {
                inside += 1;
            }
        }
        assert!(inside > 70, "only {inside}/{total} inside 2σ confidence");
        // And a wildly different load must usually fall outside.
        let mut outside = 0;
        for _ in 0..total {
            let out = tb.path.transfer(&d, &params, &NetState::with_load(0.85), Some(&mut rng));
            if !model.contains(&params, out.steady_mbps) {
                outside += 1;
            }
        }
        assert!(outside > 60, "only {outside}/{total} outside under heavy load");
    }

    #[test]
    fn too_few_observations_is_error() {
        let mut stats = SurfaceStats::new();
        stats.push(1, 1, 1, 100.0);
        assert!(SurfaceModel::build(&stats, 0.1).is_err());
    }

    #[test]
    fn fill_missing_completes_partial_grid() {
        let mut grid = vec![f64::NAN; 9];
        grid[4] = 5.0; // center only
        fill_missing(&mut grid, 3, 3).unwrap();
        assert!(grid.iter().all(|v| v.is_finite()));
        assert!(grid.iter().all(|&v| (v - 5.0).abs() < 1e-9));
        let mut empty = vec![f64::NAN; 4];
        assert!(fill_missing(&mut empty, 2, 2).is_err());
    }

    #[test]
    fn pp_factor_peaks_for_small_files() {
        let d = Dataset::new(5_000, 1.0); // small files
        let stats = stats_from_simulator(0.1, &d, 2, 11);
        let model = SurfaceModel::build(&stats, 0.1).unwrap();
        let s1 = model.pp_curve.eval(1.0);
        let s32 = model.pp_curve.eval(32.0);
        assert!(s32 > 2.0 * s1, "pipelining factor should rise: s(1)={s1:.3} s(32)={s32:.3}");
        assert!(model.argmax.0.pp >= 16, "argmax {}", model.argmax.0);
    }
}
