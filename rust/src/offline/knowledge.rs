//! The knowledge base — the product of offline analysis and the thing
//! the online Adaptive Sampling Module queries ("which can be answered
//! in constant time", paper §3).
//!
//! Per cluster: a stack of throughput surfaces (one per external-load
//! bin, ascending intensity), their Gaussian confidence parameters,
//! precomputed maxima, the suitable sampling region, and the additive
//! sufficient statistics that allow periodic refresh without re-reading
//! old logs.

use super::features::{raw_features, Normalizer, FEATURE_DIM};
use super::kmeans::nearest_centroid;
use super::regions::{extract, RegionConfig, SamplingRegion};
use super::surface::{bin_center, load_bin, SurfaceModel, SurfaceStats, NUM_LOAD_BINS};
use crate::logs::record::TransferLog;
use crate::util::json::{Json, JsonError};
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use anyhow::Result;

/// What the online module knows about a transfer request *before*
/// any sample transfer — enough to compute clustering features.
#[derive(Debug, Clone, Copy)]
pub struct RequestInfo {
    pub rtt_ms: f64,
    pub bandwidth_mbps: f64,
    pub tcp_buffer_mb: f64,
    pub disk_mbps: f64,
    pub avg_file_mb: f64,
    pub num_files: u64,
}

impl RequestInfo {
    /// Feature vector with the same mapping as log rows (parameters and
    /// throughput never enter the features, so a request maps exactly).
    pub fn raw_features(&self) -> [f64; FEATURE_DIM] {
        let proxy = TransferLog {
            id: 0,
            t_start: 0.0,
            pair: String::new(),
            rtt_ms: self.rtt_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            tcp_buffer_mb: self.tcp_buffer_mb,
            disk_mbps: self.disk_mbps,
            avg_file_mb: self.avg_file_mb,
            num_files: self.num_files,
            cc: 1,
            p: 1,
            pp: 1,
            throughput_mbps: 0.0,
            duration_s: 0.0,
            contending_mbps: [0.0; 5],
            contending_streams: 0,
        };
        raw_features(&proxy)
    }
}

/// Everything the offline phase learned about one cluster.
#[derive(Debug, Clone)]
pub struct ClusterKnowledge {
    /// Centroid in normalized feature space.
    pub centroid: Vec<f64>,
    /// Additive sufficient statistics pooled over *all* loads — the
    /// reference surface used to explain away the parameter effect
    /// when estimating per-row external-load intensity (Assumption 2:
    /// the raw Eq. 20 heuristic is parameter-biased — a cc=1,p=1 row
    /// looks "heavily loaded" because it is slow, not because the
    /// network was busy).
    pub pooled: SurfaceStats,
    /// Additive sufficient statistics per load bin.
    pub stats: Vec<SurfaceStats>,
    /// Refined representative intensity per bin (observed mean; falls
    /// back to the bin center when the bin is empty).
    pub intensities: Vec<f64>,
    /// Intensity-refinement accumulators (additive).
    pub intensity_acc: Vec<Welford>,
    /// Pooled reference model (rebuilt with everything else).
    pub pooled_model: Option<SurfaceModel>,
    /// Built surfaces, ascending intensity. Bins without enough data
    /// have no surface.
    pub surfaces: Vec<SurfaceModel>,
    /// Suitable sampling region R_s.
    pub region: SamplingRegion,
    pub n_rows: u64,
}

impl ClusterKnowledge {
    pub fn new(centroid: Vec<f64>) -> ClusterKnowledge {
        ClusterKnowledge {
            centroid,
            pooled: SurfaceStats::new(),
            stats: (0..NUM_LOAD_BINS).map(|_| SurfaceStats::new()).collect(),
            intensities: (0..NUM_LOAD_BINS).map(bin_center).collect(),
            intensity_acc: vec![Welford::new(); NUM_LOAD_BINS],
            pooled_model: None,
            surfaces: Vec::new(),
            region: SamplingRegion::default(),
            n_rows: 0,
        }
    }

    /// Per-row external-load intensity with the parameter effect
    /// explained away: the shortfall of achieved throughput relative to
    /// what the pooled reference predicts for the *same* parameters.
    /// Falls back to raw Eq. 20 before a reference exists.
    pub fn intensity_of(&self, row: &TransferLog) -> f64 {
        match &self.pooled_model {
            Some(m) => {
                let expected = m.predict(&row.params());
                if expected > 1.0 {
                    (1.0 - row.throughput_mbps / expected).clamp(0.0, 0.999)
                } else {
                    row.load_intensity()
                }
            }
            None => row.load_intensity(),
        }
    }

    /// Push one log row into the additive statistics, binning by the
    /// explained-away intensity (uses the pooled reference from the
    /// previous rebuild — the documented, bounded drift of the additive
    /// path).
    pub fn push(&mut self, row: &TransferLog) {
        self.pooled.push_log(row);
        let intensity = self.intensity_of(row);
        let bin = load_bin(intensity);
        self.stats[bin].push_log(row);
        self.intensity_acc[bin].push(intensity);
        self.n_rows += 1;
    }

    /// Initial two-pass ingest: pool everything, build the reference,
    /// then bin every row against it (initial build is allowed to read
    /// its rows twice; only *refresh* must be additive).
    pub fn ingest_initial(&mut self, rows: &[&TransferLog]) {
        for row in rows {
            self.pooled.push_log(row);
        }
        self.pooled_model = SurfaceModel::build(&self.pooled, 0.5).ok();
        for row in rows {
            let intensity = self.intensity_of(row);
            let bin = load_bin(intensity);
            self.stats[bin].push_log(row);
            self.intensity_acc[bin].push(intensity);
            self.n_rows += 1;
        }
    }

    /// Rebuild the derived artifacts (pooled reference, surfaces,
    /// argmaxes, regions) from the current statistics. `seed` keeps
    /// region extraction deterministic.
    pub fn rebuild(&mut self, region_config: &RegionConfig, seed: u64) {
        self.pooled_model = SurfaceModel::build(&self.pooled, 0.5).ok();
        self.surfaces.clear();
        for bin in 0..NUM_LOAD_BINS {
            self.intensities[bin] = if self.intensity_acc[bin].count > 0 {
                self.intensity_acc[bin].mean
            } else {
                bin_center(bin)
            };
            if let Ok(model) = SurfaceModel::build(&self.stats[bin], self.intensities[bin]) {
                self.surfaces.push(model);
            }
        }
        self.surfaces
            .sort_by(|a, b| a.intensity.partial_cmp(&b.intensity).unwrap());
        let mut rng = Rng::new(seed ^ 0x5EED_2E61_0500_0000);
        self.region = extract(&self.surfaces, region_config, &mut rng);
    }
}

/// The full knowledge base.
#[derive(Debug, Clone)]
pub struct KnowledgeBase {
    pub normalizer: Normalizer,
    pub clusters: Vec<ClusterKnowledge>,
    /// CH-index diagnostics from the k selection.
    pub k_scores: Vec<(usize, f64)>,
    /// Day index of the newest log partition analyzed.
    pub built_through_day: u64,
    pub region_config: RegionConfig,
    pub seed: u64,
}

impl KnowledgeBase {
    /// An empty knowledge base (no history at all): queries miss and
    /// the online path takes its cold-start fallback. A deterministic
    /// stand-in wherever the KB's *content* is irrelevant — fabric
    /// fallbacks in harnesses, golden-render fixtures.
    pub fn empty() -> KnowledgeBase {
        KnowledgeBase {
            normalizer: Normalizer { mean: [0.0; FEATURE_DIM], std: [1.0; FEATURE_DIM] },
            clusters: Vec::new(),
            k_scores: Vec::new(),
            built_through_day: 0,
            region_config: RegionConfig::default(),
            seed: 0,
        }
    }

    /// Constant-time cluster lookup for a request (nearest centroid).
    pub fn query(&self, request: &RequestInfo) -> Option<&ClusterKnowledge> {
        self.query_idx(request).map(|idx| &self.clusters[idx])
    }

    /// Index of the request's nearest cluster (`None` for an empty KB)
    /// — the same lookup [`Self::query`] performs. The probe plane keys
    /// estimate validity on it: a surface index only means something
    /// within the cluster whose stack it indexes.
    pub fn query_idx(&self, request: &RequestInfo) -> Option<usize> {
        if self.clusters.is_empty() {
            return None;
        }
        let feats = self.normalizer.apply(&request.raw_features());
        let flat: Vec<f64> = self.clusters.iter().flat_map(|c| c.centroid.clone()).collect();
        Some(nearest_centroid(&feats, &flat, self.clusters.len(), FEATURE_DIM))
    }

    /// Squared distance from a raw feature vector to the nearest
    /// cluster centroid, in this KB's normalized feature space — the
    /// quantity `query` minimizes (infinite for an empty KB). The
    /// knowledge fabric ranks donor candidates with this when a
    /// cold-starting shard borrows: the KB whose clusters sit closest
    /// to the new shard's canonical request explains it best.
    pub fn centroid_distance(&self, raw: &[f64; FEATURE_DIM]) -> f64 {
        let feats = self.normalizer.apply(raw);
        let mut best = f64::INFINITY;
        for cluster in &self.clusters {
            let mut d = 0.0;
            for dim in 0..FEATURE_DIM.min(cluster.centroid.len()) {
                let delta = feats[dim] - cluster.centroid[dim];
                d += delta * delta;
            }
            best = best.min(d);
        }
        best
    }

    /// Cluster index for a log row (used by the additive update path).
    pub fn assign_row(&self, row: &TransferLog) -> usize {
        let feats = self.normalizer.features(row);
        let flat: Vec<f64> = self.clusters.iter().flat_map(|c| c.centroid.clone()).collect();
        nearest_centroid(&feats, &flat, self.clusters.len(), FEATURE_DIM)
    }

    // ------------------------------------------------------------------
    // Serialization: sufficient statistics + metadata; surfaces and
    // regions are rebuilt on load (cheap, deterministic).
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("built_through_day", Json::Num(self.built_through_day as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("norm_mean", Json::from_f64_slice(&self.normalizer.mean))
            .set("norm_std", Json::from_f64_slice(&self.normalizer.std))
            .set(
                "k_scores",
                Json::Arr(
                    self.k_scores
                        .iter()
                        .map(|(k, s)| Json::from_f64_slice(&[*k as f64, *s]))
                        .collect(),
                ),
            )
            .set(
                "region",
                Json::from_f64_slice(&[
                    self.region_config.radius as f64,
                    self.region_config.gamma as f64,
                    self.region_config.lambda as f64,
                ]),
            );
        let clusters: Vec<Json> = self
            .clusters
            .iter()
            .map(|c| {
                let mut o = Json::obj();
                o.set("centroid", Json::from_f64_slice(&c.centroid))
                    .set("n_rows", Json::Num(c.n_rows as f64))
                    .set("pooled", c.pooled.to_json())
                    .set("stats", Json::Arr(c.stats.iter().map(|s| s.to_json()).collect()))
                    .set(
                        "intensity_acc",
                        Json::Arr(
                            c.intensity_acc
                                .iter()
                                .map(|w| {
                                    Json::from_f64_slice(&[w.count as f64, w.mean, w.m2])
                                })
                                .collect(),
                        ),
                    );
                o
            })
            .collect();
        root.set("clusters", Json::Arr(clusters));
        root
    }

    pub fn from_json(v: &Json) -> Result<KnowledgeBase, JsonError> {
        let mean_v = v.req_vec_f64("norm_mean")?;
        let std_v = v.req_vec_f64("norm_std")?;
        let mut mean = [0.0; FEATURE_DIM];
        let mut std = [1.0; FEATURE_DIM];
        for d in 0..FEATURE_DIM.min(mean_v.len()) {
            mean[d] = mean_v[d];
            std[d] = std_v[d];
        }
        let region_v = v.req_vec_f64("region")?;
        let region_config = RegionConfig {
            radius: region_v[0] as u32,
            gamma: region_v[1] as usize,
            lambda: region_v[2] as usize,
        };
        let seed = v.req_f64("seed")? as u64;
        let mut clusters = Vec::new();
        for (ci, cj) in v.req_arr("clusters")?.iter().enumerate() {
            let centroid = cj.req_vec_f64("centroid")?;
            let mut cluster = ClusterKnowledge::new(centroid);
            cluster.n_rows = cj.req_f64("n_rows")? as u64;
            if let Some(pj) = cj.get("pooled") {
                cluster.pooled = SurfaceStats::from_json(pj)?;
            }
            for (bin, sj) in cj.req_arr("stats")?.iter().enumerate().take(NUM_LOAD_BINS) {
                cluster.stats[bin] = SurfaceStats::from_json(sj)?;
            }
            for (bin, wj) in cj
                .req_arr("intensity_acc")?
                .iter()
                .enumerate()
                .take(NUM_LOAD_BINS)
            {
                let f = wj
                    .as_arr()
                    .ok_or_else(|| JsonError { message: "bad welford".into() })?;
                cluster.intensity_acc[bin] = Welford {
                    count: f[0].as_f64().unwrap_or(0.0) as u64,
                    mean: f[1].as_f64().unwrap_or(0.0),
                    m2: f[2].as_f64().unwrap_or(0.0),
                };
            }
            cluster.rebuild(&region_config, seed.wrapping_add(ci as u64));
            clusters.push(cluster);
        }
        let k_scores = v
            .req_arr("k_scores")?
            .iter()
            .filter_map(|e| {
                let a = e.as_arr()?;
                Some((a[0].as_f64()? as usize, a[1].as_f64()?))
            })
            .collect();
        Ok(KnowledgeBase {
            normalizer: Normalizer { mean, std },
            clusters,
            k_scores,
            built_through_day: v.req_f64("built_through_day")? as u64,
            region_config,
            seed,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<KnowledgeBase> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        KnowledgeBase::from_json(&v).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    #[test]
    fn request_features_match_log_features() {
        let log = sample_log();
        let req = RequestInfo {
            rtt_ms: log.rtt_ms,
            bandwidth_mbps: log.bandwidth_mbps,
            tcp_buffer_mb: log.tcp_buffer_mb,
            disk_mbps: log.disk_mbps,
            avg_file_mb: log.avg_file_mb,
            num_files: log.num_files,
        };
        assert_eq!(req.raw_features(), raw_features(&log));
    }

    #[test]
    fn cluster_push_routes_to_load_bin() {
        let mut c = ClusterKnowledge::new(vec![0.0; FEATURE_DIM]);
        let mut row = sample_log();
        row.throughput_mbps = 9_500.0; // ⇒ intensity ~0 ⇒ bin 0
        row.contending_mbps = [0.0; 5];
        c.push(&row);
        assert_eq!(c.stats[0].total_count(), 1);
        let mut busy = sample_log();
        busy.throughput_mbps = 500.0; // intensity ~0.93 ⇒ top bin
        busy.contending_mbps = [0.0; 5];
        c.push(&busy);
        assert_eq!(c.stats[NUM_LOAD_BINS - 1].total_count(), 1);
        assert_eq!(c.n_rows, 2);
    }
}
