//! k-means++ clustering (paper §3.1 choice (1); Arthur & Vassilvitskii
//! seeding gives the O(log m)-competitive guarantee the paper cites).
//!
//! The assignment step — an `n × k` pairwise-distance problem — is the
//! clustering hot spot and is abstracted behind [`AssignBackend`] so it
//! can run either natively or through the AOT-compiled PJRT artifact
//! whose inner tile is the L1 Pallas pairwise-distance kernel.

use crate::util::rng::Rng;
use anyhow::Result;

/// Pluggable assignment step: fill `assign[i]` with the index of the
/// nearest centroid for every point and return the total inertia
/// (sum of squared distances to the assigned centroid).
pub trait AssignBackend {
    fn assign(
        &mut self,
        points: &[f64],
        n: usize,
        d: usize,
        centroids: &[f64],
        k: usize,
        assign: &mut [u32],
    ) -> Result<f64>;

    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend.
pub struct NativeAssign;

impl AssignBackend for NativeAssign {
    fn assign(
        &mut self,
        points: &[f64],
        n: usize,
        d: usize,
        centroids: &[f64],
        k: usize,
        assign: &mut [u32],
    ) -> Result<f64> {
        anyhow::ensure!(points.len() == n * d, "points buffer shape");
        anyhow::ensure!(centroids.len() == k * d, "centroid buffer shape");
        anyhow::ensure!(assign.len() == n, "assignment buffer shape");
        let mut inertia = 0.0;
        for i in 0..n {
            let pt = &points[i * d..(i + 1) * d];
            let mut best = (0u32, f64::INFINITY);
            for c in 0..k {
                let ct = &centroids[c * d..(c + 1) * d];
                let mut dist = 0.0;
                for j in 0..d {
                    let diff = pt[j] - ct[j];
                    dist += diff * diff;
                }
                if dist < best.1 {
                    best = (c as u32, dist);
                }
            }
            assign[i] = best.0;
            inertia += best.1;
        }
        Ok(inertia)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Result of a Lloyd run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub k: usize,
    pub d: usize,
    pub centroids: Vec<f64>,
    pub assignments: Vec<u32>,
    pub inertia: f64,
    pub iterations: usize,
}

/// k-means++ seeding: first centroid uniform, the rest ∝ D²(x).
fn seed_pp(points: &[f64], n: usize, d: usize, k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut centroids = Vec::with_capacity(k * d);
    let first = rng.index(n);
    centroids.extend_from_slice(&points[first * d..(first + 1) * d]);
    let mut dist2 = vec![f64::INFINITY; n];
    while centroids.len() < k * d {
        let c_latest = &centroids[centroids.len() - d..];
        for i in 0..n {
            let pt = &points[i * d..(i + 1) * d];
            let mut dd = 0.0;
            for j in 0..d {
                let diff = pt[j] - c_latest[j];
                dd += diff * diff;
            }
            dist2[i] = dist2[i].min(dd);
        }
        let next = rng
            .weighted_index(&dist2)
            .unwrap_or_else(|| rng.index(n));
        centroids.extend_from_slice(&points[next * d..(next + 1) * d]);
    }
    centroids
}

/// Run k-means++ + Lloyd until convergence (assignments stable or
/// `max_iters`).
pub fn kmeans_pp(
    points: &[f64],
    n: usize,
    d: usize,
    k: usize,
    rng: &mut Rng,
    backend: &mut dyn AssignBackend,
    max_iters: usize,
) -> Result<KMeansResult> {
    anyhow::ensure!(n > 0 && d > 0 && k > 0, "kmeans: empty problem");
    anyhow::ensure!(k <= n, "kmeans: k={k} > n={n}");
    anyhow::ensure!(points.len() == n * d, "kmeans: bad points buffer");
    let mut centroids = seed_pp(points, n, d, k, rng);
    let mut assignments = vec![0u32; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for iter in 0..max_iters.max(1) {
        iterations = iter + 1;
        let prev = assignments.clone();
        inertia = backend.assign(points, n, d, &centroids, k, &mut assignments)?;
        // Centroid update (mean of members; empty cluster keeps its
        // previous centroid — standard Lloyd fix-up).
        let mut sums = vec![0.0; k * d];
        let mut counts = vec![0u64; k];
        for i in 0..n {
            let c = assignments[i] as usize;
            counts[c] += 1;
            for j in 0..d {
                sums[c * d + j] += points[i * d + j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] / counts[c] as f64;
                }
            }
        }
        if prev == assignments && iter > 0 {
            break;
        }
    }
    Ok(KMeansResult { k, d, centroids, assignments, inertia, iterations })
}

/// Index of the nearest centroid to a single query (the knowledge-base
/// "constant-time query" path the paper describes).
pub fn nearest_centroid(query: &[f64], centroids: &[f64], k: usize, d: usize) -> usize {
    let mut best = (0usize, f64::INFINITY);
    for c in 0..k {
        let ct = &centroids[c * d..(c + 1) * d];
        let mut dist = 0.0;
        for j in 0..d {
            let diff = query[j] - ct[j];
            dist += diff * diff;
        }
        if dist < best.1 {
            best = (c, dist);
        }
    }
    best.0
}

#[cfg(test)]
pub mod tests {
    use super::*;

    /// Three well-separated Gaussian blobs in 2-D.
    pub fn blobs(rng: &mut Rng, per_blob: usize) -> (Vec<f64>, usize, usize) {
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let mut pts = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..per_blob {
                pts.push(cx + rng.normal() * 0.5);
                pts.push(cy + rng.normal() * 0.5);
            }
        }
        (pts, per_blob * 3, 2)
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let mut rng = Rng::new(3);
        let (pts, n, d) = blobs(&mut rng, 60);
        let res = kmeans_pp(&pts, n, d, 3, &mut rng, &mut NativeAssign, 50).unwrap();
        // Each blob of 60 points must be pure.
        for blob in 0..3 {
            let members = &res.assignments[blob * 60..(blob + 1) * 60];
            let first = members[0];
            assert!(members.iter().all(|&a| a == first), "blob {blob} split");
        }
        // Inertia per point ≈ 2·σ² = 0.5.
        assert!(res.inertia / (n as f64) < 1.0, "inertia {}", res.inertia);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Rng::new(9);
        let (pts, n, d) = blobs(&mut rng, 40);
        let mut prev = f64::INFINITY;
        for k in [1usize, 2, 3, 6] {
            let res = kmeans_pp(&pts, n, d, k, &mut rng, &mut NativeAssign, 50).unwrap();
            assert!(res.inertia <= prev + 1e-9, "k={k}: {} > {prev}", res.inertia);
            prev = res.inertia;
        }
    }

    #[test]
    fn k_equals_n_gives_zero_inertia() {
        let pts = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0];
        let mut rng = Rng::new(1);
        let res = kmeans_pp(&pts, 3, 2, 3, &mut rng, &mut NativeAssign, 20).unwrap();
        assert!(res.inertia < 1e-18);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut rng = Rng::new(1);
        assert!(kmeans_pp(&[1.0, 2.0], 1, 2, 2, &mut rng, &mut NativeAssign, 5).is_err());
        assert!(kmeans_pp(&[1.0, 2.0, 3.0], 2, 2, 1, &mut rng, &mut NativeAssign, 5).is_err());
    }

    #[test]
    fn nearest_centroid_agrees_with_backend() {
        let mut rng = Rng::new(5);
        let (pts, n, d) = blobs(&mut rng, 20);
        let res = kmeans_pp(&pts, n, d, 3, &mut rng, &mut NativeAssign, 50).unwrap();
        for i in 0..n {
            let q = &pts[i * d..(i + 1) * d];
            assert_eq!(
                nearest_centroid(q, &res.centroids, 3, d) as u32,
                res.assignments[i],
                "point {i}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(11);
        let (pts, n, d) = blobs(&mut r1, 30);
        let mut ra = Rng::new(42);
        let mut rb = Rng::new(42);
        let a = kmeans_pp(&pts, n, d, 3, &mut ra, &mut NativeAssign, 50).unwrap();
        let b = kmeans_pp(&pts, n, d, 3, &mut rb, &mut NativeAssign, 50).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.centroids, b.centroids);
    }
}
