//! Coordinator metrics: per-optimizer aggregates over served requests
//! with request-latency percentiles, plus the knowledge-service block
//! (snapshot generation, refresh latency, ingest queue depth, dropped
//! rows), the per-shard table on a fabric-backed coordinator — the
//! pooled request-latency line renders in *both* modes — and the probe
//! plane block (coalesced followers, estimate hit rate, probe-byte
//! overhead) when a plane is attached.
//!
//! Every per-request distribution (goodput, decision latency, sample
//! counts) lives in a bounded [`LogHistogram`]: memory is a function of
//! the value range, never of request volume, and quantiles stay within
//! 1% of exact (bit-exact whenever distinct values occupy distinct
//! buckets — which is what keeps the golden fixture stable).
//!
//! ## Render consistency
//!
//! `render()` and `render_json()` snapshot the per-optimizer table and
//! all four attachment slots **once, up front**, then render from those
//! snapshots without re-locking. The blocks of one render are therefore
//! mutually consistent with respect to attachment: an attachment
//! swapped in mid-render can never produce a table from one epoch and a
//! plane block from another. (Counters *inside* a live attachment are
//! still read at render time — they are monotone atomics, so the worst
//! case is a block slightly newer than the table above it.)

use crate::fabric::ShardRouter;
use crate::feedback::FeedbackStats;
use crate::logs::store::IngestStats;
use crate::netplane::{LinkPlane, PlaneMode};
use crate::probe::ProbePlane;
use crate::telemetry::{
    AccuracyLedger, FlightRecorder, LogHistogram, Registry, Samples, Sentry, Settlement,
    Snapshot,
};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

fn load(a: &AtomicU64) -> u64 {
    a.load(Ordering::Relaxed)
}

#[derive(Debug, Default, Clone)]
pub struct OptimizerStats {
    pub requests: u64,
    pub total_mb: f64,
    pub total_transfer_s: f64,
    pub achieved_mbps: LogHistogram,
    pub decision_wall_ns: LogHistogram,
    pub samples_used: LogHistogram,
}

impl OptimizerStats {
    pub fn mean_achieved_mbps(&self) -> f64 {
        self.achieved_mbps.mean()
    }

    pub fn p50_decision_ns(&self) -> f64 {
        self.decision_wall_ns.quantile(0.50)
    }

    pub fn p95_decision_ns(&self) -> f64 {
        self.decision_wall_ns.quantile(0.95)
    }

    pub fn p99_decision_ns(&self) -> f64 {
        self.decision_wall_ns.quantile(0.99)
    }
}

/// Thread-safe metrics sink.
///
/// Beyond the per-optimizer table and the four render attachments,
/// every `Metrics` carries the fleet health plane: the unified
/// [`Registry`] (each `attach_*` also installs a snapshot-time
/// collector publishing that subsystem's hierarchical families), the
/// per-shard achieved-vs-optimal [`AccuracyLedger`], and the bounded
/// [`FlightRecorder`]. [`Metrics::export_snapshot`] reads all of them
/// out as one deterministic cut for the exporters.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<BTreeMap<&'static str, OptimizerStats>>,
    feedback: Mutex<Option<Arc<FeedbackStats>>>,
    fabric: Mutex<Option<Arc<ShardRouter>>>,
    probe: Mutex<Option<Arc<ProbePlane>>>,
    links: Mutex<Option<Arc<LinkPlane>>>,
    /// The unified fleet-health registry every subsystem publishes
    /// into (see DESIGN.md §Fleet health plane for the name taxonomy).
    pub registry: Registry,
    /// Per-shard achieved-vs-optimal accuracy quantiles.
    pub ledger: AccuracyLedger,
    /// Bounded ring of per-request flight summaries.
    pub recorder: FlightRecorder,
    /// The anomaly-detector engine, ticked once per settlement on the
    /// same single-cut snapshot the exporters read
    /// ([`Metrics::tick_sentry`]).
    pub sentry: Mutex<Sentry>,
}

/// One render's consistent view of the sink: the per-optimizer table
/// and every attachment slot, captured under each lock exactly once.
struct RenderSnapshot {
    stats: BTreeMap<&'static str, OptimizerStats>,
    feedback: Option<Arc<FeedbackStats>>,
    fabric: Option<Arc<ShardRouter>>,
    probe: Option<Arc<ProbePlane>>,
    links: Option<Arc<LinkPlane>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Attach the knowledge-service counters so `render` includes them
    /// and the registry publishes the `feedback.*` families.
    pub fn attach_feedback(&self, stats: Arc<FeedbackStats>) {
        *self.feedback.lock().unwrap() = Some(stats.clone());
        self.registry.collect(move |s| {
            s.gauge("feedback.kb_generation", load(&stats.kb_generation) as f64);
            s.gauge("feedback.queue_depth", load(&stats.queue_depth) as f64);
            s.counter("feedback.refreshes", load(&stats.refreshes));
            s.counter("feedback.rows_enqueued", load(&stats.rows_enqueued));
            s.counter("feedback.rows_flushed", load(&stats.rows_flushed));
            s.counter("feedback.rows_dropped", load(&stats.rows_dropped));
            s.counter("feedback.rows_flush_failed", load(&stats.rows_flush_failed));
            s.counter("feedback.rows_consumed", load(&stats.rows_consumed));
            s.counter("feedback.drift_events", load(&stats.drift_events));
            // last/total_refresh_ns and `flushes` (batch cadence) are
            // wall-clock/scheduler-shaped; the export determinism
            // contract keeps them out of the registry.
        });
    }

    /// The attached knowledge-service counters, if any.
    pub fn feedback(&self) -> Option<Arc<FeedbackStats>> {
        self.feedback.lock().unwrap().clone()
    }

    /// Publish a log store's ingest counters as the `logs.ingest.*`
    /// families (rows/bytes written, rows/bytes scanned, rows fully
    /// parsed). Registry-only — the human `render` table is unchanged,
    /// so the committed golden fixture stays byte-identical. All five
    /// counters are totals over deterministic row/byte volumes (never
    /// batch cadence or wall clock), so same-seed runs export the same
    /// values.
    pub fn attach_ingest(&self, stats: Arc<IngestStats>) {
        self.registry.collect(move |s| {
            s.counter("logs.ingest.rows_written", load(&stats.rows_written));
            s.counter("logs.ingest.bytes_written", load(&stats.bytes_written));
            s.counter("logs.ingest.rows_scanned", load(&stats.rows_scanned));
            s.counter("logs.ingest.bytes_read", load(&stats.bytes_read));
            s.counter("logs.ingest.rows_parsed", load(&stats.rows_parsed));
        });
    }

    /// Attach the knowledge fabric so `render` includes its per-shard
    /// table (generation, rows, queue depth, borrow status) and the
    /// registry publishes the `fabric.*` families.
    pub fn attach_fabric(&self, fabric: Arc<ShardRouter>) {
        *self.fabric.lock().unwrap() = Some(fabric.clone());
        self.registry.collect(move |s| {
            let st = &fabric.stats;
            s.counter("fabric.routed", load(&st.routed));
            s.counter("fabric.route_errors", load(&st.route_errors));
            s.counter("fabric.materialized", load(&st.materialized));
            s.counter("fabric.borrows", load(&st.borrows));
            s.counter("fabric.native_fits", load(&st.native_fits));
            s.counter("fabric.evictions", load(&st.evictions));
            s.counter("fabric.tick_errors", load(&st.tick_errors));
            let shards = fabric.live_shards();
            s.gauge("fabric.live_shards", shards.len() as f64);
            // Fabric-mode ingest totals: each shard owns a private log
            // store, so the fleet-wide `logs.ingest.*` families are the
            // sum over live shards (an evicted shard's contribution
            // drops with it — its store counters restart on the next
            // materialization anyway).
            let mut ingest = [0u64; 5];
            for shard in shards {
                let base = format!("fabric.shard.{}", shard.key.name());
                s.gauge(&format!("{base}.native_rows"), shard.native_rows() as f64);
                s.gauge(&format!("{base}.generation"), shard.generation() as f64);
                s.gauge(
                    &format!("{base}.borrowed"),
                    if shard.is_borrowed() { 1.0 } else { 0.0 },
                );
                let st = shard.ingest_stats();
                ingest[0] += load(&st.rows_written);
                ingest[1] += load(&st.bytes_written);
                ingest[2] += load(&st.rows_scanned);
                ingest[3] += load(&st.bytes_read);
                ingest[4] += load(&st.rows_parsed);
            }
            s.counter("logs.ingest.rows_written", ingest[0]);
            s.counter("logs.ingest.bytes_written", ingest[1]);
            s.counter("logs.ingest.rows_scanned", ingest[2]);
            s.counter("logs.ingest.bytes_read", ingest[3]);
            s.counter("logs.ingest.rows_parsed", ingest[4]);
        });
    }

    /// The attached fabric, if any.
    pub fn fabric(&self) -> Option<Arc<ShardRouter>> {
        self.fabric.lock().unwrap().clone()
    }

    /// Attach the shared probe plane so `render` includes its block
    /// (admission modes, estimate reuse, probe-byte overhead, budgets)
    /// and the registry publishes the `probe.*` families.
    pub fn attach_probe(&self, plane: Arc<ProbePlane>) {
        *self.probe.lock().unwrap() = Some(plane.clone());
        self.registry.collect(move |s| {
            let st = &plane.stats;
            s.counter("probe.led", load(&st.led));
            s.counter("probe.piggybacked", load(&st.piggybacked));
            s.counter("probe.estimate_served", load(&st.estimate_served));
            s.counter("probe.budget_forced", load(&st.budget_forced));
            s.counter("probe.follower_timeouts", load(&st.follower_timeouts));
            s.counter("probe.leader_aborts", load(&st.leader_aborts));
            s.counter("probe.stale_demotions", load(&st.stale_demotions));
            let (sample_mb, bulk_mb) = st.bytes();
            s.gauge("probe.bytes.sample_mb", sample_mb);
            s.gauge("probe.bytes.bulk_mb", bulk_mb);
            s.gauge("probe.in_flight", plane.in_flight() as f64);
            for (key, _est) in plane.estimates().entries() {
                let bucket = plane.budget(key);
                let base = format!("probe.budget.{}", key.name());
                s.gauge(&format!("{base}.available_mb"), bucket.available_mb());
                s.gauge(&format!("{base}.capacity_mb"), bucket.capacity_mb());
            }
        });
    }

    /// The attached probe plane, if any.
    pub fn probe(&self) -> Option<Arc<ProbePlane>> {
        self.probe.lock().unwrap().clone()
    }

    /// Attach the shared-link contention plane so `render` includes its
    /// block (mode, live occupancy per network, ambient convoys,
    /// carried load vs scaled capacity) and the registry publishes the
    /// `netplane.*` families.
    pub fn attach_links(&self, links: Arc<LinkPlane>) {
        *self.links.lock().unwrap() = Some(links.clone());
        self.registry.collect(move |s| {
            use crate::sim::testbed::TestbedId;
            s.gauge("netplane.active_transfers", links.active_total() as f64);
            for net in TestbedId::all() {
                let occ = links.occupancy(net);
                let base = format!("netplane.{}", net.name());
                s.gauge(&format!("{base}.transfers"), occ.transfers as f64);
                s.gauge(&format!("{base}.streams"), occ.streams as f64);
                s.gauge(&format!("{base}.offered_mbps"), occ.offered_mbps);
                s.gauge(&format!("{base}.ambient_mbps"), occ.ambient_mbps);
                s.gauge(&format!("{base}.ambient_streams"), occ.ambient_streams as f64);
                s.gauge(&format!("{base}.epoch"), occ.epoch as f64);
                s.gauge(&format!("{base}.carried_mbps"), links.carried_mbps(net));
            }
        });
    }

    /// The attached contention plane, if any.
    pub fn links(&self) -> Option<Arc<LinkPlane>> {
        self.links.lock().unwrap().clone()
    }

    pub fn record(
        &self,
        optimizer: &'static str,
        achieved_mbps: f64,
        total_mb: f64,
        total_s: f64,
        samples: usize,
        decision_wall_ns: u64,
    ) {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.entry(optimizer).or_default();
        entry.requests += 1;
        entry.total_mb += total_mb;
        entry.total_transfer_s += total_s;
        entry.achieved_mbps.record(achieved_mbps);
        entry.decision_wall_ns.record(decision_wall_ns as f64);
        entry.samples_used.record(samples as f64);
    }

    pub fn snapshot(&self) -> BTreeMap<&'static str, OptimizerStats> {
        self.inner.lock().unwrap().clone()
    }

    /// Capture everything one render needs, taking each lock exactly
    /// once (see the module docs for the consistency guarantee).
    fn render_snapshot(&self) -> RenderSnapshot {
        RenderSnapshot {
            stats: self.snapshot(),
            feedback: self.feedback(),
            fabric: self.fabric(),
            probe: self.probe(),
            links: self.links(),
        }
    }

    /// Decision-latency histogram pooled over every optimizer — the
    /// service-level distribution an operator alerts on.
    fn pooled_latency(snap: &BTreeMap<&'static str, OptimizerStats>) -> LogHistogram {
        let mut pooled = LogHistogram::new();
        for s in snap.values() {
            pooled.merge(&s.decision_wall_ns);
        }
        pooled
    }

    /// Render the standard metrics table.
    pub fn render(&self) -> String {
        let view = self.render_snapshot();
        let mut out = String::from(
            "optimizer   reqs  mean_mbps  p50_mbps  mean_samples  p50_decision  p95_decision  p99_decision\n",
        );
        for (name, s) in &view.stats {
            out.push_str(&format!(
                "{:<11} {:>4} {:>10.0} {:>9.0} {:>13.2} {:>13} {:>13} {:>13}\n",
                name,
                s.requests,
                s.mean_achieved_mbps(),
                s.achieved_mbps.quantile(0.5),
                s.samples_used.mean(),
                crate::util::timer::fmt_ns(s.p50_decision_ns()),
                crate::util::timer::fmt_ns(s.p95_decision_ns()),
                crate::util::timer::fmt_ns(s.p99_decision_ns()),
            ));
        }
        let pooled = Self::pooled_latency(&view.stats);
        if !pooled.is_empty() {
            out.push_str(&format!(
                "request latency: p50 {}, p99 {} over {} requests\n",
                crate::util::timer::fmt_ns(pooled.quantile(0.50)),
                crate::util::timer::fmt_ns(pooled.quantile(0.99)),
                pooled.count(),
            ));
        }
        if let Some(fb) = &view.feedback {
            out.push('\n');
            out.push_str(&fb.render());
        }
        if let Some(fabric) = &view.fabric {
            out.push('\n');
            out.push_str(&fabric.render());
        }
        if let Some(plane) = &view.probe {
            out.push('\n');
            out.push_str(&plane.render());
        }
        if let Some(links) = &view.links {
            out.push('\n');
            out.push_str(&links.render());
        }
        out
    }

    /// Machine-readable export of the same view `render` prints:
    /// per-optimizer aggregates (with full histograms, so a consumer
    /// can re-derive any quantile or merge across coordinators), the
    /// pooled request-latency histogram, and one object per attached
    /// subsystem. Snapshot semantics match `render` exactly.
    pub fn render_json(&self) -> Json {
        let view = self.render_snapshot();
        let mut root = Json::obj();

        let mut optimizers = Json::obj();
        for (name, s) in &view.stats {
            let mut o = Json::obj();
            o.set("requests", Json::Num(s.requests as f64))
                .set("total_mb", Json::Num(s.total_mb))
                .set("total_transfer_s", Json::Num(s.total_transfer_s))
                .set("mean_mbps", Json::Num(s.mean_achieved_mbps()))
                .set("p50_mbps", Json::Num(s.achieved_mbps.quantile(0.5)))
                .set("mean_samples", Json::Num(s.samples_used.mean()))
                .set("p50_decision_ns", Json::Num(s.p50_decision_ns()))
                .set("p99_decision_ns", Json::Num(s.p99_decision_ns()))
                .set("achieved_mbps", s.achieved_mbps.to_json())
                .set("decision_wall_ns", s.decision_wall_ns.to_json())
                .set("samples_used", s.samples_used.to_json());
            optimizers.set(name, o);
        }
        root.set("optimizers", optimizers);

        let pooled = Self::pooled_latency(&view.stats);
        if !pooled.is_empty() {
            let mut latency = Json::obj();
            latency
                .set("p50_ns", Json::Num(pooled.quantile(0.50)))
                .set("p99_ns", Json::Num(pooled.quantile(0.99)))
                .set("requests", Json::Num(pooled.count() as f64))
                .set("histogram", pooled.to_json());
            root.set("request_latency", latency);
        }

        if let Some(fb) = &view.feedback {
            let mut o = Json::obj();
            o.set("kb_generation", Json::Num(load(&fb.kb_generation) as f64))
                .set("refreshes", Json::Num(load(&fb.refreshes) as f64))
                .set("rows_enqueued", Json::Num(load(&fb.rows_enqueued) as f64))
                .set("rows_flushed", Json::Num(load(&fb.rows_flushed) as f64))
                .set("rows_flush_failed", Json::Num(load(&fb.rows_flush_failed) as f64))
                .set("rows_dropped", Json::Num(load(&fb.rows_dropped) as f64))
                .set("rows_consumed", Json::Num(load(&fb.rows_consumed) as f64))
                .set("flushes", Json::Num(load(&fb.flushes) as f64))
                .set("queue_depth", Json::Num(load(&fb.queue_depth) as f64))
                .set("drift_events", Json::Num(load(&fb.drift_events) as f64));
            root.set("feedback", o);
        }

        if let Some(fabric) = &view.fabric {
            let shards = fabric.live_shards();
            let borrowed = shards.iter().filter(|s| s.is_borrowed()).count();
            let st = &fabric.stats;
            let mut o = Json::obj();
            o.set("live_shards", Json::Num(shards.len() as f64))
                .set("borrowed_shards", Json::Num(borrowed as f64))
                .set("routed", Json::Num(load(&st.routed) as f64))
                .set("route_errors", Json::Num(load(&st.route_errors) as f64))
                .set("materialized", Json::Num(load(&st.materialized) as f64))
                .set("borrows", Json::Num(load(&st.borrows) as f64))
                .set("native_fits", Json::Num(load(&st.native_fits) as f64))
                .set("evictions", Json::Num(load(&st.evictions) as f64))
                .set("tick_errors", Json::Num(load(&st.tick_errors) as f64));
            let mut per_shard = Json::obj();
            for shard in &shards {
                let mut row = Json::obj();
                row.set("native_rows", Json::Num(shard.native_rows() as f64))
                    .set("generation", Json::Num(shard.generation() as f64))
                    .set("borrowed", Json::Bool(shard.is_borrowed()));
                per_shard.set(&shard.key.name(), row);
            }
            o.set("shards", per_shard);
            root.set("fabric", o);
        }

        if let Some(plane) = &view.probe {
            let (sample_mb, bulk_mb) = plane.stats.bytes();
            let mut o = Json::obj();
            o.set("led", Json::Num(plane.stats.led.load(Ordering::Relaxed) as f64))
                .set(
                    "piggybacked",
                    Json::Num(plane.stats.piggybacked.load(Ordering::Relaxed) as f64),
                )
                .set(
                    "estimate_served",
                    Json::Num(plane.stats.estimate_served.load(Ordering::Relaxed) as f64),
                )
                .set(
                    "budget_forced",
                    Json::Num(plane.stats.budget_forced.load(Ordering::Relaxed) as f64),
                )
                .set("sample_mb", Json::Num(sample_mb))
                .set("bulk_mb", Json::Num(bulk_mb));
            root.set("probe", o);
        }

        if let Some(links) = &view.links {
            let mut o = Json::obj();
            o.set(
                "mode",
                Json::Str(
                    match links.mode() {
                        PlaneMode::Shared => "shared",
                        PlaneMode::Isolated => "isolated",
                    }
                    .to_string(),
                ),
            )
            .set("active_transfers", Json::Num(links.active_total() as f64));
            root.set("links", o);
        }

        root
    }

    /// One deterministic fleet-health cut: the registry (every
    /// attached subsystem's collector included), the per-optimizer
    /// aggregates as `coordinator.<name>.*` families, the accuracy
    /// ledger as `health.accuracy.*` histograms, and the flight
    /// recorder's retention counters. This is what `dtopt obs` and
    /// every `--metrics-out` path feed to the exporters.
    ///
    /// Wall-clock families (`decision_wall_ns`, refresh timings,
    /// flush batch counts) are deliberately absent: two same-seed
    /// runs must export byte-identically (DESIGN.md §Fleet health
    /// plane, determinism contract — CI's obs-conformance job diffs
    /// exactly this output).
    pub fn export_snapshot(&self) -> Snapshot {
        let mut snap = self.base_snapshot();
        let mut extra = Samples::default();
        self.sentry.lock().unwrap().export_into(&mut extra);
        snap.merge(&Snapshot::from(extra));
        snap
    }

    /// The cut *before* the sentry block — exactly what the sentry
    /// itself is fed on each tick, so a detector never reads its own
    /// output families back as input.
    fn base_snapshot(&self) -> Snapshot {
        let mut snap = self.registry.snapshot();
        let mut extra = Samples::default();
        for (name, s) in self.snapshot() {
            let base = format!("coordinator.{}", name.to_ascii_lowercase());
            extra.counter(&format!("{base}.requests"), s.requests);
            extra.gauge(&format!("{base}.total_mb"), s.total_mb);
            extra.gauge(&format!("{base}.total_transfer_s"), s.total_transfer_s);
            extra.hist(&format!("{base}.achieved_mbps"), &s.achieved_mbps);
            extra.hist(&format!("{base}.samples"), &s.samples_used);
        }
        extra.counter("health.scored_transfers", self.ledger.scored());
        let overall = self.ledger.overall_hist();
        if !overall.is_empty() {
            extra.hist("health.accuracy.overall", &overall);
        }
        for (shard, hist) in self.ledger.snapshot() {
            extra.hist(&format!("health.accuracy.{shard}"), &hist);
        }
        extra.counter("recorder.flights_seen", self.recorder.total_seen());
        extra.gauge("recorder.flights_retained", self.recorder.len() as f64);
        extra.gauge("recorder.capacity", self.recorder.capacity() as f64);
        snap.merge(&Snapshot::from(extra));
        snap
    }

    /// Feed the sentry one settlement at virtual time `t_s`, cutting
    /// the same snapshot the exporters would see at this instant. Both
    /// serve paths (worker `serve_one` and the scenario runner's
    /// `run_admitted`) call this at the same point — after the ledger
    /// is scored and the flight recorded, with the link lease released
    /// — so their alert timelines are interchangeable.
    pub fn tick_sentry(&self, t_s: f64, settlement: &Settlement) {
        let snap = self.base_snapshot();
        self.sentry.lock().unwrap().tick(t_s, settlement, &snap);
    }

    /// Every alert raised so far (raise order), cloned out of the
    /// sentry.
    pub fn alerts(&self) -> Vec<crate::telemetry::Alert> {
        self.sentry.lock().unwrap().alerts().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.record("ASM", 2000.0, 500.0, 2.0, 3, 20_000);
        m.record("GO", 800.0, 500.0, 5.0, 0, 1_000);
        let snap = m.snapshot();
        assert_eq!(snap["ASM"].requests, 2);
        assert_eq!(snap["ASM"].mean_achieved_mbps(), 1500.0);
        assert_eq!(snap["GO"].requests, 1);
        let table = m.render();
        assert!(table.contains("ASM"));
        assert!(table.contains("GO"));
    }

    #[test]
    fn render_includes_latency_percentiles() {
        let m = Metrics::new();
        assert!(!m.render().contains("request latency"), "no requests, no latency line");
        for ns in [10_000u64, 20_000, 30_000, 40_000] {
            m.record("ASM", 1000.0, 500.0, 4.0, 2, ns);
        }
        m.record("GO", 800.0, 500.0, 5.0, 0, 1_000_000);
        let snap = m.snapshot();
        assert_eq!(snap["ASM"].p50_decision_ns(), 25_000.0);
        assert!(snap["ASM"].p99_decision_ns() > snap["ASM"].p50_decision_ns());
        let table = m.render();
        assert!(table.contains("p50_decision"), "{table}");
        assert!(table.contains("p99_decision"), "{table}");
        // Pooled across optimizers: the p99 catches GO's 1 ms outlier.
        assert!(table.contains("request latency: p50"), "{table}");
        assert!(table.contains("over 5 requests"), "{table}");
    }

    #[test]
    fn memory_stays_bounded_over_100k_records() {
        // The regression behind the histogram migration: the old
        // Vec-backed stats grew one f64 per request forever. Bucket
        // count must plateau regardless of record volume.
        let m = Metrics::new();
        let mut rng = crate::util::rng::Rng::new(0x31_07);
        let bound = ((1e12f64).ln() / crate::telemetry::hist::GAMMA.ln()).ceil() as usize + 1;
        let mut plateau = 0usize;
        for i in 0..100_000u64 {
            m.record(
                "ASM",
                rng.range_f64(100.0, 10_000.0),
                500.0,
                4.0,
                (i % 5) as usize,
                rng.range_u(1_000, 50_000_000),
            );
            if i == 9_999 {
                let snap = m.snapshot();
                let s = &snap["ASM"];
                plateau = s.achieved_mbps.bucket_count()
                    + s.decision_wall_ns.bucket_count()
                    + s.samples_used.bucket_count();
            }
        }
        let snap = m.snapshot();
        let s = &snap["ASM"];
        assert_eq!(s.requests, 100_000);
        let total = s.achieved_mbps.bucket_count()
            + s.decision_wall_ns.bucket_count()
            + s.samples_used.bucket_count();
        assert!(total <= 3 * bound, "bucket total {total} exceeded analytic bound");
        // 10x the records after the warm-up added (essentially) no
        // buckets: memory is range-bound, not volume-bound.
        assert!(
            total <= plateau + plateau / 10 + 8,
            "bucket count kept growing: {plateau} after 10k, {total} after 100k"
        );
    }

    #[test]
    fn render_json_round_trips_histograms() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.record("ASM", 2000.0, 500.0, 2.0, 3, 20_000);
        m.record("GO", 800.0, 500.0, 5.0, 0, 1_000_000);
        let text = m.render_json().to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let asm = parsed.get("optimizers").unwrap().get("ASM").unwrap();
        assert_eq!(asm.req_usize("requests").unwrap(), 2);
        assert_eq!(asm.req_f64("mean_mbps").unwrap(), 1500.0);
        // The embedded histogram reconstructs to the exact quantiles.
        let hist =
            LogHistogram::from_json(asm.get("decision_wall_ns").unwrap()).unwrap();
        assert_eq!(hist.quantile(0.5), m.snapshot()["ASM"].p50_decision_ns());
        let latency = parsed.get("request_latency").unwrap();
        assert_eq!(latency.req_usize("requests").unwrap(), 3);
        let pooled = LogHistogram::from_json(latency.get("histogram").unwrap()).unwrap();
        assert_eq!(pooled.count(), 3);
        assert_eq!(pooled.quantile(1.0), 1_000_000.0);
    }

    #[test]
    fn render_json_includes_attached_blocks() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        let empty = m.render_json();
        assert!(empty.get("probe").is_none());
        assert!(empty.get("links").is_none());
        m.attach_probe(Arc::new(ProbePlane::default()));
        m.attach_links(Arc::new(LinkPlane::shared()));
        let full = m.render_json();
        assert_eq!(full.get("links").unwrap().req_str("mode").unwrap(), "shared");
        assert_eq!(full.get("probe").unwrap().req_usize("led").unwrap(), 0);
    }

    #[test]
    fn render_includes_attached_feedback_block() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("knowledge service"));
        let fb = Arc::new(FeedbackStats::default());
        fb.kb_generation.store(3, std::sync::atomic::Ordering::Relaxed);
        fb.rows_dropped.store(7, std::sync::atomic::Ordering::Relaxed);
        m.attach_feedback(fb);
        let table = m.render();
        assert!(table.contains("knowledge service: generation 3"));
        assert!(table.contains("7 dropped at offer"));
    }

    #[test]
    fn attach_ingest_exports_counters_without_touching_render() {
        use crate::telemetry::registry::Value;

        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        let before = m.render();
        let stats = Arc::new(IngestStats::default());
        stats.rows_written.store(12, Ordering::Relaxed);
        stats.bytes_read.store(4096, Ordering::Relaxed);
        m.attach_ingest(stats);
        let snap = m.export_snapshot();
        assert_eq!(snap.get("logs.ingest.rows_written"), Some(&Value::Counter(12)));
        assert_eq!(snap.get("logs.ingest.bytes_read"), Some(&Value::Counter(4096)));
        assert_eq!(snap.get("logs.ingest.rows_parsed"), Some(&Value::Counter(0)));
        // Registry-only: the human table (and its golden fixture) is
        // byte-identical with or without the attachment.
        assert_eq!(m.render(), before);
    }

    #[test]
    fn render_includes_attached_probe_block() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("probe plane"));
        m.attach_probe(Arc::new(ProbePlane::default()));
        let table = m.render();
        assert!(table.contains("probe plane:"), "{table}");
        assert!(table.contains("estimate reuse"), "{table}");
    }

    #[test]
    fn render_includes_attached_link_plane_block() {
        use crate::sim::testbed::TestbedId;

        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("link plane"));
        let links = Arc::new(LinkPlane::shared());
        let lease = links.clone().admit(TestbedId::Xsede, 1);
        lease.update(4, 8, 1_500.0);
        m.attach_links(links);
        let table = m.render();
        assert!(table.contains("link plane: shared mode"), "{table}");
        assert!(table.contains("xsede: 1 active / 8 streams"), "{table}");
        drop(lease);
        assert!(m.render().contains("0 active transfer(s)"));
    }

    #[test]
    fn fabric_mode_still_renders_pooled_latency_line() {
        use crate::fabric::{FabricConfig, ShardRouter};
        use crate::logs::generate::{generate, GenConfig};
        use crate::offline::kmeans::NativeAssign;
        use crate::offline::pipeline::{build, OfflineConfig};
        use crate::sim::testbed::Testbed;

        let rows = generate(
            &Testbed::xsede(),
            &GenConfig { days: 2, arrivals_per_hour: 10.0, start_day: 0, seed: 97 },
        );
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("dtopt_metrics_fabric_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fabric =
            Arc::new(ShardRouter::open(&dir, kb, FabricConfig::default()).unwrap());
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.attach_fabric(fabric.clone());
        // The per-shard table must join — not replace — the pooled
        // request-latency line.
        let table = m.render();
        assert!(table.contains("request latency: p50"), "{table}");
        assert!(table.contains("fabric:"), "{table}");
        fabric.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_and_render_json_agree_on_one_cut() {
        // Regression: the human table and the JSON export must report
        // the same values for the same single-cut snapshot.
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.record("ASM", 2000.0, 700.0, 2.0, 3, 20_000);
        let fb = Arc::new(FeedbackStats::default());
        fb.kb_generation.store(3, Ordering::Relaxed);
        fb.rows_dropped.store(7, Ordering::Relaxed);
        m.attach_feedback(fb);
        let text = m.render();
        let json = m.render_json();
        let asm = json.get("optimizers").unwrap().get("ASM").unwrap();
        assert_eq!(asm.req_usize("requests").unwrap(), 2);
        let mean = asm.req_f64("mean_mbps").unwrap();
        assert_eq!(mean, 1500.0);
        assert!(text.contains(&format!("{mean:.0}")), "{text}");
        let p50 = asm.req_f64("p50_mbps").unwrap();
        assert!(text.contains(&format!("{p50:.0}")), "{text}");
        let fb_json = json.get("feedback").unwrap();
        assert_eq!(fb_json.req_usize("kb_generation").unwrap(), 3);
        assert_eq!(fb_json.req_usize("rows_dropped").unwrap(), 7);
        assert_eq!(fb_json.req_usize("queue_depth").unwrap(), 0);
        assert!(text.contains("knowledge service: generation 3"), "{text}");
        assert!(text.contains("7 dropped at offer"), "{text}");
    }

    #[test]
    fn export_snapshot_covers_every_family_and_excludes_wall_clock() {
        use crate::telemetry::registry::Value;
        use crate::telemetry::FlightRecord;

        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.ledger.score("xsede/large", 930.0, 1000.0);
        m.recorder.push(FlightRecord {
            id: 1,
            optimizer: "ASM",
            shard: "xsede/large".to_string(),
            probe_mode: Some("led"),
            kb_generation: 1,
            borrowed: false,
            samples: 2,
            retunes: 0,
            total_mb: 500.0,
            transfer_s: 4.0,
            achieved_mbps: 930.0,
            optimal_mbps: 1000.0,
        });
        let fb = Arc::new(FeedbackStats::default());
        fb.rows_dropped.store(7, Ordering::Relaxed);
        m.attach_feedback(fb);
        m.attach_probe(Arc::new(ProbePlane::default()));
        m.attach_links(Arc::new(LinkPlane::shared()));

        let snap = m.export_snapshot();
        assert_eq!(snap.get("feedback.rows_dropped"), Some(&Value::Counter(7)));
        assert_eq!(snap.get("coordinator.asm.requests"), Some(&Value::Counter(1)));
        assert!(
            matches!(snap.get("coordinator.asm.achieved_mbps"), Some(Value::Hist(h)) if h.count() == 1)
        );
        assert!(
            matches!(snap.get("health.accuracy.xsede/large"), Some(Value::Hist(h)) if h.count() == 1)
        );
        assert!(matches!(snap.get("health.accuracy.overall"), Some(Value::Hist(_))));
        assert_eq!(snap.get("health.scored_transfers"), Some(&Value::Counter(1)));
        assert_eq!(snap.get("recorder.flights_seen"), Some(&Value::Counter(1)));
        assert!(
            matches!(snap.get("recorder.capacity"), Some(Value::Gauge(c)) if *c > 0.0)
        );
        assert!(snap.get("probe.led").is_some());
        assert!(snap.get("probe.stale_demotions").is_some());
        assert!(snap.get("netplane.active_transfers").is_some());
        assert!(snap.get("netplane.xsede.carried_mbps").is_some());
        // A never-ticked sentry publishes nothing.
        assert!(snap.get("sentry.ticks").is_none());
        // The determinism contract: nothing wall-clock or
        // scheduler-shaped may reach an export.
        for name in snap.values.keys() {
            assert!(
                !name.contains("wall_ns")
                    && !name.contains("refresh_ns")
                    && !name.ends_with("flushes"),
                "wall-clock/scheduler family leaked into the export: {name}"
            );
        }
    }

    #[test]
    fn ticked_sentry_joins_the_export_cut() {
        use crate::telemetry::registry::Value;

        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        let settlement = Settlement {
            shard: "xsede/large".to_string(),
            network: "xsede".to_string(),
            achieved_mbps: 900.0,
            optimal_mbps: 1000.0,
            generation: 0,
            contended: true,
        };
        m.tick_sentry(10.0, &settlement);
        let snap = m.export_snapshot();
        assert_eq!(snap.get("sentry.ticks"), Some(&Value::Counter(1)));
        assert_eq!(snap.get("sentry.alerts.raised"), Some(&Value::Counter(1)));
        assert_eq!(
            snap.get("sentry.allowance-thrash.active"),
            Some(&Value::Gauge(1.0))
        );
        let alerts = m.alerts();
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].detector, "allowance-thrash");
        // The sentry reads the same cut it exports into, minus its own
        // block: its input families (here, the coordinator table) were
        // visible to the tick.
        assert!(snap.get("coordinator.asm.requests").is_some());
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record("X", i as f64, 1.0, 1.0, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot()["X"].requests, 800);
    }
}
