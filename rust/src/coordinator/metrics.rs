//! Coordinator metrics: per-optimizer aggregates over served requests
//! with request-latency percentiles, plus the knowledge-service block
//! (snapshot generation, refresh latency, ingest queue depth, dropped
//! rows), the per-shard table on a fabric-backed coordinator — the
//! pooled request-latency line renders in *both* modes — and the probe
//! plane block (coalesced followers, estimate hit rate, probe-byte
//! overhead) when a plane is attached.

use crate::fabric::ShardRouter;
use crate::feedback::FeedbackStats;
use crate::netplane::LinkPlane;
use crate::probe::ProbePlane;
use crate::util::stats::{mean, quantile};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Default, Clone)]
pub struct OptimizerStats {
    pub requests: u64,
    pub total_mb: f64,
    pub total_transfer_s: f64,
    pub achieved_mbps: Vec<f64>,
    pub decision_wall_ns: Vec<f64>,
    pub samples_used: Vec<f64>,
}

impl OptimizerStats {
    pub fn mean_achieved_mbps(&self) -> f64 {
        mean(&self.achieved_mbps)
    }

    pub fn p50_decision_ns(&self) -> f64 {
        quantile(&self.decision_wall_ns, 0.50)
    }

    pub fn p95_decision_ns(&self) -> f64 {
        quantile(&self.decision_wall_ns, 0.95)
    }

    pub fn p99_decision_ns(&self) -> f64 {
        quantile(&self.decision_wall_ns, 0.99)
    }
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<BTreeMap<&'static str, OptimizerStats>>,
    feedback: Mutex<Option<Arc<FeedbackStats>>>,
    fabric: Mutex<Option<Arc<ShardRouter>>>,
    probe: Mutex<Option<Arc<ProbePlane>>>,
    links: Mutex<Option<Arc<LinkPlane>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Attach the knowledge-service counters so `render` includes them.
    pub fn attach_feedback(&self, stats: Arc<FeedbackStats>) {
        *self.feedback.lock().unwrap() = Some(stats);
    }

    /// The attached knowledge-service counters, if any.
    pub fn feedback(&self) -> Option<Arc<FeedbackStats>> {
        self.feedback.lock().unwrap().clone()
    }

    /// Attach the knowledge fabric so `render` includes its per-shard
    /// table (generation, rows, queue depth, borrow status).
    pub fn attach_fabric(&self, fabric: Arc<ShardRouter>) {
        *self.fabric.lock().unwrap() = Some(fabric);
    }

    /// The attached fabric, if any.
    pub fn fabric(&self) -> Option<Arc<ShardRouter>> {
        self.fabric.lock().unwrap().clone()
    }

    /// Attach the shared probe plane so `render` includes its block
    /// (admission modes, estimate reuse, probe-byte overhead, budgets).
    pub fn attach_probe(&self, plane: Arc<ProbePlane>) {
        *self.probe.lock().unwrap() = Some(plane);
    }

    /// The attached probe plane, if any.
    pub fn probe(&self) -> Option<Arc<ProbePlane>> {
        self.probe.lock().unwrap().clone()
    }

    /// Attach the shared-link contention plane so `render` includes its
    /// block (mode, live occupancy per network, ambient convoys,
    /// carried load vs scaled capacity).
    pub fn attach_links(&self, links: Arc<LinkPlane>) {
        *self.links.lock().unwrap() = Some(links);
    }

    /// The attached contention plane, if any.
    pub fn links(&self) -> Option<Arc<LinkPlane>> {
        self.links.lock().unwrap().clone()
    }

    pub fn record(
        &self,
        optimizer: &'static str,
        achieved_mbps: f64,
        total_mb: f64,
        total_s: f64,
        samples: usize,
        decision_wall_ns: u64,
    ) {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.entry(optimizer).or_default();
        entry.requests += 1;
        entry.total_mb += total_mb;
        entry.total_transfer_s += total_s;
        entry.achieved_mbps.push(achieved_mbps);
        entry.decision_wall_ns.push(decision_wall_ns as f64);
        entry.samples_used.push(samples as f64);
    }

    pub fn snapshot(&self) -> BTreeMap<&'static str, OptimizerStats> {
        self.inner.lock().unwrap().clone()
    }

    /// Render the standard metrics table.
    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::from(
            "optimizer   reqs  mean_mbps  p50_mbps  mean_samples  p50_decision  p95_decision  p99_decision\n",
        );
        for (name, s) in &snap {
            out.push_str(&format!(
                "{:<11} {:>4} {:>10.0} {:>9.0} {:>13.2} {:>13} {:>13} {:>13}\n",
                name,
                s.requests,
                s.mean_achieved_mbps(),
                quantile(&s.achieved_mbps, 0.5),
                mean(&s.samples_used),
                crate::util::timer::fmt_ns(s.p50_decision_ns()),
                crate::util::timer::fmt_ns(s.p95_decision_ns()),
                crate::util::timer::fmt_ns(s.p99_decision_ns()),
            ));
        }
        // Request-latency percentiles pooled over every optimizer — the
        // service-level numbers an operator alerts on.
        let all_ns: Vec<f64> = snap
            .values()
            .flat_map(|s| s.decision_wall_ns.iter().copied())
            .collect();
        if !all_ns.is_empty() {
            out.push_str(&format!(
                "request latency: p50 {}, p99 {} over {} requests\n",
                crate::util::timer::fmt_ns(quantile(&all_ns, 0.50)),
                crate::util::timer::fmt_ns(quantile(&all_ns, 0.99)),
                all_ns.len(),
            ));
        }
        if let Some(fb) = self.feedback() {
            out.push('\n');
            out.push_str(&fb.render());
        }
        if let Some(fabric) = self.fabric() {
            out.push('\n');
            out.push_str(&fabric.render());
        }
        if let Some(plane) = self.probe() {
            out.push('\n');
            out.push_str(&plane.render());
        }
        if let Some(links) = self.links() {
            out.push('\n');
            out.push_str(&links.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.record("ASM", 2000.0, 500.0, 2.0, 3, 20_000);
        m.record("GO", 800.0, 500.0, 5.0, 0, 1_000);
        let snap = m.snapshot();
        assert_eq!(snap["ASM"].requests, 2);
        assert_eq!(snap["ASM"].mean_achieved_mbps(), 1500.0);
        assert_eq!(snap["GO"].requests, 1);
        let table = m.render();
        assert!(table.contains("ASM"));
        assert!(table.contains("GO"));
    }

    #[test]
    fn render_includes_latency_percentiles() {
        let m = Metrics::new();
        assert!(!m.render().contains("request latency"), "no requests, no latency line");
        for ns in [10_000u64, 20_000, 30_000, 40_000] {
            m.record("ASM", 1000.0, 500.0, 4.0, 2, ns);
        }
        m.record("GO", 800.0, 500.0, 5.0, 0, 1_000_000);
        let snap = m.snapshot();
        assert_eq!(snap["ASM"].p50_decision_ns(), 25_000.0);
        assert!(snap["ASM"].p99_decision_ns() > snap["ASM"].p50_decision_ns());
        let table = m.render();
        assert!(table.contains("p50_decision"), "{table}");
        assert!(table.contains("p99_decision"), "{table}");
        // Pooled across optimizers: the p99 catches GO's 1 ms outlier.
        assert!(table.contains("request latency: p50"), "{table}");
        assert!(table.contains("over 5 requests"), "{table}");
    }

    #[test]
    fn render_includes_attached_feedback_block() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("knowledge service"));
        let fb = Arc::new(FeedbackStats::default());
        fb.kb_generation.store(3, std::sync::atomic::Ordering::Relaxed);
        fb.rows_dropped.store(7, std::sync::atomic::Ordering::Relaxed);
        m.attach_feedback(fb);
        let table = m.render();
        assert!(table.contains("knowledge service: generation 3"));
        assert!(table.contains("7 dropped at offer"));
    }

    #[test]
    fn render_includes_attached_probe_block() {
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("probe plane"));
        m.attach_probe(Arc::new(ProbePlane::default()));
        let table = m.render();
        assert!(table.contains("probe plane:"), "{table}");
        assert!(table.contains("estimate reuse"), "{table}");
    }

    #[test]
    fn render_includes_attached_link_plane_block() {
        use crate::sim::testbed::TestbedId;

        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        assert!(!m.render().contains("link plane"));
        let links = Arc::new(LinkPlane::shared());
        let lease = links.clone().admit(TestbedId::Xsede, 1);
        lease.update(4, 8, 1_500.0);
        m.attach_links(links);
        let table = m.render();
        assert!(table.contains("link plane: shared mode"), "{table}");
        assert!(table.contains("xsede: 1 active / 8 streams"), "{table}");
        drop(lease);
        assert!(m.render().contains("0 active transfer(s)"));
    }

    #[test]
    fn fabric_mode_still_renders_pooled_latency_line() {
        use crate::fabric::{FabricConfig, ShardRouter};
        use crate::logs::generate::{generate, GenConfig};
        use crate::offline::kmeans::NativeAssign;
        use crate::offline::pipeline::{build, OfflineConfig};
        use crate::sim::testbed::Testbed;

        let rows = generate(
            &Testbed::xsede(),
            &GenConfig { days: 2, arrivals_per_hour: 10.0, start_day: 0, seed: 97 },
        );
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("dtopt_metrics_fabric_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fabric =
            Arc::new(ShardRouter::open(&dir, kb, FabricConfig::default()).unwrap());
        let m = Metrics::new();
        m.record("ASM", 1000.0, 500.0, 4.0, 2, 10_000);
        m.attach_fabric(fabric.clone());
        // The per-shard table must join — not replace — the pooled
        // request-latency line.
        let table = m.render();
        assert!(table.contains("request latency: p50"), "{table}");
        assert!(table.contains("fabric:"), "{table}");
        fabric.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record("X", i as f64, 1.0, 1.0, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot()["X"].requests, 800);
    }
}
