//! L3 transfer coordinator: request/response API, thread-pool server,
//! and per-optimizer metrics.

pub mod api;
pub mod metrics;
pub mod server;

pub use api::{OptimizerKind, TransferRequest, TransferResponse};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig, ResponseTap, ServeHandle, TapEvent};
