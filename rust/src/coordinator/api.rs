//! Request/response types of the transfer coordinator.

use crate::baselines::RunReport;
use crate::fabric::ShardKey;
use crate::netplane::ContentionExposure;
use crate::probe::ProbeMode;
use crate::sim::dataset::Dataset;
use crate::sim::testbed::TestbedId;
use crate::sim::transfer::NetState;

/// Which optimizer serves a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptimizerKind {
    Asm,
    Go,
    Sp,
    Sc,
    AnnOt,
    Harp,
    Nmt,
}

impl OptimizerKind {
    pub fn all() -> [OptimizerKind; 7] {
        [
            OptimizerKind::Go,
            OptimizerKind::Sp,
            OptimizerKind::Sc,
            OptimizerKind::AnnOt,
            OptimizerKind::Harp,
            OptimizerKind::Nmt,
            OptimizerKind::Asm,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Asm => "ASM",
            OptimizerKind::Go => "GO",
            OptimizerKind::Sp => "SP",
            OptimizerKind::Sc => "SC",
            OptimizerKind::AnnOt => "ANN+OT",
            OptimizerKind::Harp => "HARP",
            OptimizerKind::Nmt => "NMT",
        }
    }

    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s.to_ascii_lowercase().as_str() {
            "asm" => Some(OptimizerKind::Asm),
            "go" => Some(OptimizerKind::Go),
            "sp" => Some(OptimizerKind::Sp),
            "sc" => Some(OptimizerKind::Sc),
            "annot" | "ann+ot" | "ann" => Some(OptimizerKind::AnnOt),
            "harp" => Some(OptimizerKind::Harp),
            "nmt" => Some(OptimizerKind::Nmt),
            _ => None,
        }
    }
}

/// A transfer request submitted to the coordinator.
#[derive(Debug, Clone)]
pub struct TransferRequest {
    pub id: u64,
    pub testbed: TestbedId,
    pub dataset: Dataset,
    /// Simulated submission time (drives the diurnal hidden load unless
    /// `state_override` pins it).
    pub t_submit: f64,
    pub state_override: Option<NetState>,
    pub optimizer: Option<OptimizerKind>,
    /// Per-request RNG seed (reproducibility across optimizers).
    pub seed: u64,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct TransferResponse {
    pub id: u64,
    pub optimizer: &'static str,
    pub report: RunReport,
    /// Wall-clock time the optimizer spent deciding/executing (the
    /// coordinator's own overhead — the paper's "constant time" claim
    /// is about this number for ASM).
    pub decision_wall_ns: u64,
    /// Ground-truth optimal steady rate at submission (for accuracy).
    pub optimal_mbps: f64,
    /// Generation of the knowledge-base snapshot this request was
    /// served from (0 = the KB frozen at startup; increments on every
    /// hot-swapped refresh published by the feedback service — or, on a
    /// fabric-backed coordinator, by the serving shard).
    pub kb_generation: u64,
    /// Knowledge shard that served the request (`None` on coordinators
    /// serving from a single global KB).
    pub shard_key: Option<ShardKey>,
    /// The serving KB was borrowed — a cold-started shard serving a
    /// donor's (or the fallback) knowledge base until enough native
    /// rows accrue for its own fit. Always `false` without a fabric.
    pub borrowed: bool,
    /// How the shared probe plane served this request (`led`,
    /// `piggybacked`, or `estimate-served`). `None` when no probe plane
    /// is attached or the optimizer was not ASM.
    pub probe_mode: Option<ProbeMode>,
    /// What this transfer experienced on the shared link — distinct
    /// occupancy epochs, peak/mean neighbor pressure, peak carried load
    /// — when a contention plane (`CoordinatorConfig::links`) is
    /// attached. `None` without one. An isolated-mode plane still
    /// attributes (all-zero neighbor fields), so bake-off sides stay
    /// comparable.
    pub contention: Option<ContentionExposure>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in OptimizerKind::all() {
            assert_eq!(OptimizerKind::parse(&kind.name().to_lowercase()), Some(kind));
        }
        assert_eq!(OptimizerKind::parse("bogus"), None);
    }
}
