//! The transfer coordinator: a thread-pool service that accepts
//! transfer requests, routes each to the configured optimizer, runs it
//! against the simulated network, and aggregates metrics. This is the
//! L3 request path: knowledge-base queries and parameter decisions all
//! happen here in rust — python is long gone by now.

use super::api::{OptimizerKind, TransferRequest, TransferResponse};
use super::metrics::Metrics;
use crate::baselines::annot::AnnOt;
use crate::baselines::go::GlobusOnline;
use crate::baselines::harp::Harp;
use crate::baselines::nmt::NelderMeadTuner;
use crate::baselines::sc::SingleChunk;
use crate::baselines::sp::StaticParams;
use crate::baselines::{Optimizer, TransferEnv};
use crate::logs::record::TransferLog;
use crate::offline::knowledge::KnowledgeBase;
use crate::online::asm::AdaptiveSampling;
use crate::sim::params::BETA;
use crate::sim::testbed::Testbed;
use crate::sim::traffic::Contention;
use crate::sim::transfer::NetState;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Default optimizer when a request doesn't specify one.
    pub default_optimizer: OptimizerKind,
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, default_optimizer: OptimizerKind::Asm, seed: 0xC0 }
    }
}

/// Shared read-only context every worker uses.
struct Shared {
    kb: Arc<KnowledgeBase>,
    history: Arc<Vec<TransferLog>>,
    annot: Arc<AnnOt>,
    sp: Arc<StaticParams>,
    metrics: Arc<Metrics>,
}

enum Job {
    Run(TransferRequest, Sender<TransferResponse>),
    Stop,
}

/// The coordinator service.
pub struct Coordinator {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    config: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(
        kb: Arc<KnowledgeBase>,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        // Train the ANN once, shared by every worker.
        let annot = Arc::new(AnnOt::train(&history, config.seed ^ 0xA22));
        let sp = Arc::new(StaticParams::mine(&history));
        let shared = Arc::new(Shared {
            kb,
            history,
            annot,
            sp,
            metrics: metrics.clone(),
        });
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(config.workers.max(1));
        for widx in 0..config.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let default_opt = config.default_optimizer;
            workers.push(std::thread::spawn(move || {
                worker_loop(widx, rx, shared, default_opt);
            }));
        }
        Coordinator { tx, workers, metrics, next_id: AtomicU64::new(1), config }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, request: TransferRequest) -> Receiver<TransferResponse> {
        let (tx, rx) = channel();
        self.tx.send(Job::Run(request, tx)).expect("coordinator stopped");
        rx
    }

    /// Convenience: run a batch and wait for all responses (order
    /// preserved by request id).
    pub fn run_batch(&self, requests: Vec<TransferRequest>) -> Vec<TransferResponse> {
        let receivers: Vec<(u64, Receiver<TransferResponse>)> =
            requests.into_iter().map(|r| (r.id, self.submit(r))).collect();
        let mut out: Vec<TransferResponse> =
            receivers.into_iter().map(|(_, rx)| rx.recv().expect("worker died")).collect();
        out.sort_by_key(|r| r.id);
        out
    }

    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }
}

fn worker_loop(
    widx: usize,
    rx: Arc<Mutex<Receiver<Job>>>,
    shared: Arc<Shared>,
    default_opt: OptimizerKind,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(Job::Run(request, reply)) => {
                let response = serve_one(&shared, &request, default_opt, widx as u64);
                let _ = reply.send(response);
            }
            Ok(Job::Stop) | Err(_) => break,
        }
    }
}

/// Serve a single request: build the hidden environment, dispatch to
/// the optimizer, record metrics.
fn serve_one(
    shared: &Shared,
    request: &TransferRequest,
    default_opt: OptimizerKind,
    widx: u64,
) -> TransferResponse {
    let testbed = Testbed::by_id(request.testbed);
    // Hidden network state: diurnal profile at submission time (plus
    // contending transfers), unless the request pins a state.
    let mut state_rng = Rng::new(request.seed ^ 0x57A7E);
    let state = request.state_override.unwrap_or_else(|| {
        let load = testbed.profile.sample_load(request.t_submit, &mut state_rng);
        let contention =
            Contention::sample(&mut state_rng, testbed.path.link.bandwidth_mbps, load);
        NetState { external_load: load, contention }
    });
    let mut env = TransferEnv::new(
        testbed.clone(),
        request.dataset,
        state,
        request.seed ^ widx.rotate_left(17),
    );
    let (_, optimal_mbps) = testbed.path.optimal(&request.dataset, &state, BETA);

    let kind = request.optimizer.unwrap_or(default_opt);
    let started = Instant::now();
    let report = match kind {
        OptimizerKind::Asm => AdaptiveSampling::new(&shared.kb).run(&mut env),
        OptimizerKind::Go => GlobusOnline.run(&mut env),
        OptimizerKind::Sp => (*shared.sp).clone().run(&mut env),
        OptimizerKind::Sc => SingleChunk::default().run(&mut env),
        OptimizerKind::AnnOt => {
            // The shared ANN is read-only at run time; clone the thin
            // handle for the trait's &mut self.
            let mut model = (*shared.annot).clone();
            model.run(&mut env)
        }
        OptimizerKind::Harp => Harp::new((*shared.history).clone()).run(&mut env),
        OptimizerKind::Nmt => NelderMeadTuner::default().run(&mut env),
    };
    let decision_wall_ns = started.elapsed().as_nanos() as u64;
    shared.metrics.record(
        report.optimizer,
        report.achieved_mbps(),
        report.total_mb(),
        report.total_s(),
        report.sample_transfers(),
        decision_wall_ns,
    );
    TransferResponse {
        id: request.id,
        optimizer: report.optimizer,
        report,
        decision_wall_ns,
        optimal_mbps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::Dataset;
    use crate::sim::testbed::TestbedId;

    fn coordinator() -> Coordinator {
        let tb = Testbed::xsede();
        let rows = generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        Coordinator::new(kb, Arc::new(rows), CoordinatorConfig { workers: 3, ..Default::default() })
    }

    fn request(id: u64, opt: Option<OptimizerKind>) -> TransferRequest {
        TransferRequest {
            id,
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(60, 100.0),
            t_submit: 3_600.0 * (id as f64 % 24.0),
            state_override: None,
            optimizer: opt,
            seed: 1000 + id,
        }
    }

    #[test]
    fn serves_batch_in_order() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=6).map(|i| request(i, None)).collect();
        let responses = coord.run_batch(reqs);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert_eq!(r.optimizer, "ASM");
            assert!(r.report.achieved_mbps() > 0.0);
            assert!(r.optimal_mbps > 0.0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap["ASM"].requests, 6);
        coord.shutdown();
    }

    #[test]
    fn dispatches_every_optimizer_kind() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = OptimizerKind::all()
            .iter()
            .enumerate()
            .map(|(i, &k)| request(i as u64 + 1, Some(k)))
            .collect();
        let responses = coord.run_batch(reqs);
        let names: Vec<&str> = responses.iter().map(|r| r.optimizer).collect();
        for kind in OptimizerKind::all() {
            assert!(names.contains(&kind.name()), "missing {}", kind.name());
        }
        coord.shutdown();
    }

    #[test]
    fn asm_decision_overhead_is_tiny() {
        // The paper: "Our online module needs almost constant time to
        // agree on the parameters". Wall-clock per request (excluding
        // simulated transfer time, which is virtual) must be far below
        // a real sample transfer.
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=10).map(|i| request(i, Some(OptimizerKind::Asm))).collect();
        let responses = coord.run_batch(reqs);
        for r in &responses {
            assert!(
                r.decision_wall_ns < 200_000_000,
                "ASM decision took {}",
                crate::util::timer::fmt_ns(r.decision_wall_ns as f64)
            );
        }
        coord.shutdown();
    }
}
