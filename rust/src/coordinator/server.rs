//! The transfer coordinator: a thread-pool service that accepts
//! transfer requests, routes each to the configured optimizer, runs it
//! against the simulated network, and aggregates metrics. This is the
//! L3 request path: knowledge-base queries and parameter decisions all
//! happen here in rust — python is long gone by now.
//!
//! The knowledge base is consumed through a hot-swappable snapshot
//! slot: each request pins the current generation for its whole run,
//! and — when a [`FeedbackService`] is attached — every completed
//! transfer is offered back to the ingestion queue so the refresher can
//! fold it into the next generation. Requests served during a refresh
//! are never paused; they simply finish on the generation they pinned.

use super::api::{OptimizerKind, TransferRequest, TransferResponse};
use super::metrics::Metrics;
use crate::baselines::annot::AnnOt;
use crate::baselines::go::GlobusOnline;
use crate::baselines::harp::Harp;
use crate::baselines::nmt::NelderMeadTuner;
use crate::baselines::sc::SingleChunk;
use crate::baselines::sp::StaticParams;
use crate::baselines::{Optimizer, RunReport, TransferEnv};
use crate::fabric::{Shard, ShardKey, ShardRouter};
use crate::feedback::{FeedbackService, FeedbackStats, IngestQueue, SnapshotSlot};
use crate::feedback::KbSnapshot;
use crate::logs::record::TransferLog;
use crate::netplane::{ContentionExposure, LinkPlane};
use crate::offline::knowledge::KnowledgeBase;
use crate::online::asm::AdaptiveSampling;
use crate::probe::{Admission, ProbeMode, ProbeOcc, ProbePlane};
use crate::sim::fault::FaultBoard;
use crate::sim::params::BETA;
use crate::sim::testbed::Testbed;
use crate::sim::traffic::Contention;
use crate::sim::transfer::NetState;
use crate::telemetry::{Provenance, TraceBuilder, TraceEvent, TraceSink};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Default optimizer when a request doesn't specify one.
    pub default_optimizer: OptimizerKind,
    pub seed: u64,
    /// Shared probe plane: ASM requests coalesce their sampling ladders
    /// per shard, reuse decaying network-state estimates, and respect
    /// per-shard probe budgets. `None` = every request samples for
    /// itself (the pre-plane behavior).
    pub probe: Option<Arc<ProbePlane>>,
    /// Fault board consulted while building each request's hidden
    /// environment: link-capacity degradation and external-load steps
    /// registered on the board shape the testbed the transfer runs on
    /// (and the ground-truth optimum it is scored against). `None` =
    /// pristine testbeds. Driven by the scenario engine's timed fault
    /// events.
    pub faults: Option<Arc<FaultBoard>>,
    /// Timeline tap: every completed response also appends a compact
    /// [`TapEvent`] here, in completion order — the scenario engine's
    /// structured event timeline reads from it. `None` = no taping.
    pub tap: Option<Arc<ResponseTap>>,
    /// Shared-link contention plane: each served transfer registers its
    /// live (procs × streams, offered rate) on its network's link, sees
    /// its neighbors' occupancy fold into the hidden contention on
    /// every chunk, and is clamped to the plane's fair-share stream
    /// allowance while the link is shared. `None` = every transfer
    /// believes it owns the link (the pre-plane fiction, equivalent to
    /// attaching `LinkPlane::isolated()` minus the attribution).
    pub links: Option<Arc<LinkPlane>>,
    /// Decision-trace sink: when attached, every served request builds
    /// a [`crate::telemetry::DecisionTrace`] — one typed event per
    /// layer hop (routing, fault consult, link + probe admission, ASM
    /// ladder, allowance clamps, lease release, settlement), each
    /// carrying the provenance of the knowledge it consumed — and
    /// pushes it here on completion. `None` = tracing off: the serve
    /// path allocates nothing and every emission site is a no-op.
    pub traces: Option<Arc<TraceSink>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            default_optimizer: OptimizerKind::Asm,
            seed: 0xC0,
            probe: None,
            faults: None,
            tap: None,
            links: None,
            traces: None,
        }
    }
}

/// One taped response: the cross-cutting facts the scenario engine's
/// invariant checkers reason about, without dragging the full
/// [`RunReport`] into the timeline.
#[derive(Debug, Clone)]
pub struct TapEvent {
    pub id: u64,
    pub t_submit: f64,
    pub optimizer: &'static str,
    pub kb_generation: u64,
    pub shard_key: Option<ShardKey>,
    pub borrowed: bool,
    pub probe_mode: Option<ProbeMode>,
    pub samples: usize,
    pub bulk_retunes: usize,
    pub total_mb: f64,
    pub transfer_s: f64,
    pub achieved_mbps: f64,
    /// Shared-link exposure (`None` without a contention plane).
    pub contention: Option<ContentionExposure>,
}

/// A thread-safe response tap (see [`CoordinatorConfig::tap`]): workers
/// append one event per completed response; a harness drains them.
#[derive(Debug, Default)]
pub struct ResponseTap {
    events: Mutex<Vec<TapEvent>>,
}

impl ResponseTap {
    pub fn new() -> ResponseTap {
        ResponseTap::default()
    }

    fn push(&self, event: TapEvent) {
        self.events.lock().expect("response tap poisoned").push(event);
    }

    /// Take every taped event, in completion order.
    pub fn drain(&self) -> Vec<TapEvent> {
        std::mem::take(&mut *self.events.lock().expect("response tap poisoned"))
    }

    pub fn len(&self) -> usize {
        self.events.lock().expect("response tap poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Handles into the knowledge lifecycle service, held per worker.
struct FeedbackHandles {
    queue: IngestQueue,
    stats: Arc<FeedbackStats>,
    /// The service's log-store ingest counters, attached to the
    /// metrics registry as the `logs.ingest.*` families.
    ingest: Arc<crate::logs::store::IngestStats>,
}

/// Where a worker's knowledge comes from.
enum Knowledge {
    /// One global hot-swappable knowledge base (generation 0 forever
    /// when no feedback service is attached).
    Global {
        slot: Arc<SnapshotSlot>,
        feedback: Option<FeedbackHandles>,
    },
    /// The sharded fabric: every request routes to its own shard's
    /// snapshot slot and feeds its completed transfer back to that
    /// shard's ingest queue.
    Fabric(Arc<ShardRouter>),
}

/// Shared read-only context every worker uses.
struct Shared {
    knowledge: Knowledge,
    annot: Arc<AnnOt>,
    sp: Arc<StaticParams>,
    /// Fitted once over the shared history; each HARP request clones
    /// the thin handle instead of re-running Normalizer::fit.
    harp: Arc<Harp>,
    metrics: Arc<Metrics>,
    /// Shared probe plane for ASM requests (see `CoordinatorConfig`).
    probe: Option<Arc<ProbePlane>>,
    /// Fault board shaping each request's testbed (see
    /// `CoordinatorConfig::faults`).
    faults: Option<Arc<FaultBoard>>,
    /// Timeline tap fed on every response (see `CoordinatorConfig::tap`).
    tap: Option<Arc<ResponseTap>>,
    /// Shared-link contention plane (see `CoordinatorConfig::links`).
    links: Option<Arc<LinkPlane>>,
    /// Decision-trace sink (see `CoordinatorConfig::traces`).
    traces: Option<Arc<TraceSink>>,
}

enum Job {
    Run(TransferRequest, Sender<TransferResponse>),
    Stop,
}

/// The coordinator service.
pub struct Coordinator {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    config: CoordinatorConfig,
    /// The worker-shared serve context, retained so [`Coordinator::handle`]
    /// can hand out direct serve handles (the stampede plane's entry
    /// point, which bypasses the job channel entirely).
    shared: Arc<Shared>,
}

/// A cloneable, thread-safe handle that serves requests *directly* on
/// the calling thread — the same `serve_one` path the channel workers
/// run, minus the channel. This is the stampede plane's entry point:
/// `StampedeRunner` spawns its own worker pool, each worker cloning
/// one handle and calling [`ServeHandle::serve`] in a loop, so
/// admissions, ladder leads/piggybacks, lease epochs, and snapshot
/// resolves race on real wall-clock concurrency instead of queueing
/// behind one `mpsc` receiver lock.
///
/// The handle borrows nothing from the `Coordinator` — it keeps the
/// shared context alive on its own — but the usual lifecycle rule
/// still applies: any attached fabric/feedback service outlives every
/// handle.
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<Shared>,
    default_opt: OptimizerKind,
}

impl ServeHandle {
    /// Serve one request on the calling thread and return its response.
    pub fn serve(&self, request: &TransferRequest) -> TransferResponse {
        serve_one(&self.shared, request, self.default_opt)
    }
}

impl Coordinator {
    /// A coordinator serving from a knowledge base frozen at startup
    /// (generation 0; no log ingestion).
    pub fn new(
        kb: Arc<KnowledgeBase>,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let knowledge =
            Knowledge::Global { slot: Arc::new(SnapshotSlot::new(kb)), feedback: None };
        Coordinator::build(knowledge, history, config)
    }

    /// A coordinator wired into the knowledge lifecycle service: it
    /// serves from the service's hot-swappable snapshot slot, offers
    /// every completed transfer to the ingestion queue, and feeds the
    /// drift-rate signal. The service outlives the coordinator — shut
    /// the coordinator down first.
    pub fn with_feedback(
        service: &FeedbackService,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let handles = FeedbackHandles {
            queue: service.queue(),
            stats: service.stats.clone(),
            ingest: service.ingest_stats(),
        };
        let knowledge =
            Knowledge::Global { slot: service.slot.clone(), feedback: Some(handles) };
        Coordinator::build(knowledge, history, config)
    }

    /// A coordinator serving from the sharded knowledge fabric: each
    /// request pins its own shard's snapshot, is tagged with the shard
    /// key and borrow status, and feeds its completed transfer back to
    /// that shard's ingest queue. The fabric's refresh lifecycle is
    /// driven separately — run a `fabric::FabricPollster` (or call
    /// `ShardRouter::tick_all`) alongside a long-lived coordinator, or
    /// borrowed shards never fit natively. The fabric outlives the
    /// coordinator — shut the coordinator down first.
    pub fn with_fabric(
        fabric: Arc<ShardRouter>,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        Coordinator::build(Knowledge::Fabric(fabric), history, config)
    }

    fn build(
        knowledge: Knowledge,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        match &knowledge {
            Knowledge::Global { feedback: Some(fb), .. } => {
                metrics.attach_feedback(fb.stats.clone());
                metrics.attach_ingest(fb.ingest.clone());
            }
            Knowledge::Global { .. } => {}
            Knowledge::Fabric(router) => metrics.attach_fabric(router.clone()),
        }
        if let Some(plane) = &config.probe {
            metrics.attach_probe(plane.clone());
        }
        if let Some(links) = &config.links {
            metrics.attach_links(links.clone());
        }
        // Train the ANN (and fit HARP/SP) once, shared by every worker.
        let annot = Arc::new(AnnOt::train(&history, config.seed ^ 0xA22));
        let sp = Arc::new(StaticParams::mine(&history));
        let harp = Arc::new(Harp::new(history));
        let shared = Arc::new(Shared {
            knowledge,
            annot,
            sp,
            harp,
            metrics: metrics.clone(),
            probe: config.probe.clone(),
            faults: config.faults.clone(),
            tap: config.tap.clone(),
            links: config.links.clone(),
            traces: config.traces.clone(),
        });
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(config.workers.max(1));
        for _ in 0..config.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let default_opt = config.default_optimizer;
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, shared, default_opt);
            }));
        }
        Coordinator { tx, workers, metrics, next_id: AtomicU64::new(1), config, shared }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// A direct serve handle over this coordinator's shared context
    /// (see [`ServeHandle`]): same knowledge, planes, metrics, tap, and
    /// trace sink as the channel workers, no channel in between.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle { shared: self.shared.clone(), default_opt: self.config.default_optimizer }
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, request: TransferRequest) -> Receiver<TransferResponse> {
        let (tx, rx) = channel();
        self.tx.send(Job::Run(request, tx)).expect("coordinator stopped");
        rx
    }

    /// Convenience: run a batch and wait for all responses (order
    /// preserved by request id).
    pub fn run_batch(&self, requests: Vec<TransferRequest>) -> Vec<TransferResponse> {
        let receivers: Vec<(u64, Receiver<TransferResponse>)> =
            requests.into_iter().map(|r| (r.id, self.submit(r))).collect();
        let mut out: Vec<TransferResponse> =
            receivers.into_iter().map(|(_, rx)| rx.recv().expect("worker died")).collect();
        out.sort_by_key(|r| r.id);
        out
    }

    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, shared: Arc<Shared>, default_opt: OptimizerKind) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(Job::Run(request, reply)) => {
                let response = serve_one(&shared, &request, default_opt);
                let _ = reply.send(response);
            }
            Ok(Job::Stop) | Err(_) => break,
        }
    }
}

/// Serve a single request: pin the current KB snapshot (routing to its
/// shard when the fabric is attached), build the hidden environment,
/// dispatch to the optimizer, record metrics, and feed the completed
/// transfer back to the knowledge loop it came from.
fn serve_one(
    shared: &Shared,
    request: &TransferRequest,
    default_opt: OptimizerKind,
) -> TransferResponse {
    // Pin one KB generation for the whole transfer: a refresh published
    // mid-request never mixes versions inside one decision. On the
    // fabric path the pin is per-shard, and routing never blocks on a
    // refresh or fails the request (fabric trouble serves the fallback).
    let (snapshot, shard, shard_key, borrowed): (_, Option<Arc<Shard>>, _, _) =
        match &shared.knowledge {
            Knowledge::Global { slot, .. } => (slot.resolve(), None, None, false),
            Knowledge::Fabric(router) => {
                let routed = router.route(ShardKey::of_request(request.testbed, &request.dataset));
                (routed.snapshot, routed.shard, Some(routed.key), routed.borrowed)
            }
        };
    // Probe key: the serving shard when the fabric routed us, the
    // request's natural shard otherwise — either way, concurrent
    // requests for the same network slice share one sampling ladder,
    // one estimate, and one trace label.
    let probe_key =
        shard_key.unwrap_or_else(|| ShardKey::of_request(request.testbed, &request.dataset));
    // The decision trace starts at routing, before the environment
    // exists; it rides the builder until the env can carry it.
    let mut trace =
        shared.traces.as_ref().map(|_| TraceBuilder::new(request.id, request.seed));
    if let Some(tb) = &mut trace {
        tb.note(TraceEvent::Route {
            key: probe_key.name(),
            borrowed,
            generation: snapshot.generation,
        });
    }
    let mut testbed = Testbed::by_id(request.testbed);
    // Injected faults shape the hidden environment first: a degraded
    // link narrows the pipe and a load step raises the diurnal floor,
    // for this transfer *and* for the ground-truth optimum it is scored
    // against — optimizers only ever see the fault through measurement.
    if let Some(board) = &shared.faults {
        board.shape(&mut testbed);
        if let Some(tb) = &mut trace {
            tb.note(TraceEvent::FaultConsult {
                bandwidth_mbps: testbed.path.link.bandwidth_mbps,
            });
        }
    }
    // Hidden network state: diurnal profile at submission time (plus
    // contending transfers), unless the request pins a state.
    let state = request
        .state_override
        .unwrap_or_else(|| hidden_state_for(&testbed, request.seed, request.t_submit));
    // Seeded by the request alone — never by which worker picked the
    // job — so identical request sets produce identical hidden-network
    // draws across runs and coordinators (the experiment harnesses
    // compare optimizers and knowledge sources on exactly that basis).
    let mut env = TransferEnv::new(testbed.clone(), request.dataset, state, request.seed);
    if let Some(tb) = trace.take() {
        env.attach_trace(tb);
    }
    let (_, optimal_mbps) = testbed.path.optimal(&request.dataset, &state, BETA);
    // Join the shared link before anything measures: from this moment
    // concurrent transfers on the network see this one (and it sees
    // them) through the contention plane. The occupancy observed at
    // admission is stamped onto whatever the probe plane learns, so
    // busy-link knowledge is never replayed as quiet-network truth.
    let occ = match &shared.links {
        Some(links) => {
            let lease = links.clone().admit(request.testbed, request.id);
            let view = lease.view();
            env.attach_link(lease);
            env.note(TraceEvent::LinkAdmit { epoch: view.epoch, streams: view.streams });
            ProbeOcc { epoch: view.epoch, streams: view.streams }
        }
        None => ProbeOcc::default(),
    };

    let kind = request.optimizer.unwrap_or(default_opt);
    // Every trace carries exactly one admission event: the probe
    // plane's is emitted inside `run_admitted_asm` (it knows the
    // lead/piggyback/serve verdict); every other dispatch consults the
    // pinned KB directly.
    let planed_asm = matches!(kind, OptimizerKind::Asm) && shared.probe.is_some();
    if !planed_asm {
        env.note(TraceEvent::Admission {
            mode: "direct",
            cluster: None,
            generation: snapshot.generation,
            reserved_mb: 0.0,
            warm_start: None,
            provenance: Provenance::Kb { generation: snapshot.generation, cluster: None },
        });
    }
    let started = Instant::now();
    let mut probe_mode: Option<ProbeMode> = None;
    let report = match kind {
        OptimizerKind::Asm => match &shared.probe {
            Some(plane) => {
                let (report, mode) =
                    run_asm_with_plane(plane, probe_key, &snapshot, &mut env, occ);
                probe_mode = Some(mode);
                report
            }
            None => AdaptiveSampling::new(&snapshot.kb).run(&mut env),
        },
        OptimizerKind::Go => GlobusOnline.run(&mut env),
        OptimizerKind::Sp => (*shared.sp).clone().run(&mut env),
        OptimizerKind::Sc => SingleChunk::default().run(&mut env),
        OptimizerKind::AnnOt => {
            // The shared ANN is read-only at run time; clone the thin
            // handle for the trait's &mut self.
            let mut model = (*shared.annot).clone();
            model.run(&mut env)
        }
        OptimizerKind::Harp => (*shared.harp).clone().run(&mut env),
        OptimizerKind::Nmt => NelderMeadTuner::default().run(&mut env),
    };
    let decision_wall_ns = started.elapsed().as_nanos() as u64;
    // Leave the shared link and keep what the transfer experienced
    // there for the response (the lease would release on drop anyway —
    // this is the observation, not the cleanup).
    let contention = env.release_link();
    shared.metrics.record(
        report.optimizer,
        report.achieved_mbps(),
        report.total_mb(),
        report.total_s(),
        report.sample_transfers(),
        decision_wall_ns,
    );
    // Fleet health plane: score achieved-vs-optimal on the serving
    // shard and leave a bounded flight summary behind.
    shared.metrics.ledger.score(&probe_key.name(), report.achieved_mbps(), optimal_mbps);
    shared.metrics.recorder.push(crate::telemetry::FlightRecord {
        id: request.id,
        optimizer: report.optimizer,
        shard: probe_key.name(),
        probe_mode: probe_mode.map(|m| m.name()),
        kb_generation: snapshot.generation,
        borrowed,
        samples: report.sample_transfers(),
        retunes: report.bulk_retunes(),
        total_mb: report.total_mb(),
        transfer_s: report.total_s(),
        achieved_mbps: report.achieved_mbps(),
        optimal_mbps,
    });
    // Sentry tick: one settlement at the request's virtual submission
    // time, on the post-release cut (the lease is already off the
    // link, so surviving occupancy is a genuine leak). The scenario
    // runner's `run_admitted` ticks at exactly the same point.
    shared.metrics.tick_sentry(
        request.t_submit,
        &crate::telemetry::Settlement {
            shard: probe_key.name(),
            network: request.testbed.name().to_string(),
            achieved_mbps: report.achieved_mbps(),
            optimal_mbps,
            generation: snapshot.generation,
            contended: contention.as_ref().map(|c| c.contended_s > 0.0).unwrap_or(false),
        },
    );
    match &shared.knowledge {
        Knowledge::Global { feedback: Some(fb), .. } => {
            // Drift-rate signal: bulk-phase re-tunes mean the surfaces no
            // longer describe current traffic (one of the refresh triggers).
            fb.stats.note_drift(report.bulk_retunes() as u64);
            // The completed transfer becomes tomorrow's knowledge. Offer is
            // non-blocking; a full queue drops the row and counts it.
            fb.queue.offer(completed_log(request, &testbed, &state, &report));
        }
        Knowledge::Global { .. } => {}
        Knowledge::Fabric(_) => {
            // Same loop, scoped to the serving shard: its drift signal,
            // its queue, its partitions. `shard` is None only on the
            // degraded fallback path, which has nothing to ingest into.
            if let Some(shard) = &shard {
                shard.stats.note_drift(report.bulk_retunes() as u64);
                shard.offer(completed_log(request, &testbed, &state, &report));
            }
        }
    }
    // Settlement spans close the trace: what the link lease observed,
    // what the probe plane's estimate now says for this shard, whether
    // the completed log was offered back to the knowledge loop, and the
    // terminal accounting. The whole block is skipped when no trace is
    // attached.
    if env.tracing() {
        if let Some(exposure) = &contention {
            env.note(TraceEvent::LeaseRelease {
                contended_s: exposure.contended_s,
                peak_neighbor_mbps: exposure.peak_neighbor_mbps,
            });
        }
        let estimate = if planed_asm {
            shared.probe.as_ref().and_then(|plane| plane.estimates().peek(probe_key))
        } else {
            None
        };
        let ingest_offered = match &shared.knowledge {
            Knowledge::Global { feedback, .. } => feedback.is_some(),
            Knowledge::Fabric(_) => shard.is_some(),
        };
        env.note(TraceEvent::Settle {
            estimate_surface: estimate.as_ref().map(|e| e.surface_idx),
            estimate_generation: estimate.as_ref().map(|e| e.generation),
            ingest_offered,
        });
        env.note(TraceEvent::Done {
            optimizer: report.optimizer.to_string(),
            achieved_mbps: report.achieved_mbps(),
            total_mb: report.total_mb(),
            samples: report.sample_transfers(),
        });
    }
    if let Some(sink) = &shared.traces {
        if let Some(tb) = env.take_trace() {
            sink.push(tb.finish());
        }
    }
    if let Some(tap) = &shared.tap {
        tap.push(TapEvent {
            id: request.id,
            t_submit: request.t_submit,
            optimizer: report.optimizer,
            kb_generation: snapshot.generation,
            shard_key,
            borrowed,
            probe_mode,
            samples: report.sample_transfers(),
            bulk_retunes: report.bulk_retunes(),
            total_mb: report.total_mb(),
            transfer_s: report.total_s(),
            achieved_mbps: report.achieved_mbps(),
            contention,
        });
    }
    TransferResponse {
        id: request.id,
        optimizer: report.optimizer,
        report,
        decision_wall_ns,
        optimal_mbps,
        kb_generation: snapshot.generation,
        shard_key,
        borrowed,
        probe_mode,
        contention,
    }
}

/// Run one ASM request through the shared probe plane: admission
/// decides whether this request leads the sampling ladder, piggybacks
/// on a concurrent leader, or serves straight from the decayed
/// estimate; afterwards the plane settles the probe budget and absorbs
/// what the run learned.
fn run_asm_with_plane(
    plane: &ProbePlane,
    key: ShardKey,
    snapshot: &KbSnapshot,
    env: &mut TransferEnv,
    occ: ProbeOcc,
) -> (RunReport, ProbeMode) {
    let expected_mb = plane.expected_sample_mb(env.dataset.total_mb());
    // Surface indices only mean something within one cluster's stack:
    // estimate validity and piggybacking are both keyed on it.
    let cluster_idx = snapshot.kb.query_idx(&env.request);
    let generation = snapshot.generation;
    let admission = plane.admit(key, cluster_idx, generation, expected_mb, occ);
    run_admitted_asm(
        plane, key, cluster_idx, generation, expected_mb, &snapshot.kb, env, admission, occ,
    )
}

/// Execute one ASM request for an already-decided admission: wire the
/// convergence hook, run the ladder/bulk, settle the plane. The single
/// body behind both the worker path above (which lets the plane decide
/// the admission) and the scenario runner's directly driven coalesced
/// bursts (which stage admissions themselves) — shared so the replay
/// can never stop mirroring production's settle logic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_admitted_asm<'kb>(
    plane: &'kb ProbePlane,
    key: ShardKey,
    cluster_idx: Option<usize>,
    generation: u64,
    expected_mb: f64,
    kb: &'kb KnowledgeBase,
    env: &mut TransferEnv,
    admission: Admission,
    occ: ProbeOcc,
) -> (RunReport, ProbeMode) {
    let mut asm = AdaptiveSampling::new(kb);
    asm.cluster_hint = cluster_idx; // don't repeat the centroid lookup
    match admission {
        Admission::Lead { guard, warm_start } => {
            asm.start_surface = warm_start;
            // A leader pays for fresh samples: the budget reservation
            // and (when an unconfident estimate seeded one) the
            // warm-start surface are the whole admission story.
            env.note(TraceEvent::Admission {
                mode: "lead",
                cluster: cluster_idx,
                generation,
                reserved_mb: expected_mb,
                warm_start,
                provenance: Provenance::Fresh,
            });
            // Followers are released the moment the ladder converges —
            // not when this whole transfer finishes. If the run never
            // reaches the ladder (cold-start KB), the unfired hook drops
            // with `asm` and its guard wakes followers via abort.
            asm.on_converged = Some(Box::new(move |outcome| {
                plane.lead_converged(key, cluster_idx, guard, outcome, generation, occ);
            }));
            let report = asm.run(env);
            plane.finish_led(
                key, cluster_idx, asm.outcome, &report, expected_mb, generation, occ,
            );
            (report, ProbeMode::Led)
        }
        Admission::Piggyback(result) => {
            asm.start_surface = Some(result.surface_idx);
            asm.skip_sampling = true;
            env.note(TraceEvent::Admission {
                mode: "piggyback",
                cluster: cluster_idx,
                generation,
                reserved_mb: 0.0,
                warm_start: Some(result.surface_idx),
                provenance: Provenance::Leader {
                    cluster: result.cluster_idx,
                    surface: result.surface_idx,
                    generation: result.generation,
                },
            });
            let report = asm.run(env);
            plane.finish_passive(key, cluster_idx, asm.outcome, &report, generation, occ);
            (report, ProbeMode::Piggybacked)
        }
        Admission::Serve(surface_idx) => {
            asm.start_surface = surface_idx;
            asm.skip_sampling = true;
            // Serve mode trusts stored knowledge: attribute the actual
            // estimate when the store still holds one for this shard,
            // the pinned KB otherwise (budget-forced serves with no
            // estimate land there).
            let provenance = match surface_idx.and_then(|_| plane.estimates().peek(key)) {
                Some(e) => Provenance::Estimate {
                    cluster: e.cluster_idx,
                    surface: e.surface_idx,
                    generation: e.generation,
                    occ_streams: e.occ.streams,
                },
                None => Provenance::Kb { generation, cluster: cluster_idx },
            };
            env.note(TraceEvent::Admission {
                mode: "serve",
                cluster: cluster_idx,
                generation,
                reserved_mb: 0.0,
                warm_start: surface_idx,
                provenance,
            });
            let report = asm.run(env);
            plane.finish_passive(key, cluster_idx, asm.outcome, &report, generation, occ);
            (report, ProbeMode::EstimateServed)
        }
    }
}

/// The hidden-state draw for a request: seeded by the request alone —
/// never by which worker picked the job — so identical request sets
/// produce identical hidden-network draws across runs and
/// coordinators. `pub(crate)` as the single source of truth: the
/// scenario runner's coalesced-burst path calls it too, so a directly
/// driven environment draws exactly what the worker path would have.
pub(crate) fn hidden_state_for(testbed: &Testbed, request_seed: u64, t_submit: f64) -> NetState {
    let mut state_rng = Rng::new(request_seed ^ 0x57A7E);
    let load = testbed.profile.sample_load(t_submit, &mut state_rng);
    let contention =
        Contention::sample(&mut state_rng, testbed.path.link.bandwidth_mbps, load);
    NetState { external_load: load, contention }
}

/// Render a completed request as a log row with the same schema the
/// offline analysis mines from historical logs: request shape, the
/// *final* parameter decision, and the steady throughput it sustained.
/// `pub(crate)` so the scenario engine's coalesced-burst path can feed
/// the serving shard exactly like the worker path does.
pub(crate) fn completed_log(
    request: &TransferRequest,
    testbed: &Testbed,
    state: &NetState,
    report: &RunReport,
) -> TransferLog {
    TransferLog {
        id: request.id,
        t_start: request.t_submit,
        pair: testbed.id.name().to_string(),
        rtt_ms: testbed.path.link.rtt_ms,
        bandwidth_mbps: testbed.path.link.bandwidth_mbps,
        tcp_buffer_mb: testbed.path.src.tcp_buffer_mb.min(testbed.path.dst.tcp_buffer_mb),
        disk_mbps: testbed.path.src.disk_mbps.min(testbed.path.dst.disk_mbps),
        avg_file_mb: request.dataset.avg_file_mb,
        num_files: request.dataset.num_files,
        cc: report.final_params.cc,
        p: report.final_params.p,
        pp: report.final_params.pp,
        throughput_mbps: report.final_steady_mbps(),
        duration_s: report.total_s(),
        contending_mbps: state.contention.rate_mbps,
        contending_streams: state.contention.streams,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::Dataset;
    use crate::sim::testbed::TestbedId;

    fn coordinator() -> Coordinator {
        let tb = Testbed::xsede();
        let rows = generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        Coordinator::new(kb, Arc::new(rows), CoordinatorConfig { workers: 3, ..Default::default() })
    }

    fn request(id: u64, opt: Option<OptimizerKind>) -> TransferRequest {
        TransferRequest {
            id,
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(60, 100.0),
            t_submit: 3_600.0 * (id as f64 % 24.0),
            state_override: None,
            optimizer: opt,
            seed: 1000 + id,
        }
    }

    #[test]
    fn serves_batch_in_order() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=6).map(|i| request(i, None)).collect();
        let responses = coord.run_batch(reqs);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert_eq!(r.optimizer, "ASM");
            assert!(r.report.achieved_mbps() > 0.0);
            assert!(r.optimal_mbps > 0.0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap["ASM"].requests, 6);
        coord.shutdown();
    }

    #[test]
    fn dispatches_every_optimizer_kind() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = OptimizerKind::all()
            .iter()
            .enumerate()
            .map(|(i, &k)| request(i as u64 + 1, Some(k)))
            .collect();
        let responses = coord.run_batch(reqs);
        let names: Vec<&str> = responses.iter().map(|r| r.optimizer).collect();
        for kind in OptimizerKind::all() {
            assert!(names.contains(&kind.name()), "missing {}", kind.name());
        }
        coord.shutdown();
    }

    #[test]
    fn frozen_coordinator_reports_generation_zero() {
        let coord = coordinator();
        let responses = coord.run_batch(vec![request(1, None)]);
        assert_eq!(responses[0].kb_generation, 0);
        assert_eq!(responses[0].shard_key, None);
        assert!(!responses[0].borrowed);
        coord.shutdown();
    }

    #[test]
    fn fabric_coordinator_tags_shard_and_borrow_status() {
        use crate::fabric::{FabricConfig, ShardConfig, ShardKey, ShardRouter};
        use crate::sim::dataset::SizeClass;

        let tb = Testbed::xsede();
        let rows =
            generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let dir =
            std::env::temp_dir().join(format!("dtopt_server_fabric_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fabric = Arc::new(
            ShardRouter::open(
                &dir,
                kb,
                FabricConfig {
                    shard: ShardConfig { min_native_rows: 1_000_000, ..Default::default() },
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let coord = Coordinator::with_fabric(
            fabric.clone(),
            Arc::new(rows),
            CoordinatorConfig { workers: 2, ..Default::default() },
        );
        let responses = coord.run_batch((1..=4).map(|i| request(i, None)).collect());
        for r in &responses {
            // Dataset::new(60, 100.0) ⇒ large; no native shard exists,
            // so the cold-started shard serves the borrowed fallback.
            assert_eq!(r.shard_key, Some(ShardKey::new(TestbedId::Xsede, SizeClass::Large)));
            assert!(r.borrowed);
            assert_eq!(r.kb_generation, 0);
        }
        // Completed transfers were offered to the shard's own queue.
        let shard = fabric
            .shard(&ShardKey::new(TestbedId::Xsede, SizeClass::Large))
            .expect("shard materialized");
        assert!(shard.flush_barrier(std::time::Duration::from_secs(30)));
        assert_eq!(shard.stats.rows_flushed.load(Ordering::Relaxed), 4);
        // The metrics block renders the per-shard fabric table AND the
        // pooled request-latency line (fabric mode must not replace it).
        let table = coord.metrics.render();
        assert!(table.contains("xsede/large"), "{table}");
        assert!(table.contains("fabric:"), "{table}");
        assert!(table.contains("request latency: p50"), "{table}");
        coord.shutdown();
        fabric.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probe_plane_attributes_modes_and_coalesces_sampling() {
        use crate::probe::{ProbeConfig, ProbePlane};

        let tb = Testbed::xsede();
        let rows =
            generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let plane = Arc::new(ProbePlane::new(ProbeConfig::default()));
        let coord = Coordinator::new(
            kb,
            Arc::new(rows),
            CoordinatorConfig { workers: 3, probe: Some(plane.clone()), ..Default::default() },
        );
        // A burst on one network slice: long enough transfers that the
        // independent path would sample on every request.
        let reqs: Vec<TransferRequest> = (1..=10)
            .map(|i| TransferRequest {
                id: i,
                testbed: TestbedId::Xsede,
                dataset: Dataset::new(400, 100.0),
                t_submit: 3_600.0 * 9.0,
                state_override: None,
                optimizer: Some(OptimizerKind::Asm),
                seed: 2_000 + i,
            })
            .collect();
        let responses = coord.run_batch(reqs);
        let led = responses
            .iter()
            .filter(|r| r.probe_mode == Some(crate::probe::ProbeMode::Led))
            .count();
        assert!(
            responses.iter().all(|r| r.probe_mode.is_some()),
            "every ASM response carries a probe_mode"
        );
        assert!(led >= 1, "someone must have led the sampling ladder");
        // Requests admitted after the first leader finished reuse its
        // knowledge instead of re-probing the same network.
        assert!(led < responses.len(), "the burst must coalesce, not all lead");
        let sampled: usize = responses.iter().map(|r| r.report.sample_transfers()).sum();
        assert!(
            sampled < responses.len(),
            "{sampled} sampling transfers across {} coalesced requests",
            responses.len()
        );
        let table = coord.metrics.render();
        assert!(table.contains("probe plane:"), "{table}");
        assert!(plane.stats.admissions() >= responses.len() as u64);
        coord.shutdown();
    }

    #[test]
    fn feedback_loop_ingests_and_hot_swaps() {
        use crate::feedback::{FeedbackConfig, FeedbackService, IngestConfig, RefreshPolicy};
        use crate::logs::store::LogStore;
        use std::time::Duration;

        let tb = Testbed::xsede();
        let rows = generate(
            &tb,
            &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 },
        );
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("dtopt_server_feedback_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let service = FeedbackService::start(
            kb,
            LogStore::open(&dir).unwrap(),
            FeedbackConfig {
                ingest: IngestConfig {
                    capacity: 256,
                    flush_batch: 4,
                    flush_interval: Duration::from_millis(5),
                },
                policy: RefreshPolicy {
                    min_new_rows: 1,
                    min_interval: Duration::ZERO,
                    ..Default::default()
                },
                background: false, // driven by tick() for determinism
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::with_feedback(
            &service,
            Arc::new(rows),
            CoordinatorConfig { workers: 2, ..Default::default() },
        );
        // Wave 1 serves from, and is attributed to, generation 0.
        let wave1 = coord.run_batch((1..=4).map(|i| request(i, None)).collect());
        assert!(wave1.iter().all(|r| r.kb_generation == 0));
        // Completed transfers reach the store; the policy then fires.
        assert!(service.flush_barrier(Duration::from_secs(30)), "ingest queue drained");
        assert_eq!(service.stats.rows_flushed.load(Ordering::Relaxed), 4);
        let fired = service.tick().unwrap();
        assert_eq!(fired.map(|(generation, _)| generation), Some(1));
        // Wave 2 observes the hot-swapped snapshot.
        let wave2 = coord.run_batch((5..=8).map(|i| request(i, None)).collect());
        assert!(wave2.iter().all(|r| r.kb_generation == 1));
        // Metrics render includes the service block.
        assert!(coord.metrics.render().contains("knowledge service: generation 1"));
        coord.shutdown();
        service.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_board_degrades_served_requests_and_tap_records_them() {
        use crate::sim::fault::FaultBoard;

        let tb = Testbed::xsede();
        let rows =
            generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let board = Arc::new(FaultBoard::new());
        let tap = Arc::new(ResponseTap::new());
        let coord = Coordinator::new(
            kb,
            Arc::new(rows),
            CoordinatorConfig {
                workers: 1,
                faults: Some(board.clone()),
                tap: Some(tap.clone()),
                ..Default::default()
            },
        );
        // Same request (same seed, same hidden draws) healthy vs under a
        // halved link: the degraded run must be scored against — and
        // bounded by — the narrower pipe.
        let healthy = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        board.degrade_link(TestbedId::Xsede, 0.3);
        let degraded = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        assert!(
            degraded.optimal_mbps < healthy.optimal_mbps,
            "degraded optimum {} vs healthy {}",
            degraded.optimal_mbps,
            healthy.optimal_mbps
        );
        assert!(
            degraded.report.achieved_mbps() < healthy.report.achieved_mbps(),
            "degraded {} vs healthy {}",
            degraded.report.achieved_mbps(),
            healthy.report.achieved_mbps()
        );
        board.restore_link(TestbedId::Xsede);
        let healed = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        assert_eq!(healed.optimal_mbps, healthy.optimal_mbps, "restore heals exactly");
        // The tap recorded all three responses in completion order.
        let taped = tap.drain();
        assert_eq!(taped.len(), 3);
        assert!(taped.iter().all(|e| e.optimizer == "GO" && e.total_mb > 0.0));
        assert!(tap.is_empty(), "drain empties the tap");
        coord.shutdown();
    }

    #[test]
    fn link_plane_makes_contention_bite_and_attributes_exposure() {
        use crate::netplane::LinkPlane;

        let tb = Testbed::xsede();
        let rows =
            generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());

        // Baseline: no plane — the old private-testbed world.
        let coord = Coordinator::new(kb.clone(), Arc::new(rows.clone()), CoordinatorConfig::default());
        let quiet = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        assert!(quiet.contention.is_none(), "no plane, no exposure");
        coord.shutdown();

        // Shared plane with a scripted ambient convoy: the same request
        // (same seed, same hidden draws) must achieve less, and the
        // response must attribute the pressure it ran under.
        let links = Arc::new(LinkPlane::shared());
        links.set_ambient(TestbedId::Xsede, 6_000.0, 48);
        let coord = Coordinator::new(
            kb.clone(),
            Arc::new(rows.clone()),
            CoordinatorConfig { workers: 1, links: Some(links.clone()), ..Default::default() },
        );
        let contended = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        let exposure = contended.contention.expect("plane attributes exposure");
        assert!(exposure.peak_neighbor_mbps >= 5_999.0, "{exposure:?}");
        assert!(exposure.mean_neighbor_mbps > 0.0);
        assert!(exposure.contended_s > 0.0);
        assert!(exposure.peak_carried_mbps <= 10_000.0 + 1e-6);
        assert!(
            contended.report.achieved_mbps() < quiet.report.achieved_mbps(),
            "convoy must bite: {} vs {}",
            contended.report.achieved_mbps(),
            quiet.report.achieved_mbps()
        );
        // Occupancy drains when the transfer completes.
        assert_eq!(links.active_total(), 0);
        assert_eq!(links.occupancy(TestbedId::Xsede).offered_mbps, 0.0);
        let table = coord.metrics.render();
        assert!(table.contains("link plane: shared mode"), "{table}");
        coord.shutdown();

        // Isolated plane: attribution exists, neighbors are invisible —
        // the pre-plane numbers for bake-off comparability.
        let isolated = Arc::new(LinkPlane::isolated());
        isolated.set_ambient(TestbedId::Xsede, 6_000.0, 48);
        let coord = Coordinator::new(
            kb,
            Arc::new(rows),
            CoordinatorConfig { workers: 1, links: Some(isolated), ..Default::default() },
        );
        let fiction = &coord.run_batch(vec![request(1, Some(OptimizerKind::Go))])[0];
        let exposure = fiction.contention.expect("isolated plane still attributes");
        assert_eq!(exposure.peak_neighbor_mbps, 0.0);
        assert_eq!(
            fiction.report.achieved_mbps(),
            quiet.report.achieved_mbps(),
            "isolated mode must reproduce the pre-plane numbers exactly"
        );
        coord.shutdown();
    }

    #[test]
    fn asm_decision_overhead_is_tiny() {
        // The paper: "Our online module needs almost constant time to
        // agree on the parameters". Wall-clock per request (excluding
        // simulated transfer time, which is virtual) must be far below
        // a real sample transfer.
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=10).map(|i| request(i, Some(OptimizerKind::Asm))).collect();
        let responses = coord.run_batch(reqs);
        for r in &responses {
            assert!(
                r.decision_wall_ns < 200_000_000,
                "ASM decision took {}",
                crate::util::timer::fmt_ns(r.decision_wall_ns as f64)
            );
        }
        coord.shutdown();
    }
}
