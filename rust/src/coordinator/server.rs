//! The transfer coordinator: a thread-pool service that accepts
//! transfer requests, routes each to the configured optimizer, runs it
//! against the simulated network, and aggregates metrics. This is the
//! L3 request path: knowledge-base queries and parameter decisions all
//! happen here in rust — python is long gone by now.
//!
//! The knowledge base is consumed through a hot-swappable snapshot
//! slot: each request pins the current generation for its whole run,
//! and — when a [`FeedbackService`] is attached — every completed
//! transfer is offered back to the ingestion queue so the refresher can
//! fold it into the next generation. Requests served during a refresh
//! are never paused; they simply finish on the generation they pinned.

use super::api::{OptimizerKind, TransferRequest, TransferResponse};
use super::metrics::Metrics;
use crate::baselines::annot::AnnOt;
use crate::baselines::go::GlobusOnline;
use crate::baselines::harp::Harp;
use crate::baselines::nmt::NelderMeadTuner;
use crate::baselines::sc::SingleChunk;
use crate::baselines::sp::StaticParams;
use crate::baselines::{Optimizer, RunReport, TransferEnv};
use crate::feedback::{FeedbackService, FeedbackStats, IngestQueue, SnapshotSlot};
use crate::logs::record::TransferLog;
use crate::offline::knowledge::KnowledgeBase;
use crate::online::asm::AdaptiveSampling;
use crate::sim::params::BETA;
use crate::sim::testbed::Testbed;
use crate::sim::traffic::Contention;
use crate::sim::transfer::NetState;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Default optimizer when a request doesn't specify one.
    pub default_optimizer: OptimizerKind,
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, default_optimizer: OptimizerKind::Asm, seed: 0xC0 }
    }
}

/// Handles into the knowledge lifecycle service, held per worker.
struct FeedbackHandles {
    queue: IngestQueue,
    stats: Arc<FeedbackStats>,
}

/// Shared read-only context every worker uses.
struct Shared {
    /// The hot-swappable knowledge base (generation 0 forever when no
    /// feedback service is attached).
    slot: Arc<SnapshotSlot>,
    annot: Arc<AnnOt>,
    sp: Arc<StaticParams>,
    /// Fitted once over the shared history; each HARP request clones
    /// the thin handle instead of re-running Normalizer::fit.
    harp: Arc<Harp>,
    metrics: Arc<Metrics>,
    feedback: Option<FeedbackHandles>,
}

enum Job {
    Run(TransferRequest, Sender<TransferResponse>),
    Stop,
}

/// The coordinator service.
pub struct Coordinator {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    config: CoordinatorConfig,
}

impl Coordinator {
    /// A coordinator serving from a knowledge base frozen at startup
    /// (generation 0; no log ingestion).
    pub fn new(
        kb: Arc<KnowledgeBase>,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        Coordinator::build(Arc::new(SnapshotSlot::new(kb)), history, config, None)
    }

    /// A coordinator wired into the knowledge lifecycle service: it
    /// serves from the service's hot-swappable snapshot slot, offers
    /// every completed transfer to the ingestion queue, and feeds the
    /// drift-rate signal. The service outlives the coordinator — shut
    /// the coordinator down first.
    pub fn with_feedback(
        service: &FeedbackService,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let handles = FeedbackHandles { queue: service.queue(), stats: service.stats.clone() };
        Coordinator::build(service.slot.clone(), history, config, Some(handles))
    }

    fn build(
        slot: Arc<SnapshotSlot>,
        history: Arc<Vec<TransferLog>>,
        config: CoordinatorConfig,
        feedback: Option<FeedbackHandles>,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        if let Some(fb) = &feedback {
            metrics.attach_feedback(fb.stats.clone());
        }
        // Train the ANN (and fit HARP/SP) once, shared by every worker.
        let annot = Arc::new(AnnOt::train(&history, config.seed ^ 0xA22));
        let sp = Arc::new(StaticParams::mine(&history));
        let harp = Arc::new(Harp::new(history));
        let shared = Arc::new(Shared {
            slot,
            annot,
            sp,
            harp,
            metrics: metrics.clone(),
            feedback,
        });
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(config.workers.max(1));
        for widx in 0..config.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let default_opt = config.default_optimizer;
            workers.push(std::thread::spawn(move || {
                worker_loop(widx, rx, shared, default_opt);
            }));
        }
        Coordinator { tx, workers, metrics, next_id: AtomicU64::new(1), config }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, request: TransferRequest) -> Receiver<TransferResponse> {
        let (tx, rx) = channel();
        self.tx.send(Job::Run(request, tx)).expect("coordinator stopped");
        rx
    }

    /// Convenience: run a batch and wait for all responses (order
    /// preserved by request id).
    pub fn run_batch(&self, requests: Vec<TransferRequest>) -> Vec<TransferResponse> {
        let receivers: Vec<(u64, Receiver<TransferResponse>)> =
            requests.into_iter().map(|r| (r.id, self.submit(r))).collect();
        let mut out: Vec<TransferResponse> =
            receivers.into_iter().map(|(_, rx)| rx.recv().expect("worker died")).collect();
        out.sort_by_key(|r| r.id);
        out
    }

    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }
}

fn worker_loop(
    widx: usize,
    rx: Arc<Mutex<Receiver<Job>>>,
    shared: Arc<Shared>,
    default_opt: OptimizerKind,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(Job::Run(request, reply)) => {
                let response = serve_one(&shared, &request, default_opt, widx as u64);
                let _ = reply.send(response);
            }
            Ok(Job::Stop) | Err(_) => break,
        }
    }
}

/// Serve a single request: pin the current KB snapshot, build the
/// hidden environment, dispatch to the optimizer, record metrics, and
/// feed the completed transfer back to the knowledge loop.
fn serve_one(
    shared: &Shared,
    request: &TransferRequest,
    default_opt: OptimizerKind,
    widx: u64,
) -> TransferResponse {
    // Pin one KB generation for the whole transfer: a refresh published
    // mid-request never mixes versions inside one decision.
    let snapshot = shared.slot.resolve();
    let testbed = Testbed::by_id(request.testbed);
    // Hidden network state: diurnal profile at submission time (plus
    // contending transfers), unless the request pins a state.
    let mut state_rng = Rng::new(request.seed ^ 0x57A7E);
    let state = request.state_override.unwrap_or_else(|| {
        let load = testbed.profile.sample_load(request.t_submit, &mut state_rng);
        let contention =
            Contention::sample(&mut state_rng, testbed.path.link.bandwidth_mbps, load);
        NetState { external_load: load, contention }
    });
    let mut env = TransferEnv::new(
        testbed.clone(),
        request.dataset,
        state,
        request.seed ^ widx.rotate_left(17),
    );
    let (_, optimal_mbps) = testbed.path.optimal(&request.dataset, &state, BETA);

    let kind = request.optimizer.unwrap_or(default_opt);
    let started = Instant::now();
    let report = match kind {
        OptimizerKind::Asm => AdaptiveSampling::new(&snapshot.kb).run(&mut env),
        OptimizerKind::Go => GlobusOnline.run(&mut env),
        OptimizerKind::Sp => (*shared.sp).clone().run(&mut env),
        OptimizerKind::Sc => SingleChunk::default().run(&mut env),
        OptimizerKind::AnnOt => {
            // The shared ANN is read-only at run time; clone the thin
            // handle for the trait's &mut self.
            let mut model = (*shared.annot).clone();
            model.run(&mut env)
        }
        OptimizerKind::Harp => (*shared.harp).clone().run(&mut env),
        OptimizerKind::Nmt => NelderMeadTuner::default().run(&mut env),
    };
    let decision_wall_ns = started.elapsed().as_nanos() as u64;
    shared.metrics.record(
        report.optimizer,
        report.achieved_mbps(),
        report.total_mb(),
        report.total_s(),
        report.sample_transfers(),
        decision_wall_ns,
    );
    if let Some(fb) = &shared.feedback {
        // Drift-rate signal: bulk-phase re-tunes mean the surfaces no
        // longer describe current traffic (one of the refresh triggers).
        fb.stats.note_drift(report.bulk_retunes() as u64);
        // The completed transfer becomes tomorrow's knowledge. Offer is
        // non-blocking; a full queue drops the row and counts it.
        fb.queue.offer(completed_log(request, &testbed, &state, &report));
    }
    TransferResponse {
        id: request.id,
        optimizer: report.optimizer,
        report,
        decision_wall_ns,
        optimal_mbps,
        kb_generation: snapshot.generation,
    }
}

/// Render a completed request as a log row with the same schema the
/// offline analysis mines from historical logs: request shape, the
/// *final* parameter decision, and the steady throughput it sustained.
fn completed_log(
    request: &TransferRequest,
    testbed: &Testbed,
    state: &NetState,
    report: &RunReport,
) -> TransferLog {
    TransferLog {
        id: request.id,
        t_start: request.t_submit,
        pair: testbed.id.name().to_string(),
        rtt_ms: testbed.path.link.rtt_ms,
        bandwidth_mbps: testbed.path.link.bandwidth_mbps,
        tcp_buffer_mb: testbed.path.src.tcp_buffer_mb.min(testbed.path.dst.tcp_buffer_mb),
        disk_mbps: testbed.path.src.disk_mbps.min(testbed.path.dst.disk_mbps),
        avg_file_mb: request.dataset.avg_file_mb,
        num_files: request.dataset.num_files,
        cc: report.final_params.cc,
        p: report.final_params.p,
        pp: report.final_params.pp,
        throughput_mbps: report.final_steady_mbps(),
        duration_s: report.total_s(),
        contending_mbps: state.contention.rate_mbps,
        contending_streams: state.contention.streams,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generate::{generate, GenConfig};
    use crate::offline::kmeans::NativeAssign;
    use crate::offline::pipeline::{build, OfflineConfig};
    use crate::sim::dataset::Dataset;
    use crate::sim::testbed::TestbedId;

    fn coordinator() -> Coordinator {
        let tb = Testbed::xsede();
        let rows = generate(&tb, &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 });
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        Coordinator::new(kb, Arc::new(rows), CoordinatorConfig { workers: 3, ..Default::default() })
    }

    fn request(id: u64, opt: Option<OptimizerKind>) -> TransferRequest {
        TransferRequest {
            id,
            testbed: TestbedId::Xsede,
            dataset: Dataset::new(60, 100.0),
            t_submit: 3_600.0 * (id as f64 % 24.0),
            state_override: None,
            optimizer: opt,
            seed: 1000 + id,
        }
    }

    #[test]
    fn serves_batch_in_order() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=6).map(|i| request(i, None)).collect();
        let responses = coord.run_batch(reqs);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert_eq!(r.optimizer, "ASM");
            assert!(r.report.achieved_mbps() > 0.0);
            assert!(r.optimal_mbps > 0.0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap["ASM"].requests, 6);
        coord.shutdown();
    }

    #[test]
    fn dispatches_every_optimizer_kind() {
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = OptimizerKind::all()
            .iter()
            .enumerate()
            .map(|(i, &k)| request(i as u64 + 1, Some(k)))
            .collect();
        let responses = coord.run_batch(reqs);
        let names: Vec<&str> = responses.iter().map(|r| r.optimizer).collect();
        for kind in OptimizerKind::all() {
            assert!(names.contains(&kind.name()), "missing {}", kind.name());
        }
        coord.shutdown();
    }

    #[test]
    fn frozen_coordinator_reports_generation_zero() {
        let coord = coordinator();
        let responses = coord.run_batch(vec![request(1, None)]);
        assert_eq!(responses[0].kb_generation, 0);
        coord.shutdown();
    }

    #[test]
    fn feedback_loop_ingests_and_hot_swaps() {
        use crate::feedback::{FeedbackConfig, FeedbackService, IngestConfig, RefreshPolicy};
        use crate::logs::store::LogStore;
        use std::time::Duration;

        let tb = Testbed::xsede();
        let rows = generate(
            &tb,
            &GenConfig { days: 5, arrivals_per_hour: 25.0, start_day: 0, seed: 61 },
        );
        let kb = Arc::new(build(&rows, &OfflineConfig::default(), &mut NativeAssign).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("dtopt_server_feedback_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let service = FeedbackService::start(
            kb,
            LogStore::open(&dir).unwrap(),
            FeedbackConfig {
                ingest: IngestConfig {
                    capacity: 256,
                    flush_batch: 4,
                    flush_interval: Duration::from_millis(5),
                },
                policy: RefreshPolicy {
                    min_new_rows: 1,
                    min_interval: Duration::ZERO,
                    ..Default::default()
                },
                background: false, // driven by tick() for determinism
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::with_feedback(
            &service,
            Arc::new(rows),
            CoordinatorConfig { workers: 2, ..Default::default() },
        );
        // Wave 1 serves from, and is attributed to, generation 0.
        let wave1 = coord.run_batch((1..=4).map(|i| request(i, None)).collect());
        assert!(wave1.iter().all(|r| r.kb_generation == 0));
        // Completed transfers reach the store; the policy then fires.
        assert!(service.flush_barrier(Duration::from_secs(30)), "ingest queue drained");
        assert_eq!(service.stats.rows_flushed.load(Ordering::Relaxed), 4);
        let fired = service.tick().unwrap();
        assert_eq!(fired.map(|(generation, _)| generation), Some(1));
        // Wave 2 observes the hot-swapped snapshot.
        let wave2 = coord.run_batch((5..=8).map(|i| request(i, None)).collect());
        assert!(wave2.iter().all(|r| r.kb_generation == 1));
        // Metrics render includes the service block.
        assert!(coord.metrics.render().contains("knowledge service: generation 1"));
        coord.shutdown();
        service.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn asm_decision_overhead_is_tiny() {
        // The paper: "Our online module needs almost constant time to
        // agree on the parameters". Wall-clock per request (excluding
        // simulated transfer time, which is virtual) must be far below
        // a real sample transfer.
        let coord = coordinator();
        let reqs: Vec<TransferRequest> = (1..=10).map(|i| request(i, Some(OptimizerKind::Asm))).collect();
        let responses = coord.run_batch(reqs);
        for r in &responses {
            assert!(
                r.decision_wall_ns < 200_000_000,
                "ASM decision took {}",
                crate::util::timer::fmt_ns(r.decision_wall_ns as f64)
            );
        }
        coord.shutdown();
    }
}
