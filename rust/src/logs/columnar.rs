//! Compact columnar log partitions (`day_<n>.dtc`) — the write-side half
//! of the zero-copy ingest layer.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! file   := "DTC1" group*
//! group  := rows:u32  payload_len:u32  payload
//! payload:= dict  col(id,u64) col(t,f64) col(rtt_ms,f64) col(bw_mbps,f64)
//!           col(buf_mb,f64) col(disk_mbps,f64) col(avg_file_mb,f64)
//!           col(num_files,u64) col(cc,u32) col(p,u32) col(pp,u32)
//!           col(th_mbps,f64) col(dur_s,f64) col(contend[0..5],f64)×5
//!           col(contend_streams,u32) col(pair_idx,u16)
//! dict   := count:u16 { len:u16 bytes }*count      (sorted, deduped pairs)
//! col    := value*rows, contiguous                  (per-column slices)
//! ```
//!
//! Each `append` batch becomes one self-contained row group, so the
//! format keeps `LogStore::append`'s O(batch) additive property — the
//! paper's "we do not need to combine it with previous logs" — while a
//! reader decodes fields with pure offset arithmetic over per-column
//! slices. `f64` bit patterns are preserved exactly (the JSONL writer
//! also guarantees f64 text round-trip), which is what makes
//! "byte-identical sufficient statistics across formats" a theorem
//! rather than a hope. Row count queries read only the 8-byte group
//! headers.

use super::record::TransferLog;
use super::scan::LogRowView;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fs;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

/// File magic for columnar partitions, version 1.
pub const MAGIC: &[u8; 4] = b"DTC1";

/// Partition filename extension (the store dispatches readers on it).
pub const EXT: &str = "dtc";

/// Column widths in payload order (id .. pair_idx). `num_files` is a
/// u64 column; `pair_idx` indexes the group dictionary.
const COL_WIDTHS: [usize; 20] = [8, 8, 8, 8, 8, 8, 8, 8, 4, 4, 4, 8, 8, 8, 8, 8, 8, 8, 4, 2];
const COL_ID: usize = 0;
const COL_T: usize = 1;
const COL_RTT: usize = 2;
const COL_BW: usize = 3;
const COL_BUF: usize = 4;
const COL_DISK: usize = 5;
const COL_AVG_FILE: usize = 6;
const COL_NUM_FILES: usize = 7;
const COL_CC: usize = 8;
const COL_P: usize = 9;
const COL_PP: usize = 10;
const COL_TH: usize = 11;
const COL_DUR: usize = 12;
const COL_CONTEND0: usize = 13;
const COL_STREAMS: usize = 18;
const COL_PAIR_IDX: usize = 19;

fn row_bytes() -> usize {
    COL_WIDTHS.iter().sum()
}

// ----------------------------------------------------------------------
// Writer
// ----------------------------------------------------------------------

/// Buffered appender for one columnar partition file: encodes each batch
/// into a reused scratch buffer and writes it as one row group.
pub struct PartitionWriter {
    file: BufWriter<fs::File>,
    scratch: Vec<u8>,
}

impl PartitionWriter {
    /// Open (creating if absent) in append mode; a new or empty file
    /// gets the magic first.
    pub fn open_append(path: &Path) -> Result<PartitionWriter> {
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {path:?}"))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {path:?}"))?
            .len();
        let mut w = PartitionWriter { file: BufWriter::new(file), scratch: Vec::new() };
        if len == 0 {
            w.file
                .write_all(MAGIC)
                .with_context(|| format!("writing magic to {path:?}"))?;
        }
        Ok(w)
    }

    /// Append one batch as a row group. Returns the bytes written.
    pub fn write_group(&mut self, rows: &[&TransferLog]) -> Result<u64> {
        if rows.is_empty() {
            return Ok(0);
        }
        ensure!(rows.len() <= u32::MAX as usize, "row group too large");
        self.scratch.clear();
        encode_group(rows, &mut self.scratch)?;
        let header_rows = (rows.len() as u32).to_le_bytes();
        let header_len = (self.scratch.len() as u32).to_le_bytes();
        self.file.write_all(&header_rows).context("writing columnar group header")?;
        self.file.write_all(&header_len).context("writing columnar group header")?;
        self.file.write_all(&self.scratch).context("writing columnar group payload")?;
        Ok(8 + self.scratch.len() as u64)
    }

    /// Flush the underlying buffer (dropping without finishing loses
    /// nothing on success paths but swallows flush errors).
    pub fn finish(mut self) -> Result<()> {
        self.file.flush().context("flushing columnar partition")?;
        Ok(())
    }
}

/// Encode one row group payload (dict + columns) into `out`.
fn encode_group(rows: &[&TransferLog], out: &mut Vec<u8>) -> Result<()> {
    // Dictionary: sorted deduped pair strings, u16-indexed.
    let mut dict: BTreeMap<&str, u16> = BTreeMap::new();
    for row in rows {
        let next = dict.len();
        dict.entry(row.pair.as_str()).or_insert_with(|| next as u16);
    }
    ensure!(dict.len() <= u16::MAX as usize, "too many distinct pairs in one batch");
    // BTreeMap iteration is sorted; renumber in that order for a
    // deterministic file regardless of row order within the batch.
    let mut idx = 0u16;
    for v in dict.values_mut() {
        *v = idx;
        idx += 1;
    }
    for entry in dict.keys() {
        ensure!(entry.len() <= u16::MAX as usize, "pair string too long");
    }
    out.extend_from_slice(&(dict.len() as u16).to_le_bytes());
    for entry in dict.keys() {
        out.extend_from_slice(&(entry.len() as u16).to_le_bytes());
        out.extend_from_slice(entry.as_bytes());
    }
    // Columns, each contiguous.
    for row in rows {
        out.extend_from_slice(&row.id.to_le_bytes());
    }
    for col in [COL_T, COL_RTT, COL_BW, COL_BUF, COL_DISK, COL_AVG_FILE] {
        for row in rows {
            out.extend_from_slice(&f64_field(row, col).to_le_bytes());
        }
    }
    for row in rows {
        out.extend_from_slice(&row.num_files.to_le_bytes());
    }
    for row in rows {
        out.extend_from_slice(&row.cc.to_le_bytes());
    }
    for row in rows {
        out.extend_from_slice(&row.p.to_le_bytes());
    }
    for row in rows {
        out.extend_from_slice(&row.pp.to_le_bytes());
    }
    for col in [COL_TH, COL_DUR] {
        for row in rows {
            out.extend_from_slice(&f64_field(row, col).to_le_bytes());
        }
    }
    for c in 0..5 {
        for row in rows {
            out.extend_from_slice(&row.contending_mbps[c].to_le_bytes());
        }
    }
    for row in rows {
        out.extend_from_slice(&row.contending_streams.to_le_bytes());
    }
    for row in rows {
        out.extend_from_slice(&dict[row.pair.as_str()].to_le_bytes());
    }
    Ok(())
}

fn f64_field(row: &TransferLog, col: usize) -> f64 {
    match col {
        COL_T => row.t_start,
        COL_RTT => row.rtt_ms,
        COL_BW => row.bandwidth_mbps,
        COL_BUF => row.tcp_buffer_mb,
        COL_DISK => row.disk_mbps,
        COL_AVG_FILE => row.avg_file_mb,
        COL_TH => row.throughput_mbps,
        COL_DUR => row.duration_s,
        _ => unreachable!("non-f64 column {col}"),
    }
}

// ----------------------------------------------------------------------
// Reader
// ----------------------------------------------------------------------

/// One decoded row group: absolute column base offsets into the
/// partition buffer plus the validated dictionary spans.
struct Group {
    rows: usize,
    /// Absolute byte spans of the dictionary strings (validated UTF-8).
    dict: Vec<(usize, usize)>,
    /// Absolute base offset of each column, payload order.
    col_off: [usize; 20],
}

/// A fully validated columnar partition held in memory: row access is
/// offset arithmetic over per-column slices, no per-row allocation.
pub struct ColumnarPartition {
    bytes: Vec<u8>,
    groups: Vec<Group>,
    total_rows: usize,
}

impl ColumnarPartition {
    /// Parse and validate a partition buffer: magic, group framing,
    /// payload sizes, dictionary UTF-8, and pair indexes. Everything
    /// after this is infallible slice reads.
    pub fn parse(bytes: Vec<u8>) -> Result<ColumnarPartition> {
        ensure!(bytes.len() >= 4 && &bytes[..4] == MAGIC, "bad columnar magic");
        let mut groups = Vec::new();
        let mut total_rows = 0usize;
        let mut pos = 4usize;
        while pos < bytes.len() {
            ensure!(pos + 8 <= bytes.len(), "truncated group header at byte {pos}");
            let rows = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let payload_len =
                u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap()) as usize;
            pos += 8;
            ensure!(pos + payload_len <= bytes.len(), "truncated group payload at byte {pos}");
            let payload_end = pos + payload_len;
            // Dictionary.
            ensure!(payload_len >= 2, "truncated dictionary at byte {pos}");
            let dict_count = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
            let mut dpos = pos + 2;
            let mut dict = Vec::with_capacity(dict_count);
            for _ in 0..dict_count {
                ensure!(dpos + 2 <= payload_end, "truncated dictionary entry");
                let len =
                    u16::from_le_bytes(bytes[dpos..dpos + 2].try_into().unwrap()) as usize;
                dpos += 2;
                ensure!(dpos + len <= payload_end, "truncated dictionary entry");
                std::str::from_utf8(&bytes[dpos..dpos + len])
                    .context("invalid utf8 in pair dictionary")?;
                dict.push((dpos, len));
                dpos += len;
            }
            // Columns.
            ensure!(
                payload_end - dpos == rows * row_bytes(),
                "group payload size mismatch: {} column bytes for {rows} rows",
                payload_end - dpos
            );
            let mut col_off = [0usize; 20];
            let mut off = dpos;
            for (i, w) in COL_WIDTHS.iter().enumerate() {
                col_off[i] = off;
                off += w * rows;
            }
            let group = Group { rows, dict, col_off };
            // Validate pair indexes once so row access can't fail.
            for r in 0..rows {
                let pi = read_u16(&bytes, group.col_off[COL_PAIR_IDX] + 2 * r) as usize;
                ensure!(pi < dict_count, "pair index {pi} out of range (dict {dict_count})");
            }
            total_rows += rows;
            groups.push(group);
            pos = payload_end;
        }
        Ok(ColumnarPartition { bytes, groups, total_rows })
    }

    pub fn row_count(&self) -> usize {
        self.total_rows
    }

    /// Borrow row `i` (0-based over the whole partition, groups in file
    /// order).
    pub fn view(&self, mut i: usize) -> Option<LogRowView<'_>> {
        for g in &self.groups {
            if i < g.rows {
                return Some(self.view_in(g, i));
            }
            i -= g.rows;
        }
        None
    }

    fn view_in(&self, g: &Group, r: usize) -> LogRowView<'_> {
        let b = &self.bytes;
        let f = |col: usize| read_f64(b, g.col_off[col] + 8 * r);
        let pi = read_u16(b, g.col_off[COL_PAIR_IDX] + 2 * r) as usize;
        let (doff, dlen) = g.dict[pi];
        let pair = std::str::from_utf8(&b[doff..doff + dlen]).expect("dict validated at parse");
        LogRowView::from_columns(
            read_u64(b, g.col_off[COL_ID] + 8 * r),
            f(COL_T),
            f(COL_RTT),
            f(COL_BW),
            f(COL_BUF),
            f(COL_DISK),
            f(COL_AVG_FILE),
            read_u64(b, g.col_off[COL_NUM_FILES] + 8 * r),
            read_u32(b, g.col_off[COL_CC] + 4 * r),
            read_u32(b, g.col_off[COL_P] + 4 * r),
            read_u32(b, g.col_off[COL_PP] + 4 * r),
            f(COL_TH),
            f(COL_DUR),
            [
                read_f64(b, g.col_off[COL_CONTEND0] + 8 * r),
                read_f64(b, g.col_off[COL_CONTEND0 + 1] + 8 * r),
                read_f64(b, g.col_off[COL_CONTEND0 + 2] + 8 * r),
                read_f64(b, g.col_off[COL_CONTEND0 + 3] + 8 * r),
                read_f64(b, g.col_off[COL_CONTEND0 + 4] + 8 * r),
            ],
            read_u32(b, g.col_off[COL_STREAMS] + 4 * r),
            pair,
        )
    }

    /// Iterate `(group_index, row_in_group)` pairs starting at global
    /// row `skip` — the store's cursor-skip path.
    pub(crate) fn cursor_at(&self, skip: usize) -> (usize, usize) {
        let mut remaining = skip;
        for (gi, g) in self.groups.iter().enumerate() {
            if remaining < g.rows {
                return (gi, remaining);
            }
            remaining -= g.rows;
        }
        (self.groups.len(), 0)
    }

    pub(crate) fn group_count(&self) -> usize {
        self.groups.len()
    }

    pub(crate) fn group_rows(&self, gi: usize) -> usize {
        self.groups[gi].rows
    }

    pub(crate) fn view_at(&self, gi: usize, r: usize) -> LogRowView<'_> {
        self.view_in(&self.groups[gi], r)
    }
}

fn read_u16(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(b[off..off + 2].try_into().unwrap())
}

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn read_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn read_f64(b: &[u8], off: usize) -> f64 {
    f64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

/// Row count from group headers only — no payload is read.
pub fn row_count_file(path: &Path) -> Result<usize> {
    let mut file = fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 4];
    file.read_exact(&mut magic)
        .with_context(|| format!("reading magic of {path:?}"))?;
    ensure!(&magic == MAGIC, "bad columnar magic in {path:?}");
    let mut count = 0usize;
    let mut header = [0u8; 8];
    loop {
        match file.read_exact(&mut header) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => bail!("reading group header of {path:?}: {e}"),
        }
        count += u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        let payload_len = u32::from_le_bytes(header[4..].try_into().unwrap());
        file.seek(SeekFrom::Current(payload_len as i64))
            .with_context(|| format!("seeking past group in {path:?}"))?;
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir();
        dir.join(format!("dtopt_dtc_{tag}_{}.dtc", std::process::id()))
    }

    fn variant(i: u64) -> TransferLog {
        let mut row = sample_log();
        row.id = i;
        row.t_start = 100.0 + i as f64 * 0.125;
        row.throughput_mbps = 1000.0 + i as f64;
        row.pair = if i % 3 == 0 { "xsede".into() } else { format!("pair_{}", i % 7) };
        row
    }

    #[test]
    fn group_roundtrip_exact_bits() {
        let path = tmpfile("rt");
        let _ = fs::remove_file(&path);
        let rows: Vec<TransferLog> = (0..37).map(variant).collect();
        let mut w = PartitionWriter::open_append(&path).unwrap();
        let refs: Vec<&TransferLog> = rows[..20].iter().collect();
        w.write_group(&refs).unwrap();
        let refs: Vec<&TransferLog> = rows[20..].iter().collect();
        w.write_group(&refs).unwrap();
        w.finish().unwrap();

        assert_eq!(row_count_file(&path).unwrap(), 37);
        let part = ColumnarPartition::parse(fs::read(&path).unwrap()).unwrap();
        assert_eq!(part.row_count(), 37);
        for (i, expect) in rows.iter().enumerate() {
            let got = part.view(i).unwrap().to_log();
            assert_eq!(&got, expect, "row {i}");
        }
        assert!(part.view(37).is_none());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn appends_accumulate_groups() {
        let path = tmpfile("acc");
        let _ = fs::remove_file(&path);
        for batch in 0..3u64 {
            let rows: Vec<TransferLog> = (batch * 5..batch * 5 + 5).map(variant).collect();
            let mut w = PartitionWriter::open_append(&path).unwrap();
            let refs: Vec<&TransferLog> = rows.iter().collect();
            w.write_group(&refs).unwrap();
            w.finish().unwrap();
        }
        let part = ColumnarPartition::parse(fs::read(&path).unwrap()).unwrap();
        assert_eq!(part.group_count(), 3);
        assert_eq!(part.row_count(), 15);
        assert_eq!(part.view(14).unwrap().id, 14);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn cursor_at_crosses_groups() {
        let path = tmpfile("cur");
        let _ = fs::remove_file(&path);
        let rows: Vec<TransferLog> = (0..10).map(variant).collect();
        let mut w = PartitionWriter::open_append(&path).unwrap();
        let refs: Vec<&TransferLog> = rows[..4].iter().collect();
        w.write_group(&refs).unwrap();
        let refs: Vec<&TransferLog> = rows[4..].iter().collect();
        w.write_group(&refs).unwrap();
        w.finish().unwrap();
        let part = ColumnarPartition::parse(fs::read(&path).unwrap()).unwrap();
        assert_eq!(part.cursor_at(0), (0, 0));
        assert_eq!(part.cursor_at(3), (0, 3));
        assert_eq!(part.cursor_at(4), (1, 0));
        assert_eq!(part.cursor_at(9), (1, 5));
        assert_eq!(part.cursor_at(10), (2, 0));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn truncated_and_corrupt_files_error() {
        assert!(ColumnarPartition::parse(b"DTC".to_vec()).is_err());
        assert!(ColumnarPartition::parse(b"NOPE".to_vec()).is_err());
        // Header claims more payload than exists.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(&999u32.to_le_bytes());
        assert!(ColumnarPartition::parse(bytes).is_err());
        // Valid file truncated mid-payload.
        let path = tmpfile("trunc");
        let _ = fs::remove_file(&path);
        let rows: Vec<TransferLog> = (0..8).map(variant).collect();
        let mut w = PartitionWriter::open_append(&path).unwrap();
        let refs: Vec<&TransferLog> = rows.iter().collect();
        w.write_group(&refs).unwrap();
        w.finish().unwrap();
        let full = fs::read(&path).unwrap();
        let cut = full[..full.len() - 10].to_vec();
        assert!(ColumnarPartition::parse(cut).is_err());
        let _ = fs::remove_file(&path);
    }
}
