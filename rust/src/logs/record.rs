//! Transfer-log record format — the schema the offline analysis mines.
//!
//! Mirrors what production Globus/GridFTP logs carry (paper §4): the
//! endpoint pair and its network characteristics, the dataset shape, the
//! parameter triple used, the achieved throughput, and aggregate rates
//! of the known contending transfers (paper Fig. 4's five categories).

use crate::sim::params::Params;
use crate::sim::traffic::Contention;
use crate::util::json::{write_number, write_string, Json, JsonError};

#[derive(Debug, Clone, PartialEq)]
pub struct TransferLog {
    pub id: u64,
    /// Simulation timestamp (seconds since epoch 0).
    pub t_start: f64,
    /// Endpoint-pair identifier (testbed name in the simulator).
    pub pair: String,
    pub rtt_ms: f64,
    pub bandwidth_mbps: f64,
    pub tcp_buffer_mb: f64,
    pub disk_mbps: f64,
    pub avg_file_mb: f64,
    pub num_files: u64,
    pub cc: u32,
    pub p: u32,
    pub pp: u32,
    pub throughput_mbps: f64,
    pub duration_s: f64,
    /// Aggregate Mbps of known contending transfers by category
    /// (same_pair, src_out, src_in, dst_out, dst_in).
    pub contending_mbps: [f64; 5],
    pub contending_streams: u32,
}

impl TransferLog {
    pub fn params(&self) -> Params {
        Params::new(self.cc, self.p, self.pp)
    }

    pub fn contention(&self) -> Contention {
        Contention { rate_mbps: self.contending_mbps, streams: self.contending_streams }
    }

    /// External-load intensity heuristic, paper Eq. 20: the fraction of
    /// the pipe not accounted for by observed outgoing traffic. With
    /// `th_out` = our transfer plus known path-sharing contenders, high
    /// intensity ⇔ much of the link was consumed by uncharted traffic
    /// (or the transfer was parameter-limited — the clustering stage
    /// separates those regimes by dataset/parameter similarity).
    pub fn load_intensity(&self) -> f64 {
        let th_out = self.throughput_mbps + self.contention().total_path_mbps();
        ((self.bandwidth_mbps - th_out) / self.bandwidth_mbps).clamp(0.0, 1.0)
    }

    /// The sufficient-statistics projection of this row — everything the
    /// additive offline update consumes, nothing it doesn't.
    pub fn suff(&self) -> SuffRow {
        SuffRow {
            t_start: self.t_start,
            rtt_ms: self.rtt_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            tcp_buffer_mb: self.tcp_buffer_mb,
            disk_mbps: self.disk_mbps,
            avg_file_mb: self.avg_file_mb,
            num_files: self.num_files,
            cc: self.cc,
            p: self.p,
            pp: self.pp,
            throughput_mbps: self.throughput_mbps,
            contending_mbps: self.contending_mbps,
            contending_streams: self.contending_streams,
        }
    }

    /// Serialize one JSONL line into a caller-owned buffer, byte-identical
    /// to `to_json().to_string_compact()` but with zero heap allocation
    /// per row. Keys are emitted in the `BTreeMap` (lexicographic) order
    /// the tree writer produces, so golden JSONL fixtures are unaffected
    /// by which writer produced them.
    pub fn write_jsonl(&self, out: &mut String) {
        out.push('{');
        out.push_str("\"avg_file_mb\":");
        write_number(self.avg_file_mb, out);
        out.push_str(",\"buf_mb\":");
        write_number(self.tcp_buffer_mb, out);
        out.push_str(",\"bw_mbps\":");
        write_number(self.bandwidth_mbps, out);
        out.push_str(",\"cc\":");
        write_number(self.cc as f64, out);
        out.push_str(",\"contend_mbps\":[");
        for (i, x) in self.contending_mbps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_number(*x, out);
        }
        out.push_str("],\"contend_streams\":");
        write_number(self.contending_streams as f64, out);
        out.push_str(",\"disk_mbps\":");
        write_number(self.disk_mbps, out);
        out.push_str(",\"dur_s\":");
        write_number(self.duration_s, out);
        out.push_str(",\"id\":");
        write_number(self.id as f64, out);
        out.push_str(",\"num_files\":");
        write_number(self.num_files as f64, out);
        out.push_str(",\"p\":");
        write_number(self.p as f64, out);
        out.push_str(",\"pair\":");
        write_string(&self.pair, out);
        out.push_str(",\"pp\":");
        write_number(self.pp as f64, out);
        out.push_str(",\"rtt_ms\":");
        write_number(self.rtt_ms, out);
        out.push_str(",\"t\":");
        write_number(self.t_start, out);
        out.push_str(",\"th_mbps\":");
        write_number(self.throughput_mbps, out);
        out.push('}');
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64))
            .set("t", Json::Num(self.t_start))
            .set("pair", Json::Str(self.pair.clone()))
            .set("rtt_ms", Json::Num(self.rtt_ms))
            .set("bw_mbps", Json::Num(self.bandwidth_mbps))
            .set("buf_mb", Json::Num(self.tcp_buffer_mb))
            .set("disk_mbps", Json::Num(self.disk_mbps))
            .set("avg_file_mb", Json::Num(self.avg_file_mb))
            .set("num_files", Json::Num(self.num_files as f64))
            .set("cc", Json::Num(self.cc as f64))
            .set("p", Json::Num(self.p as f64))
            .set("pp", Json::Num(self.pp as f64))
            .set("th_mbps", Json::Num(self.throughput_mbps))
            .set("dur_s", Json::Num(self.duration_s))
            .set("contend_mbps", Json::from_f64_slice(&self.contending_mbps))
            .set("contend_streams", Json::Num(self.contending_streams as f64));
        o
    }

    pub fn from_json(v: &Json) -> Result<TransferLog, JsonError> {
        let cm = v.req_vec_f64("contend_mbps")?;
        let mut contending_mbps = [0.0; 5];
        for (i, x) in cm.iter().take(5).enumerate() {
            contending_mbps[i] = *x;
        }
        Ok(TransferLog {
            id: v.req_f64("id")? as u64,
            t_start: v.req_f64("t")?,
            pair: v.req_str("pair")?.to_string(),
            rtt_ms: v.req_f64("rtt_ms")?,
            bandwidth_mbps: v.req_f64("bw_mbps")?,
            tcp_buffer_mb: v.req_f64("buf_mb")?,
            disk_mbps: v.req_f64("disk_mbps")?,
            avg_file_mb: v.req_f64("avg_file_mb")?,
            num_files: v.req_f64("num_files")? as u64,
            cc: v.req_f64("cc")? as u32,
            p: v.req_f64("p")? as u32,
            pp: v.req_f64("pp")? as u32,
            throughput_mbps: v.req_f64("th_mbps")?,
            duration_s: v.req_f64("dur_s")?,
            contending_mbps,
            contending_streams: v.req_f64("contend_streams")? as u32,
        })
    }
}

/// The fields of a [`TransferLog`] the additive offline analysis actually
/// consumes — the sufficient-statistics contract of `pipeline::update`:
/// clustering features (network + dataset shape), the parameter triple,
/// achieved throughput, contention (for the Eq. 20 intensity fallback),
/// and `t_start` (for `built_through_day`). Deliberately excludes `id`,
/// `pair`, and `duration_s`, which the update never reads — so the lazy
/// JSONL scanner can hand the refresher `Copy` rows with no per-row heap
/// allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SuffRow {
    pub t_start: f64,
    pub rtt_ms: f64,
    pub bandwidth_mbps: f64,
    pub tcp_buffer_mb: f64,
    pub disk_mbps: f64,
    pub avg_file_mb: f64,
    pub num_files: u64,
    pub cc: u32,
    pub p: u32,
    pub pp: u32,
    pub throughput_mbps: f64,
    pub contending_mbps: [f64; 5],
    pub contending_streams: u32,
}

impl SuffRow {
    /// Expand back into a `TransferLog` proxy with the non-sufficient
    /// fields zeroed. `String::new()` does not allocate, so this is
    /// heap-free — it lets the suff path reuse the exact `update` code
    /// (identical Welford push order ⇒ bit-identical statistics) instead
    /// of maintaining a parallel copy of the feature/intensity math.
    pub fn to_log(&self) -> TransferLog {
        TransferLog {
            id: 0,
            t_start: self.t_start,
            pair: String::new(),
            rtt_ms: self.rtt_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            tcp_buffer_mb: self.tcp_buffer_mb,
            disk_mbps: self.disk_mbps,
            avg_file_mb: self.avg_file_mb,
            num_files: self.num_files,
            cc: self.cc,
            p: self.p,
            pp: self.pp,
            throughput_mbps: self.throughput_mbps,
            duration_s: 0.0,
            contending_mbps: self.contending_mbps,
            contending_streams: self.contending_streams,
        }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    pub fn sample_log() -> TransferLog {
        TransferLog {
            id: 42,
            t_start: 1234.5,
            pair: "xsede".into(),
            rtt_ms: 40.0,
            bandwidth_mbps: 10_000.0,
            tcp_buffer_mb: 48.0,
            disk_mbps: 1_200.0,
            avg_file_mb: 128.0,
            num_files: 100,
            cc: 4,
            p: 8,
            pp: 2,
            throughput_mbps: 4_321.0,
            duration_s: 237.0,
            contending_mbps: [100.0, 50.0, 0.0, 0.0, 25.0],
            contending_streams: 12,
        }
    }

    #[test]
    fn json_roundtrip() {
        let log = sample_log();
        let text = log.to_json().to_string_compact();
        let back = TransferLog::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn load_intensity_bounds_and_monotonicity() {
        let mut log = sample_log();
        let base = log.load_intensity();
        assert!((0.0..=1.0).contains(&base));
        // Higher achieved throughput ⇒ lower inferred external load.
        log.throughput_mbps = 9_000.0;
        assert!(log.load_intensity() < base);
        // Saturated link from our own transfer ⇒ intensity ~0.
        log.throughput_mbps = 10_000.0;
        assert_eq!(log.load_intensity(), 0.0);
    }

    #[test]
    fn write_jsonl_matches_tree_writer() {
        let mut log = sample_log();
        // Exercise escaping and the scientific/plain number split.
        log.pair = "a\"b\\c\nd\té".into();
        log.t_start = 0.1234567890123456789;
        log.throughput_mbps = -2.5e30;
        log.disk_mbps = 1e-12;
        let mut buf = String::new();
        log.write_jsonl(&mut buf);
        assert_eq!(buf, log.to_json().to_string_compact());
        // And the streamed line parses back to the same row.
        let back = TransferLog::from_json(&Json::parse(&buf).unwrap()).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn suff_projection_roundtrip() {
        let log = sample_log();
        let suff = log.suff();
        let proxy = suff.to_log();
        assert_eq!(proxy.suff(), suff);
        // The proxy carries everything the additive update consumes.
        assert_eq!(proxy.params(), log.params());
        assert_eq!(proxy.contention(), log.contention());
        assert_eq!(proxy.load_intensity(), log.load_intensity());
        assert_eq!(proxy.t_start, log.t_start);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let v = Json::parse("{\"id\":1}").unwrap();
        assert!(TransferLog::from_json(&v).is_err());
    }
}
