//! Partitioned log store with per-day partitions in two on-disk formats.
//!
//! The paper's offline analysis is *additive*: "when new logs are
//! generated for a certain period of time, we do not need to combine it
//! with previous logs". The store mirrors that by partitioning rows into
//! per-day files so the pipeline can consume exactly the partitions that
//! are new since the last analysis.
//!
//! Two partition formats live behind one API (see DESIGN.md §Zero-copy
//! ingest):
//!
//! * `day_<n>.jsonl` — one JSON object per line. The interop and
//!   golden-fixture default: human-greppable, diffable, and the format
//!   external log producers write.
//! * `day_<n>.dtc` — columnar row groups (`columnar` module). The hot
//!   path for high-volume stores: O(1) row counts, per-column slice
//!   reads, ~2× smaller rows.
//!
//! Directories may mix formats; readers dispatch per partition by
//! extension (preferring `.dtc` when both exist — the `compact`
//! migration's crash window leaves both, and the `.dtc` is the complete,
//! verified one). The scanning read path (`scan_day`/`scan_range`)
//! yields borrowed [`LogRowView`]s with no `Json` tree and no per-row
//! allocation; `read_day` is built on top of it for callers that want
//! owned rows.

use super::columnar::{self, ColumnarPartition, PartitionWriter};
use super::record::TransferLog;
use super::scan::{scan_line, Lines, LogRowView};
use crate::sim::traffic::DAY_S;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::fs;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// On-disk partition format for *new* partitions. Existing partitions
/// always keep their format on append (a day never straddles formats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFormat {
    /// One JSON object per line — interop + golden-fixture default.
    Jsonl,
    /// Columnar row groups (`day_<n>.dtc`).
    Columnar,
}

impl StoreFormat {
    fn ext(self) -> &'static str {
        match self {
            StoreFormat::Jsonl => "jsonl",
            StoreFormat::Columnar => columnar::EXT,
        }
    }
}

/// Ingest-side telemetry, shared by every reader/writer on this store
/// (and its clones). Exported as the `logs.ingest.*` counter families —
/// all monotonic row/byte counts, no wall-clock anywhere, so they are
/// safe for the byte-deterministic metrics exports.
#[derive(Debug, Default)]
pub struct IngestStats {
    /// Rows appended (either format).
    pub rows_written: AtomicU64,
    /// Bytes appended (either format).
    pub bytes_written: AtomicU64,
    /// Rows yielded by the lazy scanning path (`scan_day`/`scan_range`).
    pub rows_scanned: AtomicU64,
    /// Partition bytes loaded for scanning.
    pub bytes_read: AtomicU64,
    /// Rows materialized into owned `TransferLog`s (`read_day` etc.) —
    /// the scan-vs-parse split is `rows_scanned` vs `rows_parsed`.
    pub rows_parsed: AtomicU64,
}

impl IngestStats {
    fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// Directory-backed partitioned log store.
pub struct LogStore {
    pub dir: PathBuf,
    format: StoreFormat,
    stats: Arc<IngestStats>,
}

impl LogStore {
    /// Open with the JSONL default for new partitions (interop-safe; the
    /// closed loop's own stores upgrade via [`Self::open_with_format`]).
    pub fn open(dir: impl AsRef<Path>) -> Result<LogStore> {
        Self::open_with_format(dir, StoreFormat::Jsonl)
    }

    /// Open, selecting the format newly created partitions use.
    pub fn open_with_format(dir: impl AsRef<Path>, format: StoreFormat) -> Result<LogStore> {
        fs::create_dir_all(dir.as_ref())
            .with_context(|| format!("creating log dir {:?}", dir.as_ref()))?;
        Ok(LogStore {
            dir: dir.as_ref().to_path_buf(),
            format,
            stats: Arc::new(IngestStats::default()),
        })
    }

    /// The format used for new partitions.
    pub fn format(&self) -> StoreFormat {
        self.format
    }

    /// Shared ingest counters (clone to wire into a telemetry registry).
    pub fn stats(&self) -> Arc<IngestStats> {
        self.stats.clone()
    }

    fn partition_path(&self, day: u64, format: StoreFormat) -> PathBuf {
        self.dir.join(format!("day_{day:05}.{}", format.ext()))
    }

    /// The on-disk partition for `day`, dispatching by extension.
    /// Prefers `.dtc` when both exist (see module docs).
    fn existing_partition(&self, day: u64) -> Option<(PathBuf, StoreFormat)> {
        for format in [StoreFormat::Columnar, StoreFormat::Jsonl] {
            let path = self.partition_path(day, format);
            if path.exists() {
                return Some((path, format));
            }
        }
        None
    }

    /// Append rows, routing each to its day partition. Each call writes
    /// one streamed batch per touched day: JSONL partitions stream
    /// through a `BufWriter` with one reused per-row buffer, columnar
    /// partitions append one row group.
    pub fn append(&self, rows: &[TransferLog]) -> Result<()> {
        let mut by_day: BTreeMap<u64, Vec<&TransferLog>> = BTreeMap::new();
        for row in rows {
            by_day.entry((row.t_start / DAY_S).floor() as u64).or_default().push(row);
        }
        let mut buf = String::new();
        for (day, day_rows) in by_day {
            // A day partition keeps its existing format; only brand-new
            // days take the store's configured format.
            let format = self.existing_partition(day).map(|(_, f)| f).unwrap_or(self.format);
            let path = self.partition_path(day, format);
            let written = match format {
                StoreFormat::Jsonl => {
                    let file = fs::OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(&path)
                        .with_context(|| format!("opening {path:?}"))?;
                    let mut out = BufWriter::new(file);
                    let mut written = 0u64;
                    for row in &day_rows {
                        buf.clear();
                        row.write_jsonl(&mut buf);
                        buf.push('\n');
                        out.write_all(buf.as_bytes())
                            .with_context(|| format!("appending row to {path:?}"))?;
                        written += buf.len() as u64;
                    }
                    out.flush().with_context(|| format!("flushing {path:?}"))?;
                    written
                }
                StoreFormat::Columnar => {
                    let mut w = PartitionWriter::open_append(&path)?;
                    let written = w.write_group(&day_rows)?;
                    w.finish()?;
                    written
                }
            };
            self.stats.add(&self.stats.rows_written, day_rows.len() as u64);
            self.stats.add(&self.stats.bytes_written, written);
        }
        Ok(())
    }

    /// Day indices present in the store (either format, deduped).
    pub fn days(&self) -> Result<Vec<u64>> {
        let mut days = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            let rest = name.strip_prefix("day_").and_then(|r| {
                r.strip_suffix(".jsonl")
                    .or_else(|| r.strip_suffix(&format!(".{}", columnar::EXT)))
            });
            if let Some(rest) = rest {
                if let Ok(d) = rest.parse::<u64>() {
                    days.push(d);
                }
            }
        }
        days.sort_unstable();
        days.dedup();
        Ok(days)
    }

    /// Number of rows in one partition, without parsing them. JSONL
    /// partitions count non-empty lines over a reused byte buffer (no
    /// per-line `String`); columnar partitions read only the group
    /// headers. Cursor bookkeeping uses this so it never pays a
    /// deserialization cost.
    pub fn row_count(&self, day: u64) -> Result<usize> {
        let (path, format) = self
            .existing_partition(day)
            .with_context(|| format!("no partition for day {day} in {:?}", self.dir))?;
        match format {
            StoreFormat::Jsonl => count_jsonl_rows(&path),
            StoreFormat::Columnar => columnar::row_count_file(&path),
        }
    }

    /// Load one partition for lazy scanning. The returned [`DayScan`]
    /// owns the partition bytes; its iterators yield borrowed
    /// [`LogRowView`]s — no `Json` tree, no per-row allocation.
    pub fn scan_day(&self, day: u64) -> Result<DayScan> {
        let (path, format) = self
            .existing_partition(day)
            .with_context(|| format!("no partition for day {day} in {:?}", self.dir))?;
        let bytes = fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        self.stats.add(&self.stats.bytes_read, bytes.len() as u64);
        let inner = match format {
            StoreFormat::Jsonl => DayScanInner::Jsonl(bytes),
            StoreFormat::Columnar => DayScanInner::Columnar(
                ColumnarPartition::parse(bytes).with_context(|| format!("parsing {path:?}"))?,
            ),
        };
        Ok(DayScan { path, stats: self.stats.clone(), inner })
    }

    /// Scans for every partition in `[from_day, to_day)`, in day order.
    pub fn scan_range(&self, from_day: u64, to_day: u64) -> Result<Vec<(u64, DayScan)>> {
        let mut scans = Vec::new();
        for day in self.days()? {
            if day >= from_day && day < to_day {
                scans.push((day, self.scan_day(day)?));
            }
        }
        Ok(scans)
    }

    /// Read one partition into owned rows (scan + materialize).
    pub fn read_day(&self, day: u64) -> Result<Vec<TransferLog>> {
        let scan = self.scan_day(day)?;
        let mut rows = Vec::new();
        for view in scan.rows() {
            rows.push(view?.to_log());
        }
        self.stats.add(&self.stats.rows_parsed, rows.len() as u64);
        Ok(rows)
    }

    /// Read every partition in `[from_day, to_day)`.
    pub fn read_range(&self, from_day: u64, to_day: u64) -> Result<Vec<TransferLog>> {
        let mut rows = Vec::new();
        for day in self.days()? {
            if day >= from_day && day < to_day {
                rows.extend(self.read_day(day)?);
            }
        }
        Ok(rows)
    }

    /// Read everything.
    pub fn read_all(&self) -> Result<Vec<TransferLog>> {
        self.read_range(0, u64::MAX)
    }

    /// Migrate every JSONL partition to columnar, in place. Idempotent;
    /// each original is removed only after the freshly written `.dtc`
    /// has been re-read and verified row-for-row. A day already carrying
    /// both formats (the crash window of a previous run) keeps the
    /// `.dtc` if it holds at least the JSONL's rows, else errors.
    pub fn compact(&self) -> Result<CompactReport> {
        let mut report = CompactReport::default();
        for day in self.days()? {
            let jsonl = self.partition_path(day, StoreFormat::Jsonl);
            let dtc = self.partition_path(day, StoreFormat::Columnar);
            if !jsonl.exists() {
                report.already_columnar.push(day);
                continue;
            }
            if dtc.exists() {
                // Crash window: verify the columnar copy subsumes the
                // JSONL before dropping the original.
                let dtc_rows = columnar::row_count_file(&dtc)?;
                let jsonl_rows = count_jsonl_rows(&jsonl)?;
                ensure!(
                    dtc_rows >= jsonl_rows,
                    "day {day}: {dtc:?} has {dtc_rows} rows but {jsonl:?} has {jsonl_rows}; \
                     refusing to drop the larger original"
                );
                ColumnarPartition::parse(fs::read(&dtc)?)
                    .with_context(|| format!("verifying {dtc:?}"))?;
                fs::remove_file(&jsonl)
                    .with_context(|| format!("removing migrated {jsonl:?}"))?;
                report.migrated.push(day);
                continue;
            }
            let rows = self.read_day(day)?;
            let tmp = self.dir.join(format!("day_{day:05}.{}.tmp", columnar::EXT));
            let _ = fs::remove_file(&tmp);
            {
                let mut w = PartitionWriter::open_append(&tmp)?;
                let refs: Vec<&TransferLog> = rows.iter().collect();
                w.write_group(&refs)?;
                w.finish()?;
            }
            // Verified re-read before the original goes away.
            let part = ColumnarPartition::parse(
                fs::read(&tmp).with_context(|| format!("re-reading {tmp:?}"))?,
            )
            .with_context(|| format!("verifying {tmp:?}"))?;
            ensure!(
                part.row_count() == rows.len(),
                "day {day}: verification found {} rows, expected {}",
                part.row_count(),
                rows.len()
            );
            for (i, expect) in rows.iter().enumerate() {
                let got = part.view(i).expect("row count verified").to_log();
                ensure!(&got == expect, "day {day}: row {i} did not survive migration");
            }
            fs::rename(&tmp, &dtc)
                .with_context(|| format!("installing {dtc:?}"))?;
            fs::remove_file(&jsonl)
                .with_context(|| format!("removing migrated {jsonl:?}"))?;
            report.migrated.push(day);
        }
        Ok(report)
    }
}

/// What [`LogStore::compact`] did, per day.
#[derive(Debug, Default)]
pub struct CompactReport {
    pub migrated: Vec<u64>,
    pub already_columnar: Vec<u64>,
}

/// Count non-empty JSONL lines with one reused 64 KiB buffer — no
/// per-line `String`, no parsing. A final unterminated line counts.
fn count_jsonl_rows(path: &Path) -> Result<usize> {
    let mut file = fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut buf = [0u8; 64 * 1024];
    let mut count = 0usize;
    let mut line_has_content = false;
    loop {
        let n = file.read(&mut buf).with_context(|| format!("reading {path:?}"))?;
        if n == 0 {
            break;
        }
        for &b in &buf[..n] {
            if b == b'\n' {
                if line_has_content {
                    count += 1;
                }
                line_has_content = false;
            } else if !matches!(b, b' ' | b'\t' | b'\r') {
                line_has_content = true;
            }
        }
    }
    if line_has_content {
        count += 1;
    }
    Ok(count)
}

/// One loaded partition, ready for zero-copy scanning.
pub struct DayScan {
    path: PathBuf,
    stats: Arc<IngestStats>,
    inner: DayScanInner,
}

enum DayScanInner {
    Jsonl(Vec<u8>),
    Columnar(ColumnarPartition),
}

impl DayScan {
    /// Iterate every row.
    pub fn rows(&self) -> ScanRows<'_> {
        self.rows_from(0)
    }

    /// Iterate rows starting after the first `skip` — the refresher's
    /// cursor path. Skipping is cheap: JSONL skips lines without field
    /// extraction, columnar starts mid-group by offset arithmetic.
    pub fn rows_from(&self, skip: usize) -> ScanRows<'_> {
        let inner = match &self.inner {
            DayScanInner::Jsonl(bytes) => RowsInner::Jsonl { lines: Lines::new(bytes), skip },
            DayScanInner::Columnar(part) => {
                let (gi, ri) = part.cursor_at(skip);
                RowsInner::Columnar { part, gi, ri }
            }
        };
        ScanRows { day: self, inner, scanned: 0 }
    }
}

enum RowsInner<'a> {
    Jsonl { lines: Lines<'a>, skip: usize },
    Columnar { part: &'a ColumnarPartition, gi: usize, ri: usize },
}

/// Iterator of borrowed row views over one partition. Folds its yield
/// count into the store's `rows_scanned` counter on drop.
pub struct ScanRows<'a> {
    day: &'a DayScan,
    inner: RowsInner<'a>,
    scanned: u64,
}

impl<'a> Iterator for ScanRows<'a> {
    type Item = Result<LogRowView<'a>>;

    fn next(&mut self) -> Option<Result<LogRowView<'a>>> {
        let item = match &mut self.inner {
            RowsInner::Jsonl { lines, skip } => loop {
                let (lineno, line) = lines.next()?;
                if *skip > 0 {
                    *skip -= 1;
                    continue;
                }
                break match scan_line(line) {
                    Ok(view) => Some(Ok(view)),
                    Err(e) => Some(Err(anyhow::anyhow!("{:?}:{lineno}: {e}", self.day.path))),
                };
            },
            RowsInner::Columnar { part, gi, ri } => loop {
                if *gi >= part.group_count() {
                    return None;
                }
                if *ri >= part.group_rows(*gi) {
                    *gi += 1;
                    *ri = 0;
                    continue;
                }
                let view = part.view_at(*gi, *ri);
                *ri += 1;
                break Some(Ok(view));
            },
        };
        if matches!(item, Some(Ok(_))) {
            self.scanned += 1;
        }
        item
    }
}

impl Drop for ScanRows<'_> {
    fn drop(&mut self) {
        if self.scanned > 0 {
            self.day.stats.add(&self.day.stats.rows_scanned, self.scanned);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn roundtrip_for(format: StoreFormat, tag: &str) {
        let dir = tmpdir(tag);
        let store = LogStore::open_with_format(&dir, format).unwrap();
        let mut a = sample_log();
        a.id = 1;
        a.t_start = 10.0; // day 0
        let mut b = sample_log();
        b.id = 2;
        b.t_start = DAY_S * 3.5; // day 3
        store.append(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(store.days().unwrap(), vec![0, 3]);
        assert_eq!(store.row_count(0).unwrap(), 1);
        assert_eq!(store.row_count(3).unwrap(), 1);
        assert_eq!(store.read_day(0).unwrap(), vec![a.clone()]);
        assert_eq!(store.read_day(3).unwrap(), vec![b.clone()]);
        assert_eq!(store.read_all().unwrap().len(), 2);
        assert_eq!(store.read_range(1, 4).unwrap(), vec![b]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrip_across_partitions() {
        roundtrip_for(StoreFormat::Jsonl, "rt");
    }

    #[test]
    fn roundtrip_across_partitions_columnar() {
        roundtrip_for(StoreFormat::Columnar, "rtc");
    }

    #[test]
    fn append_is_additive() {
        for (format, tag) in [(StoreFormat::Jsonl, "add"), (StoreFormat::Columnar, "addc")] {
            let dir = tmpdir(tag);
            let store = LogStore::open_with_format(&dir, format).unwrap();
            let mut row = sample_log();
            row.t_start = 100.0;
            store.append(&[row.clone()]).unwrap();
            store.append(&[row.clone()]).unwrap();
            assert_eq!(store.read_day(0).unwrap().len(), 2);
            assert_eq!(store.row_count(0).unwrap(), 2);
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn missing_day_errors() {
        let dir = tmpdir("missing");
        let store = LogStore::open(&dir).unwrap();
        assert!(store.read_day(99).is_err());
        assert!(store.row_count(99).is_err());
        assert!(store.scan_day(99).is_err());
        assert!(store.days().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_respects_existing_partition_format() {
        let dir = tmpdir("fmt");
        let mut row = sample_log();
        row.t_start = 50.0;
        // Day 0 is born JSONL...
        LogStore::open(&dir).unwrap().append(&[row.clone()]).unwrap();
        // ...and a columnar-configured store must keep appending to it
        // as JSONL (a day never straddles formats).
        let store = LogStore::open_with_format(&dir, StoreFormat::Columnar).unwrap();
        store.append(&[row.clone()]).unwrap();
        assert!(dir.join("day_00000.jsonl").exists());
        assert!(!dir.join("day_00000.dtc").exists());
        // A new day takes the configured format.
        row.t_start = DAY_S * 2.0 + 1.0;
        store.append(&[row.clone()]).unwrap();
        assert!(dir.join("day_00002.dtc").exists());
        assert_eq!(store.days().unwrap(), vec![0, 2]);
        assert_eq!(store.read_all().unwrap().len(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_skip_matches_slice() {
        for (format, tag) in [(StoreFormat::Jsonl, "skip"), (StoreFormat::Columnar, "skipc")] {
            let dir = tmpdir(tag);
            let store = LogStore::open_with_format(&dir, format).unwrap();
            let rows: Vec<TransferLog> = (0..20)
                .map(|i| {
                    let mut r = sample_log();
                    r.id = i;
                    r.t_start = 10.0 + i as f64;
                    r
                })
                .collect();
            // Two appends → two row groups in the columnar case, so the
            // skip crosses a group boundary.
            store.append(&rows[..8]).unwrap();
            store.append(&rows[8..]).unwrap();
            let scan = store.scan_day(0).unwrap();
            let fresh: Vec<TransferLog> =
                scan.rows_from(5).map(|v| v.unwrap().to_log()).collect();
            assert_eq!(fresh, rows[5..].to_vec());
            assert!(scan.rows_from(20).next().is_none());
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn ingest_stats_count_reads_and_writes() {
        let dir = tmpdir("stats");
        let store = LogStore::open(&dir).unwrap();
        let mut row = sample_log();
        row.t_start = 5.0;
        store.append(&[row.clone(), row.clone()]).unwrap();
        let stats = store.stats();
        assert_eq!(stats.rows_written.load(Ordering::Relaxed), 2);
        assert!(stats.bytes_written.load(Ordering::Relaxed) > 0);
        let _ = store.read_day(0).unwrap();
        assert_eq!(stats.rows_scanned.load(Ordering::Relaxed), 2);
        assert_eq!(stats.rows_parsed.load(Ordering::Relaxed), 2);
        assert!(stats.bytes_read.load(Ordering::Relaxed) > 0);
        // A cursor-skipped scan counts only the rows it yields.
        let scan = store.scan_day(0).unwrap();
        let n = scan.rows_from(1).count();
        assert_eq!(n, 1);
        drop(scan);
        assert_eq!(stats.rows_scanned.load(Ordering::Relaxed), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_jsonl_line_errors_with_location() {
        let dir = tmpdir("badline");
        let store = LogStore::open(&dir).unwrap();
        let mut row = sample_log();
        row.t_start = 5.0;
        store.append(&[row]).unwrap();
        // Corrupt the partition with a truncated second line.
        let path = dir.join("day_00000.jsonl");
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"id\":1,");
        fs::write(&path, text).unwrap();
        let err = store.read_day(0).unwrap_err().to_string();
        assert!(err.contains(":2:"), "error should carry line number: {err}");
        assert_eq!(store.row_count(0).unwrap(), 2, "count is lexical, not parsed");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_migrates_and_is_idempotent() {
        let dir = tmpdir("compact");
        let store = LogStore::open(&dir).unwrap();
        let rows: Vec<TransferLog> = (0..12)
            .map(|i| {
                let mut r = sample_log();
                r.id = i;
                r.t_start = if i < 7 { 10.0 } else { DAY_S + 10.0 };
                r
            })
            .collect();
        store.append(&rows).unwrap();
        let before = store.read_all().unwrap();
        let report = store.compact().unwrap();
        assert_eq!(report.migrated, vec![0, 1]);
        assert!(!dir.join("day_00000.jsonl").exists());
        assert!(dir.join("day_00000.dtc").exists());
        assert_eq!(store.read_all().unwrap(), before);
        // Second run: nothing left to do.
        let report = store.compact().unwrap();
        assert!(report.migrated.is_empty());
        assert_eq!(report.already_columnar, vec![0, 1]);
        assert_eq!(store.read_all().unwrap(), before);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_format_directory_reads_both() {
        let dir = tmpdir("mixed");
        let store = LogStore::open(&dir).unwrap();
        let mut a = sample_log();
        a.id = 1;
        a.t_start = 10.0;
        store.append(&[a.clone()]).unwrap();
        let colstore = LogStore::open_with_format(&dir, StoreFormat::Columnar).unwrap();
        let mut b = sample_log();
        b.id = 2;
        b.t_start = DAY_S + 10.0;
        colstore.append(&[b.clone()]).unwrap();
        assert!(dir.join("day_00000.jsonl").exists());
        assert!(dir.join("day_00001.dtc").exists());
        for store in [&store, &colstore] {
            assert_eq!(store.days().unwrap(), vec![0, 1]);
            assert_eq!(store.read_range(0, 2).unwrap(), vec![a.clone(), b.clone()]);
            assert_eq!(store.row_count(0).unwrap(), 1);
            assert_eq!(store.row_count(1).unwrap(), 1);
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
