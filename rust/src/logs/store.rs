//! JSONL log store with per-day partitions.
//!
//! The paper's offline analysis is *additive*: "when new logs are
//! generated for a certain period of time, we do not need to combine it
//! with previous logs". The store mirrors that by partitioning rows into
//! `day_<n>.jsonl` files so the pipeline can consume exactly the
//! partitions that are new since the last analysis.

use super::record::TransferLog;
use crate::sim::traffic::DAY_S;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

/// Directory-backed partitioned log store.
pub struct LogStore {
    pub dir: PathBuf,
}

impl LogStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<LogStore> {
        fs::create_dir_all(dir.as_ref())
            .with_context(|| format!("creating log dir {:?}", dir.as_ref()))?;
        Ok(LogStore { dir: dir.as_ref().to_path_buf() })
    }

    fn partition_path(&self, day: u64) -> PathBuf {
        self.dir.join(format!("day_{day:05}.jsonl"))
    }

    /// Append rows, routing each to its day partition.
    pub fn append(&self, rows: &[TransferLog]) -> Result<()> {
        let mut by_day: BTreeMap<u64, Vec<&TransferLog>> = BTreeMap::new();
        for row in rows {
            by_day.entry((row.t_start / DAY_S).floor() as u64).or_default().push(row);
        }
        for (day, day_rows) in by_day {
            let path = self.partition_path(day);
            let mut file = fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening {path:?}"))?;
            let mut buf = String::new();
            for row in day_rows {
                buf.push_str(&row.to_json().to_string_compact());
                buf.push('\n');
            }
            file.write_all(buf.as_bytes())?;
        }
        Ok(())
    }

    /// Day indices present in the store.
    pub fn days(&self) -> Result<Vec<u64>> {
        let mut days = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix("day_").and_then(|r| r.strip_suffix(".jsonl")) {
                if let Ok(d) = rest.parse::<u64>() {
                    days.push(d);
                }
            }
        }
        days.sort_unstable();
        Ok(days)
    }

    /// Number of rows in one partition, without parsing them (one
    /// non-empty JSONL line per row). Cursor bookkeeping uses this so
    /// it never pays the deserialization cost of `read_day`.
    pub fn row_count(&self, day: u64) -> Result<usize> {
        let path = self.partition_path(day);
        let file = fs::File::open(&path).with_context(|| format!("opening {path:?}"))?;
        let mut count = 0usize;
        for line in BufReader::new(file).lines() {
            if !line?.trim().is_empty() {
                count += 1;
            }
        }
        Ok(count)
    }

    /// Read one partition.
    pub fn read_day(&self, day: u64) -> Result<Vec<TransferLog>> {
        let path = self.partition_path(day);
        let file = fs::File::open(&path).with_context(|| format!("opening {path:?}"))?;
        let mut rows = Vec::new();
        for (lineno, line) in BufReader::new(file).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(&line)
                .map_err(|e| anyhow::anyhow!("{path:?}:{}: {e}", lineno + 1))?;
            rows.push(
                TransferLog::from_json(&v)
                    .map_err(|e| anyhow::anyhow!("{path:?}:{}: {e}", lineno + 1))?,
            );
        }
        Ok(rows)
    }

    /// Read every partition in `[from_day, to_day)`.
    pub fn read_range(&self, from_day: u64, to_day: u64) -> Result<Vec<TransferLog>> {
        let mut rows = Vec::new();
        for day in self.days()? {
            if day >= from_day && day < to_day {
                rows.extend(self.read_day(day)?);
            }
        }
        Ok(rows)
    }

    /// Read everything.
    pub fn read_all(&self) -> Result<Vec<TransferLog>> {
        self.read_range(0, u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dtopt_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn roundtrip_across_partitions() {
        let dir = tmpdir("rt");
        let store = LogStore::open(&dir).unwrap();
        let mut a = sample_log();
        a.id = 1;
        a.t_start = 10.0; // day 0
        let mut b = sample_log();
        b.id = 2;
        b.t_start = DAY_S * 3.5; // day 3
        store.append(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(store.days().unwrap(), vec![0, 3]);
        assert_eq!(store.row_count(0).unwrap(), 1);
        assert_eq!(store.row_count(3).unwrap(), 1);
        assert_eq!(store.read_day(0).unwrap(), vec![a.clone()]);
        assert_eq!(store.read_day(3).unwrap(), vec![b.clone()]);
        assert_eq!(store.read_all().unwrap().len(), 2);
        assert_eq!(store.read_range(1, 4).unwrap(), vec![b]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_is_additive() {
        let dir = tmpdir("add");
        let store = LogStore::open(&dir).unwrap();
        let mut row = sample_log();
        row.t_start = 100.0;
        store.append(&[row.clone()]).unwrap();
        store.append(&[row.clone()]).unwrap();
        assert_eq!(store.read_day(0).unwrap().len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_day_errors() {
        let dir = tmpdir("missing");
        let store = LogStore::open(&dir).unwrap();
        assert!(store.read_day(99).is_err());
        assert!(store.days().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
