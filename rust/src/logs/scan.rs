//! Lazy JSONL field scanner — the zero-copy half of the ingest layer.
//!
//! The refresher and offline pipeline consume only the
//! sufficient-statistics fields of each row (see
//! [`crate::logs::record::SuffRow`]), yet `read_day` historically paid
//! for a full `Json` tree (a `BTreeMap` + `String` key per field) plus an
//! owned `TransferLog` per row. This module walks the partition bytes
//! once, extracting fields directly into a borrowed [`LogRowView`] with
//! no tree and no per-row heap allocation (the `pair` string stays a raw
//! byte span until someone asks for it).
//!
//! The scanner is a strict drop-in for the tree path: on any line the
//! `Json::parse` + `TransferLog::from_json` pipeline accepts, it produces
//! field-for-field identical values (same greedy number tokenization,
//! same `str::parse::<f64>`, same `as u32`/`as u64` casts, duplicate keys
//! last-wins, unknown keys skipped); on any line that pipeline rejects —
//! malformed syntax, truncation, missing or wrong-typed fields — it
//! errors rather than skewing statistics. The property tests at the
//! bottom pin that contract.

use super::record::{SuffRow, TransferLog};
use std::borrow::Cow;
use std::fmt;

/// Scanner failure: malformed syntax, truncation, or a missing/invalid
/// required field. Carries the byte offset within the line.
#[derive(Debug, Clone)]
pub struct ScanError {
    pub message: String,
}

impl ScanError {
    fn new(message: String) -> ScanError {
        ScanError { message }
    }
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "scan error: {}", self.message)
    }
}

impl std::error::Error for ScanError {}

/// One log row viewed in place: numeric fields are extracted (they are
/// `Copy`), the endpoint-pair string stays a borrowed raw span and is
/// only decoded on demand.
#[derive(Debug, Clone, Copy)]
pub struct LogRowView<'a> {
    pub id: u64,
    pub t_start: f64,
    pub rtt_ms: f64,
    pub bandwidth_mbps: f64,
    pub tcp_buffer_mb: f64,
    pub disk_mbps: f64,
    pub avg_file_mb: f64,
    pub num_files: u64,
    pub cc: u32,
    pub p: u32,
    pub pp: u32,
    pub throughput_mbps: f64,
    pub duration_s: f64,
    pub contending_mbps: [f64; 5],
    pub contending_streams: u32,
    /// Raw bytes between the quotes of the `pair` value — escapes (if
    /// any) not yet decoded, but validated at scan time.
    pair_raw: &'a [u8],
    pair_escaped: bool,
}

impl<'a> LogRowView<'a> {
    /// Build a view over already-decoded columnar data (the `.dtc`
    /// reader): `pair` carries no JSON escapes and must be valid UTF-8.
    pub(crate) fn from_columns(
        id: u64,
        t_start: f64,
        rtt_ms: f64,
        bandwidth_mbps: f64,
        tcp_buffer_mb: f64,
        disk_mbps: f64,
        avg_file_mb: f64,
        num_files: u64,
        cc: u32,
        p: u32,
        pp: u32,
        throughput_mbps: f64,
        duration_s: f64,
        contending_mbps: [f64; 5],
        contending_streams: u32,
        pair: &'a str,
    ) -> LogRowView<'a> {
        LogRowView {
            id,
            t_start,
            rtt_ms,
            bandwidth_mbps,
            tcp_buffer_mb,
            disk_mbps,
            avg_file_mb,
            num_files,
            cc,
            p,
            pp,
            throughput_mbps,
            duration_s,
            contending_mbps,
            contending_streams,
            pair_raw: pair.as_bytes(),
            pair_escaped: false,
        }
    }

    /// The endpoint pair, decoded lazily: borrowed straight from the
    /// partition bytes when the value carries no escapes (the common
    /// case — generator pairs are plain identifiers), owned otherwise.
    pub fn pair(&self) -> Cow<'a, str> {
        if self.pair_escaped {
            let mut out = String::new();
            decode_string(self.pair_raw, Some(&mut out))
                .expect("pair span validated at scan time");
            Cow::Owned(out)
        } else {
            Cow::Borrowed(
                std::str::from_utf8(self.pair_raw).expect("pair span validated at scan time"),
            )
        }
    }

    /// The sufficient-statistics projection — `Copy`, no allocation, and
    /// never touches the pair span. This is what the refresher feeds to
    /// `pipeline::update_suff`.
    pub fn suff(&self) -> SuffRow {
        SuffRow {
            t_start: self.t_start,
            rtt_ms: self.rtt_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            tcp_buffer_mb: self.tcp_buffer_mb,
            disk_mbps: self.disk_mbps,
            avg_file_mb: self.avg_file_mb,
            num_files: self.num_files,
            cc: self.cc,
            p: self.p,
            pp: self.pp,
            throughput_mbps: self.throughput_mbps,
            contending_mbps: self.contending_mbps,
            contending_streams: self.contending_streams,
        }
    }

    /// Materialize the full owned record (allocates the pair string) —
    /// the interop path `read_day` is built on.
    pub fn to_log(&self) -> TransferLog {
        TransferLog {
            id: self.id,
            t_start: self.t_start,
            pair: self.pair().into_owned(),
            rtt_ms: self.rtt_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            tcp_buffer_mb: self.tcp_buffer_mb,
            disk_mbps: self.disk_mbps,
            avg_file_mb: self.avg_file_mb,
            num_files: self.num_files,
            cc: self.cc,
            p: self.p,
            pp: self.pp,
            throughput_mbps: self.throughput_mbps,
            duration_s: self.duration_s,
            contending_mbps: self.contending_mbps,
            contending_streams: self.contending_streams,
        }
    }
}

/// Scan one JSONL line into a borrowed view. The line must be exactly
/// one JSON object (surrounding whitespace allowed, like `Json::parse`).
pub fn scan_line(bytes: &[u8]) -> Result<LogRowView<'_>, ScanError> {
    let mut s = Scanner { bytes, pos: 0 };
    s.skip_ws();
    s.expect(b'{')?;

    // Each required field starts "missing"; a valid-typed occurrence
    // sets it, a wrong-typed later duplicate poisons it back to None —
    // exactly the `BTreeMap` last-wins + extraction-time check of the
    // tree path.
    let mut id = None;
    let mut t_start = None;
    let mut rtt_ms = None;
    let mut bw_mbps = None;
    let mut buf_mb = None;
    let mut disk_mbps = None;
    let mut avg_file_mb = None;
    let mut num_files = None;
    let mut cc = None;
    let mut p = None;
    let mut pp = None;
    let mut th_mbps = None;
    let mut dur_s = None;
    let mut contend_streams = None;
    let mut contend: Option<[f64; 5]> = None;
    let mut pair: Option<(&[u8], bool)> = None;

    s.skip_ws();
    if s.peek() == Some(b'}') {
        s.pos += 1;
    } else {
        loop {
            s.skip_ws();
            let (key_raw, key_escaped) = s.string_span()?;
            s.skip_ws();
            s.expect(b':')?;
            s.skip_ws();
            // Keys with escapes are pathological; decode them so e.g.
            // "pp" still matches "pp" like the tree parser would.
            let mut decoded_key = String::new();
            let key: &[u8] = if key_escaped {
                decode_string(key_raw, Some(&mut decoded_key))?;
                decoded_key.as_bytes()
            } else {
                key_raw
            };
            match key {
                b"id" => id = s.number_or_skip()?,
                b"t" => t_start = s.number_or_skip()?,
                b"rtt_ms" => rtt_ms = s.number_or_skip()?,
                b"bw_mbps" => bw_mbps = s.number_or_skip()?,
                b"buf_mb" => buf_mb = s.number_or_skip()?,
                b"disk_mbps" => disk_mbps = s.number_or_skip()?,
                b"avg_file_mb" => avg_file_mb = s.number_or_skip()?,
                b"num_files" => num_files = s.number_or_skip()?,
                b"cc" => cc = s.number_or_skip()?,
                b"p" => p = s.number_or_skip()?,
                b"pp" => pp = s.number_or_skip()?,
                b"th_mbps" => th_mbps = s.number_or_skip()?,
                b"dur_s" => dur_s = s.number_or_skip()?,
                b"contend_streams" => contend_streams = s.number_or_skip()?,
                b"contend_mbps" => contend = s.f64_array_or_skip()?,
                b"pair" => {
                    pair = if s.peek() == Some(b'"') {
                        let (span, escaped) = s.string_span()?;
                        // Validate now so downstream accessors can't
                        // silently accept what `from_json` rejects.
                        decode_string(span, None)?;
                        Some((span, escaped))
                    } else {
                        s.skip_value()?;
                        None
                    };
                }
                _ => s.skip_value()?,
            }
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.pos += 1,
                Some(b'}') => {
                    s.pos += 1;
                    break;
                }
                _ => return Err(s.err("expected ',' or '}'")),
            }
        }
    }
    s.skip_ws();
    if s.pos != s.bytes.len() {
        return Err(s.err("trailing characters after JSON value"));
    }

    let req = |name: &str, v: Option<f64>| {
        v.ok_or_else(|| ScanError::new(format!("missing/invalid number field '{name}'")))
    };
    let (pair_raw, pair_escaped) = pair
        .ok_or_else(|| ScanError::new("missing/invalid string field 'pair'".to_string()))?;
    let contending_mbps = contend
        .ok_or_else(|| ScanError::new("missing/invalid array field 'contend_mbps'".to_string()))?;
    Ok(LogRowView {
        id: req("id", id)? as u64,
        t_start: req("t", t_start)?,
        rtt_ms: req("rtt_ms", rtt_ms)?,
        bandwidth_mbps: req("bw_mbps", bw_mbps)?,
        tcp_buffer_mb: req("buf_mb", buf_mb)?,
        disk_mbps: req("disk_mbps", disk_mbps)?,
        avg_file_mb: req("avg_file_mb", avg_file_mb)?,
        num_files: req("num_files", num_files)? as u64,
        cc: req("cc", cc)? as u32,
        p: req("p", p)? as u32,
        pp: req("pp", pp)? as u32,
        throughput_mbps: req("th_mbps", th_mbps)?,
        duration_s: req("dur_s", dur_s)?,
        contending_mbps,
        contending_streams: req("contend_streams", contend_streams)? as u32,
        pair_raw,
        pair_escaped,
    })
}

/// Iterator over the non-empty lines of a JSONL partition buffer,
/// yielding `(lineno, line_bytes)` — shared by the scanning reader and
/// the allocation-free skip/count paths in the store.
pub(crate) struct Lines<'a> {
    bytes: &'a [u8],
    pos: usize,
    lineno: usize,
}

impl<'a> Lines<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> Lines<'a> {
        Lines { bytes, pos: 0, lineno: 0 }
    }
}

impl<'a> Iterator for Lines<'a> {
    type Item = (usize, &'a [u8]);

    fn next(&mut self) -> Option<(usize, &'a [u8])> {
        while self.pos < self.bytes.len() {
            let start = self.pos;
            let end = memchr_nl(&self.bytes[start..])
                .map(|i| start + i)
                .unwrap_or(self.bytes.len());
            self.pos = end + 1; // Past the '\n' (or past EOF — loop exits).
            self.lineno += 1;
            let line = &self.bytes[start..end];
            if line.iter().any(|b| !matches!(b, b' ' | b'\t' | b'\r')) {
                return Some((self.lineno, line));
            }
        }
        None
    }
}

fn memchr_nl(haystack: &[u8]) -> Option<usize> {
    haystack.iter().position(|&b| b == b'\n')
}

// ----------------------------------------------------------------------
// The byte walker. Token-level semantics mirror `util::json::Parser`
// exactly — same whitespace set, same greedy number span, same escape
// grammar — so scan/parse agreement is structural, not coincidental.
// ----------------------------------------------------------------------

struct Scanner<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scanner<'a> {
    fn err(&self, msg: &str) -> ScanError {
        ScanError::new(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ScanError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// Raw span of a string literal (between the quotes, escapes left
    /// in place but structurally validated later) plus whether any
    /// escape is present.
    fn string_span(&mut self) -> Result<(&'a [u8], bool), ScanError> {
        self.expect(b'"')?;
        let start = self.pos;
        let mut escaped = false;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let span = &self.bytes[start..self.pos];
                    self.pos += 1;
                    return Ok((span, escaped));
                }
                Some(b'\\') => {
                    escaped = true;
                    self.pos += 1;
                    if self.pos >= self.bytes.len() {
                        return Err(self.err("unterminated string"));
                    }
                    self.pos += 1;
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Greedy number token, identical to the tree parser: optional '-',
    /// then every digit/`.`/`e`/`E`/`+`/`-` byte, then `str::parse`.
    fn number(&mut self) -> Result<f64, ScanError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>().map_err(|_| self.err("invalid number"))
    }

    /// A field value expected to be a number: `Some(x)` when it is,
    /// `None` when it's valid JSON of another type (the tree path only
    /// fails such rows at extraction time, and a later duplicate key can
    /// still repair them), hard error on malformed syntax.
    fn number_or_skip(&mut self) -> Result<Option<f64>, ScanError> {
        match self.peek() {
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Some(self.number()?)),
            _ => {
                self.skip_value()?;
                Ok(None)
            }
        }
    }

    /// A field value expected to be an all-numbers array (the
    /// `contend_mbps` shape): first five elements fill the fixed array
    /// (missing tail stays zero, like `from_json`'s `.take(5)`), every
    /// element must be a number or the field poisons to `None`.
    fn f64_array_or_skip(&mut self) -> Result<Option<[f64; 5]>, ScanError> {
        if self.peek() != Some(b'[') {
            self.skip_value()?;
            return Ok(None);
        }
        self.pos += 1;
        let mut out = [0.0; 5];
        let mut n = 0usize;
        let mut all_numbers = true;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Some(out));
        }
        loop {
            self.skip_ws();
            match self.peek() {
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    let x = self.number()?;
                    if n < 5 {
                        out[n] = x;
                    }
                    n += 1;
                }
                _ => {
                    self.skip_value()?;
                    all_numbers = false;
                }
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(if all_numbers { Some(out) } else { None });
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    /// Skip one complete JSON value of any type, validating structure
    /// (unknown keys must not let malformed bytes through).
    fn skip_value(&mut self) -> Result<(), ScanError> {
        match self.peek() {
            Some(b'"') => {
                let (span, _) = self.string_span()?;
                decode_string(span, None)
            }
            Some(b'{') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    let (span, _) = self.string_span()?;
                    decode_string(span, None)?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    self.skip_value()?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or '}'")),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.skip_value()?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or ']'")),
                    }
                }
            }
            Some(b't') => self.literal(b"true"),
            Some(b'f') => self.literal(b"false"),
            Some(b'n') => self.literal(b"null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(|_| ()),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &[u8]) -> Result<(), ScanError> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }
}

/// Validate (and optionally decode into `out`) the raw span of a string
/// literal, with the same escape grammar as the tree parser: the short
/// escapes, `\uXXXX` with surrogate pairs, UTF-8 validity of raw runs.
fn decode_string(raw: &[u8], mut out: Option<&mut String>) -> Result<(), ScanError> {
    let mut pos = 0usize;
    let fail = |msg: &str| ScanError::new(format!("{msg} in string"));
    while pos < raw.len() {
        if raw[pos] == b'\\' {
            pos += 1;
            let c = match raw.get(pos) {
                Some(b'"') => '"',
                Some(b'\\') => '\\',
                Some(b'/') => '/',
                Some(b'b') => '\u{8}',
                Some(b'f') => '\u{c}',
                Some(b'n') => '\n',
                Some(b'r') => '\r',
                Some(b't') => '\t',
                Some(b'u') => {
                    pos += 1;
                    let cp = hex4(raw, pos).ok_or_else(|| fail("invalid \\u escape"))?;
                    pos += 4;
                    let ch = if (0xD800..0xDC00).contains(&cp) {
                        if raw.get(pos) != Some(&b'\\') || raw.get(pos + 1) != Some(&b'u') {
                            return Err(fail("missing low surrogate"));
                        }
                        pos += 2;
                        let low = hex4(raw, pos).ok_or_else(|| fail("invalid \\u escape"))?;
                        pos += 4;
                        if !(0xDC00..0xE000).contains(&low) {
                            return Err(fail("invalid low surrogate"));
                        }
                        let combined = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                        char::from_u32(combined).ok_or_else(|| fail("invalid surrogate pair"))?
                    } else {
                        char::from_u32(cp).ok_or_else(|| fail("invalid \\u escape"))?
                    };
                    if let Some(out) = out.as_deref_mut() {
                        out.push(ch);
                    }
                    continue;
                }
                _ => return Err(fail("invalid escape")),
            };
            pos += 1;
            if let Some(out) = out.as_deref_mut() {
                out.push(c);
            }
        } else {
            // Raw UTF-8 run up to the next backslash.
            let end = raw[pos..]
                .iter()
                .position(|&b| b == b'\\')
                .map(|i| pos + i)
                .unwrap_or(raw.len());
            let run =
                std::str::from_utf8(&raw[pos..end]).map_err(|_| fail("invalid utf8"))?;
            if let Some(out) = out.as_deref_mut() {
                out.push_str(run);
            }
            pos = end;
        }
    }
    Ok(())
}

fn hex4(raw: &[u8], pos: usize) -> Option<u32> {
    if pos + 4 > raw.len() {
        return None;
    }
    let hex = std::str::from_utf8(&raw[pos..pos + 4]).ok()?;
    u32::from_str_radix(hex, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::record::tests::sample_log;
    use crate::util::json::Json;
    use crate::util::proptest::{forall, Config};
    use crate::util::rng::Rng;

    fn random_log(rng: &mut Rng) -> TransferLog {
        let pairs = ["xsede", "did clab", "a\"b", "p\\q", "é😀", "", "tab\there"];
        TransferLog {
            id: rng.below(1 << 40),
            t_start: rng.range_f64(0.0, 1e7),
            pair: pairs[rng.index(pairs.len())].to_string(),
            rtt_ms: rng.range_f64(0.05, 300.0),
            bandwidth_mbps: rng.range_f64(10.0, 100_000.0),
            tcp_buffer_mb: rng.range_f64(0.1, 512.0),
            disk_mbps: rng.range_f64(10.0, 10_000.0),
            avg_file_mb: rng.range_f64(1e-3, 4096.0),
            num_files: rng.below(1 << 20),
            cc: rng.below(64) as u32,
            p: rng.below(64) as u32,
            pp: rng.below(64) as u32,
            throughput_mbps: rng.range_f64(0.0, 100_000.0),
            duration_s: rng.range_f64(0.0, 1e5),
            contending_mbps: [
                rng.range_f64(0.0, 5_000.0),
                rng.range_f64(0.0, 5_000.0),
                rng.range_f64(0.0, 5_000.0),
                rng.range_f64(0.0, 5_000.0),
                rng.range_f64(0.0, 5_000.0),
            ],
            contending_streams: rng.below(256) as u32,
        }
    }

    fn assert_view_matches(view: &LogRowView, log: &TransferLog) -> Result<(), String> {
        let owned = view.to_log();
        if &owned != log {
            return Err(format!("scan mismatch: {owned:?} != {log:?}"));
        }
        if view.suff() != log.suff() {
            return Err("suff projection mismatch".to_string());
        }
        Ok(())
    }

    #[test]
    fn scan_agrees_with_tree_parse_on_writer_output() {
        forall(
            Config { cases: 256, seed: 0x5CA_1 },
            random_log,
            |log| {
                let line = log.to_json().to_string_compact();
                let view = scan_line(line.as_bytes()).map_err(|e| e.to_string())?;
                let tree = TransferLog::from_json(&Json::parse(&line).unwrap()).unwrap();
                assert_view_matches(&view, &tree)
            },
        );
    }

    #[test]
    fn scan_agrees_on_shuffled_keys_and_whitespace() {
        forall(
            Config { cases: 256, seed: 0x5CA_2 },
            |rng| {
                let log = random_log(rng);
                // Hand-build the line with randomized key order, random
                // whitespace, and an occasional unknown key with a
                // nested value — everything the tree parser tolerates.
                let tree = log.to_json();
                let mut keys: Vec<String> = match &tree {
                    Json::Obj(m) => m.keys().cloned().collect(),
                    _ => unreachable!(),
                };
                rng.shuffle(&mut keys);
                let ws = |rng: &mut Rng| {
                    [" ", "", "\t", "  "][rng.index(4)].to_string()
                };
                let mut line = String::from("{");
                for (i, k) in keys.iter().enumerate() {
                    if i > 0 {
                        line.push(',');
                    }
                    line.push_str(&ws(rng));
                    line.push_str(&format!("\"{k}\""));
                    line.push_str(&ws(rng));
                    line.push(':');
                    line.push_str(&ws(rng));
                    line.push_str(&tree.get(k).unwrap().to_string_compact());
                }
                if rng.chance(0.5) {
                    line.push_str(",\"extra\":{\"nested\":[1,\"two\",null,{}]}");
                }
                line.push_str(&ws(rng));
                line.push('}');
                (log, line)
            },
            |(log, line)| {
                let view = scan_line(line.as_bytes()).map_err(|e| e.to_string())?;
                let tree = TransferLog::from_json(&Json::parse(line).unwrap()).unwrap();
                if &tree != log {
                    return Err("tree parse disagrees with source log".to_string());
                }
                assert_view_matches(&view, log)
            },
        );
    }

    #[test]
    fn malformed_and_truncated_lines_error() {
        let good = sample_log().to_json().to_string_compact();
        // Truncations at every prefix length must error, never yield a row.
        for cut in 0..good.len() {
            let prefix = &good.as_bytes()[..cut];
            if prefix.iter().all(|b| matches!(b, b' ' | b'\t' | b'\r')) {
                continue; // Whitespace-only lines are skipped upstream.
            }
            assert!(
                scan_line(prefix).is_err(),
                "truncated line must error at cut={cut}"
            );
        }
        for bad in [
            "{",
            "[1,2]",
            "{\"id\":}",
            "{\"id\":1,}",
            "{\"id\":1} extra",
            "{\"id\":nope}",
            "{\"pair\":\"unterminated}",
            "{\"contend_mbps\":[1,2}",
        ] {
            assert!(scan_line(bad.as_bytes()).is_err(), "must reject {bad:?}");
            assert!(Json::parse(bad)
                .map(|v| TransferLog::from_json(&v))
                .is_err());
        }
    }

    #[test]
    fn wrong_typed_or_missing_fields_error_like_from_json() {
        for bad in [
            // Missing a required field.
            "{\"id\":1}",
            // pair not a string.
            "{\"avg_file_mb\":1,\"buf_mb\":1,\"bw_mbps\":1,\"cc\":1,\"contend_mbps\":[0,0,0,0,0],\"contend_streams\":0,\"disk_mbps\":1,\"dur_s\":1,\"id\":1,\"num_files\":1,\"p\":1,\"pair\":7,\"pp\":1,\"rtt_ms\":1,\"t\":1,\"th_mbps\":1}",
            // contend_mbps holds a non-number.
            "{\"avg_file_mb\":1,\"buf_mb\":1,\"bw_mbps\":1,\"cc\":1,\"contend_mbps\":[0,\"x\",0],\"contend_streams\":0,\"disk_mbps\":1,\"dur_s\":1,\"id\":1,\"num_files\":1,\"p\":1,\"pair\":\"a\",\"pp\":1,\"rtt_ms\":1,\"t\":1,\"th_mbps\":1}",
            // Numeric field is null (the writer's non-finite encoding).
            "{\"avg_file_mb\":1,\"buf_mb\":1,\"bw_mbps\":1,\"cc\":1,\"contend_mbps\":[0,0,0,0,0],\"contend_streams\":0,\"disk_mbps\":1,\"dur_s\":1,\"id\":null,\"num_files\":1,\"p\":1,\"pair\":\"a\",\"pp\":1,\"rtt_ms\":1,\"t\":1,\"th_mbps\":1}",
        ] {
            assert!(scan_line(bad.as_bytes()).is_err(), "must reject {bad:?}");
            let tree = Json::parse(bad).unwrap();
            assert!(TransferLog::from_json(&tree).is_err(), "tree path must also reject {bad:?}");
        }
    }

    #[test]
    fn duplicate_keys_last_wins_like_tree_parser() {
        let base = sample_log().to_json().to_string_compact();
        // Append a duplicate that overrides id — BTreeMap keeps the last.
        let line = format!("{},\"id\":777}}", &base[..base.len() - 1]);
        let view = scan_line(line.as_bytes()).unwrap();
        let tree = TransferLog::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(view.id, 777);
        assert_eq!(view.to_log(), tree);
    }

    #[test]
    fn pair_decoding_borrows_when_unescaped() {
        let mut log = sample_log();
        log.pair = "plain".into();
        let line = log.to_json().to_string_compact();
        let view = scan_line(line.as_bytes()).unwrap();
        assert!(matches!(view.pair(), Cow::Borrowed("plain")));
        log.pair = "needs\"escape".into();
        let line = log.to_json().to_string_compact();
        let view = scan_line(line.as_bytes()).unwrap();
        assert_eq!(view.pair(), "needs\"escape");
        assert!(matches!(view.pair(), Cow::Owned(_)));
    }

    #[test]
    fn lines_iterator_skips_blanks_and_counts_linenos() {
        let buf = b"a\n\n  \nb\nc";
        let got: Vec<(usize, &[u8])> = Lines::new(buf).collect();
        assert_eq!(
            got,
            vec![(1, b"a".as_slice()), (4, b"b".as_slice()), (5, b"c".as_slice())]
        );
    }
}
