//! Transfer-log layer: record schema, partitioned JSONL store, and the
//! synthetic production-log generator.

pub mod generate;
pub mod record;
pub mod store;

pub use generate::{generate, GenConfig, PARAM_KNOTS};
pub use record::TransferLog;
pub use store::LogStore;
