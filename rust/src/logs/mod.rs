//! Transfer-log layer: record schema, partitioned store (JSONL +
//! columnar `.dtc` behind one API), the zero-copy ingest scanner, and
//! the synthetic production-log generator.

pub mod columnar;
pub mod generate;
pub mod record;
pub mod scan;
pub mod store;

pub use generate::{generate, GenConfig, PARAM_KNOTS};
pub use record::{SuffRow, TransferLog};
pub use scan::LogRowView;
pub use store::{LogStore, StoreFormat};
