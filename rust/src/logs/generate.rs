//! Synthetic historical-log generator — the stand-in for the paper's
//! production Globus logs.
//!
//! Replays a months-long workload trace through the simulator: Poisson
//! transfer arrivals, a realistic mixture of user parameter policies
//! (defaults, habits, hand-tuning, exploration), diurnal external load
//! from the testbed profile, and sampled known-contending transfers.
//! The result has exactly the shape the offline pipeline expects from
//! production logs: a joint distribution over parameters × load ×
//! throughput with dense coverage of the parameter knots.

use super::record::TransferLog;
use crate::sim::dataset::{Dataset, SizeClass};
use crate::sim::params::{Params, PP_LEVELS};
use crate::sim::testbed::Testbed;
use crate::sim::traffic::{Contention, DAY_S, HOUR_S};
use crate::sim::transfer::NetState;
use crate::util::rng::Rng;

/// Parameter knots users historically picked for cc and p — this is the
/// grid the offline surfaces are built on, so the generator guarantees
/// the historical data covers it.
pub const PARAM_KNOTS: [u32; 8] = [1, 2, 3, 4, 6, 8, 12, 16];

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Number of simulated days of history.
    pub days: u64,
    /// Mean transfer arrivals per hour.
    pub arrivals_per_hour: f64,
    /// Starting day offset (so later partitions continue the timeline).
    pub start_day: u64,
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { days: 30, arrivals_per_hour: 40.0, start_day: 0, seed: 0xC0FFEE }
    }
}

/// Cache for the "hand-tuned user" policy: the quiet-network optimum
/// only depends on the dataset through (class, log₂ file-size bucket),
/// so the 16×16×6 grid search runs once per bucket instead of once per
/// hand-tuned row (§Perf: ~8× faster history generation).
type OptCache = std::collections::HashMap<(u32, i32), Params>;

fn quiet_optimal_cached(
    cache: &mut OptCache,
    testbed: &Testbed,
    class: SizeClass,
    dataset: &Dataset,
) -> Params {
    let bucket = (class as u32, dataset.avg_file_mb.log2().floor() as i32);
    if let Some(p) = cache.get(&bucket) {
        return *p;
    }
    let (opt, _) = testbed.path.optimal(dataset, &NetState::quiet(), 16);
    cache.insert(bucket, opt);
    opt
}

/// How a simulated "user" picks parameters — the policy mixture that
/// gives production logs their spread.
fn pick_params(
    rng: &mut Rng,
    class: SizeClass,
    testbed: &Testbed,
    dataset: &Dataset,
    cache: &mut OptCache,
) -> Params {
    let style = rng.f64();
    if style < 0.22 {
        // Globus-online-like static defaults per class.
        match class {
            SizeClass::Small => Params::new(2, 2, 8),
            SizeClass::Medium => Params::new(4, 4, 4),
            SizeClass::Large => Params::new(2, 8, 1),
        }
    } else if style < 0.50 {
        // Uniform exploration over the knot grid (power users trying
        // things, scripted sweeps, etc.).
        Params::new(
            PARAM_KNOTS[rng.index(PARAM_KNOTS.len())],
            PARAM_KNOTS[rng.index(PARAM_KNOTS.len())],
            PP_LEVELS[rng.index(PP_LEVELS.len())],
        )
    } else if style < 0.78 {
        // Hand-tuned users: near the quiet-network optimum with jitter.
        let opt = quiet_optimal_cached(cache, testbed, class, dataset);
        fn jig(rng: &mut Rng, v: u32) -> u32 {
            let knot_idx = PARAM_KNOTS.iter().position(|&k| k >= v).unwrap_or(7);
            let j = (knot_idx as i64 + rng.range_u(0, 2) as i64 - 1).clamp(0, 7) as usize;
            PARAM_KNOTS[j]
        }
        let pp_idx = PP_LEVELS.iter().position(|&k| k >= opt.pp).unwrap_or(5);
        let pj = (pp_idx as i64 + rng.range_u(0, 2) as i64 - 1).clamp(0, 5) as usize;
        let cc = jig(rng, opt.cc);
        let p = jig(rng, opt.p);
        Params::new(cc, p, PP_LEVELS[pj])
    } else {
        // Habitual favorites (the long tail of cargo-cult settings).
        let favorites = [
            Params::new(1, 1, 1),
            Params::new(4, 1, 1),
            Params::new(8, 2, 2),
            Params::new(16, 1, 4),
            Params::new(1, 16, 1),
            Params::new(6, 6, 16),
        ];
        favorites[rng.index(favorites.len())]
    }
}

/// Generate the history for one testbed.
pub fn generate(testbed: &Testbed, config: &GenConfig) -> Vec<TransferLog> {
    let mut rng = Rng::new(config.seed ^ testbed.id.name().len() as u64);
    let mut rows = Vec::new();
    let mut id: u64 = config.start_day * 1_000_000;
    let t_begin = config.start_day as f64 * DAY_S;
    let t_end = (config.start_day + config.days) as f64 * DAY_S;
    let mut opt_cache = OptCache::new();
    let mut t = t_begin + rng.exponential(config.arrivals_per_hour / HOUR_S);
    while t < t_end {
        id += 1;
        let class = match rng.f64() {
            x if x < 0.35 => SizeClass::Small,
            x if x < 0.70 => SizeClass::Medium,
            _ => SizeClass::Large,
        };
        let dataset = Dataset::sample(class, &mut rng);
        let params = pick_params(&mut rng, class, testbed, &dataset, &mut opt_cache);
        let external_load = testbed.profile.sample_load(t, &mut rng);
        let contention =
            Contention::sample(&mut rng, testbed.path.link.bandwidth_mbps, external_load);
        let state = NetState { external_load, contention };
        let outcome = testbed.path.transfer(&dataset, &params, &state, Some(&mut rng));
        rows.push(TransferLog {
            id,
            t_start: t,
            pair: testbed.id.name().to_string(),
            rtt_ms: testbed.path.link.rtt_ms,
            bandwidth_mbps: testbed.path.link.bandwidth_mbps,
            tcp_buffer_mb: testbed.path.src.tcp_buffer_mb.min(testbed.path.dst.tcp_buffer_mb),
            disk_mbps: testbed.path.src.disk_mbps.min(testbed.path.dst.disk_mbps),
            avg_file_mb: dataset.avg_file_mb,
            num_files: dataset.num_files,
            cc: params.cc,
            p: params.p,
            pp: params.pp,
            throughput_mbps: outcome.throughput_mbps,
            duration_s: outcome.duration_s,
            contending_mbps: contention.rate_mbps,
            contending_streams: contention.streams,
        });
        t += rng.exponential(config.arrivals_per_hour / HOUR_S);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::testbed::TestbedId;

    fn quick_config() -> GenConfig {
        GenConfig { days: 3, arrivals_per_hour: 30.0, start_day: 0, seed: 7 }
    }

    #[test]
    fn generates_plausible_volume() {
        let rows = generate(&Testbed::xsede(), &quick_config());
        // 3 days × 24 h × 30/h = 2160 expected.
        assert!(rows.len() > 1_500 && rows.len() < 3_000, "n={}", rows.len());
    }

    #[test]
    fn rows_are_time_ordered_and_within_range() {
        let rows = generate(&Testbed::didclab(), &quick_config());
        for w in rows.windows(2) {
            assert!(w[1].t_start >= w[0].t_start);
        }
        assert!(rows.iter().all(|r| r.t_start < 3.0 * DAY_S));
        assert!(rows.iter().all(|r| r.throughput_mbps > 0.0 && r.throughput_mbps.is_finite()));
    }

    #[test]
    fn covers_parameter_knots() {
        let rows = generate(&Testbed::xsede(), &GenConfig { days: 10, ..quick_config() });
        for &k in &PARAM_KNOTS {
            assert!(rows.iter().any(|r| r.cc == k), "no coverage of cc={k}");
            assert!(rows.iter().any(|r| r.p == k), "no coverage of p={k}");
        }
        for &pp in &PP_LEVELS {
            assert!(rows.iter().any(|r| r.pp == pp), "no coverage of pp={pp}");
        }
    }

    #[test]
    fn covers_all_size_classes() {
        let rows = generate(&Testbed::xsede(), &quick_config());
        for class in SizeClass::all() {
            let n = rows.iter().filter(|r| SizeClass::classify(r.avg_file_mb) == class).count();
            assert!(n > rows.len() / 10, "class {class:?} underrepresented: {n}");
        }
    }

    #[test]
    fn peak_hours_show_lower_throughput() {
        let tb = Testbed::didclab();
        let rows = generate(&tb, &GenConfig { days: 10, ..quick_config() });
        // Compare identical static params (the GO defaults for medium).
        let med: Vec<&TransferLog> = rows
            .iter()
            .filter(|r| r.cc == 4 && r.p == 4 && r.pp == 4 && SizeClass::classify(r.avg_file_mb) == SizeClass::Medium)
            .collect();
        let (mut peak, mut off) = (Vec::new(), Vec::new());
        for r in med {
            match tb.profile.period(r.t_start) {
                crate::sim::traffic::Period::Peak => peak.push(r.throughput_mbps),
                crate::sim::traffic::Period::OffPeak => off.push(r.throughput_mbps),
            }
        }
        if peak.len() > 5 && off.len() > 5 {
            let pm = crate::util::stats::mean(&peak);
            let om = crate::util::stats::mean(&off);
            assert!(pm < om, "peak {pm:.0} should be below off-peak {om:.0}");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate(&Testbed::xsede(), &quick_config());
        let b = generate(&Testbed::xsede(), &quick_config());
        assert_eq!(a.len(), b.len());
        assert_eq!(a[10], b[10]);
    }

    #[test]
    fn start_day_offsets_timeline() {
        let cfg = GenConfig { start_day: 5, days: 1, ..quick_config() };
        let rows = generate(&Testbed::by_id(TestbedId::Xsede), &cfg);
        assert!(rows.iter().all(|r| r.t_start >= 5.0 * DAY_S && r.t_start < 6.0 * DAY_S));
    }
}
