//! The shared-link contention plane — concurrent transfers actually
//! contend, end to end.
//!
//! The paper's online model reasons explicitly about contending
//! transfers on a shared link, yet a coordinator that hands every
//! request a private copy of the testbed scores decisions against a
//! fiction: self-traffic is invisible, so under heavy multi-user load
//! each transfer believes it owns the bottleneck. HARP's historical
//! tuning (Arslan & Kosar) and the two-phase dynamic model (Nine &
//! Kosar) both treat concurrent-transfer interference as the
//! first-order effect; this module makes it physical:
//!
//! ```text
//!                    ┌────────────────────────────────────────────┐
//!  serve_one ───────▶│ LinkPlane (per network → LinkState)        │
//!   admit(id)        │   registry: id → (procs×streams, offered)  │
//!     │              │   ambient convoy (scenario `contention`)   │
//!     ▼              │   epoch: bumps on join / leave / ambient   │
//!  LinkLease ───────▶│ neighbors(id): everyone else's offered     │
//!   per chunk:       │   rate + streams, capped at the scaled     │
//!   view → merge     │   (fault-shaped) link capacity             │
//!   into NetState ──▶│ stream_allowance: fair-share cap on        │
//!   update(θ, rate)  │   cc×p while ≥ 2 transfers share the link  │
//!   release ────────▶│ exposure: what this transfer experienced   │
//!                    └────────────────────────────────────────────┘
//! ```
//!
//! * [`plane`] — the [`LinkPlane`] registry itself, the [`LinkLease`]
//!   a transfer holds while it occupies the link, and the
//!   [`ContentionExposure`] summary attributed on every response.
//!   [`LinkPlane::isolated`] keeps the old private-testbed behaviour
//!   selectable so pre-plane bake-offs stay comparable.
//! * [`cohort`] — a deterministic fixed-point solver scoring a whole
//!   cohort of parameter decisions under mutual contention: the
//!   ground-truth evaluator `experiments::convoy` uses to compare
//!   plane-aware decisions against fiction-scored ones.
//!
//! `sim/transfer.rs` composes the three contention sources in one
//! place: live occupancy from this plane, the sampled external
//! [`Contention`](crate::sim::traffic::Contention), and
//! [`FaultBoard`](crate::sim::fault::FaultBoard) capacity scaling —
//! `TransferEnv::run_chunk` re-reads the plane on every chunk, so a
//! transfer's achieved goodput degrades the moment neighbors pile on
//! (and recovers when they drain). The probe plane records the
//! occupancy observed at admission next to each estimate, so knowledge
//! learned under heavy self-traffic is never reused as quiet-network
//! truth (see `probe::estimate::ProbeOcc`).

pub mod cohort;
pub mod plane;

pub use cohort::{aggregate_mbps, fairness_spread, solve_cohort, CohortMember};
pub use plane::{
    ContentionExposure, LinkLease, LinkPlane, LinkPlaneConfig, NeighborView, Occupancy,
    PlaneMode,
};
