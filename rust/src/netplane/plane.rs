//! The [`LinkPlane`]: per-network live-occupancy registry.
//!
//! One [`LinkState`] per network tracks every transfer currently on the
//! wire — its parameter load (procs × streams) and the steady rate it
//! last offered — plus an *ambient* convoy (a scripted fleet of
//! contending transfers the scenario engine injects through the
//! `contention` fault). An epoch counter bumps on every join, leave,
//! and ambient change, so consumers can tell "the link's population
//! changed since I last looked" apart from "same neighbors, new
//! numbers".
//!
//! ## Lock sharding
//!
//! The registry used to be one `Mutex<BTreeMap<TestbedId, LinkState>>`:
//! every join, leave, per-chunk view, and load update on *any* network
//! contended on the same mutex — the hottest lock on the serve path
//! once the stampede plane runs genuinely concurrent workers. The
//! network population is a closed enum ([`TestbedId::all`]), so the
//! plane now holds one `Mutex<LinkState>` per network in a fixed
//! array: transfers on different networks never touch each other's
//! lock, and no code path ever holds two of them at once.
//!
//! Invariants the scenario conformance suite asserts end-to-end:
//! occupancy is never negative and always returns to zero at drain
//! (leases release on drop, so a panicking worker cannot leak
//! registration), and the carried load reported for a network never
//! exceeds its fault-scaled link capacity — the plane saturates the
//! snapshot at capacity, because a link cannot carry more than it has.

use crate::sim::fault::FaultBoard;
use crate::sim::params::Params;
use crate::sim::testbed::{Testbed, TestbedId};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Shared vs isolated serving (see [`LinkPlane::isolated`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlaneMode {
    /// Transfers on the same network see each other: neighbor views are
    /// real and the fair-share stream allowance applies.
    Shared,
    /// The pre-plane fiction, kept selectable so existing bake-offs
    /// stay comparable: registration is tracked (bookkeeping and
    /// metrics still work) but neighbor views are empty and no
    /// allowance is imposed.
    Isolated,
}

/// Plane tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct LinkPlaneConfig {
    /// Total cc×p streams the plane is willing to see on one network
    /// before fair-sharing kicks in: while `n ≥ 2` transfers share the
    /// link each one's decision is capped at `stream_budget / n`.
    pub stream_budget: u32,
    /// Floor of the per-transfer allowance — even a crowded link grants
    /// at least this many streams.
    pub min_streams: u32,
}

impl Default for LinkPlaneConfig {
    fn default() -> Self {
        LinkPlaneConfig { stream_budget: 64, min_streams: 2 }
    }
}

/// One registered transfer's current load on the link.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
struct TransferLoad {
    procs: u32,
    streams: u32,
    offered_mbps: f64,
}

/// Per-network shared state.
#[derive(Debug, Default)]
struct LinkState {
    active: BTreeMap<u64, TransferLoad>,
    ambient_mbps: f64,
    ambient_streams: u32,
    /// Bumps on join / leave / ambient change. Zero means the network
    /// has never been touched (the render filter below).
    epoch: u64,
    peak_concurrent: usize,
    joins: u64,
    leaves: u64,
}

/// A bookkeeping snapshot of one network's occupancy: the registered
/// transfers (ambient reported separately) as the invariant checkers
/// see them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Registered transfers currently on the link.
    pub transfers: usize,
    /// Their cc×p streams, summed.
    pub streams: u32,
    /// Their offered rates, summed (Mbps).
    pub offered_mbps: f64,
    /// The scripted ambient convoy, if any.
    pub ambient_mbps: f64,
    pub ambient_streams: u32,
    pub epoch: u64,
}

/// What one transfer sees of everyone else: its neighbors' load plus
/// the ambient convoy, ready to merge into a [`NetState`]'s contention.
///
/// [`NetState`]: crate::sim::transfer::NetState
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NeighborView {
    /// Neighbor transfers (self excluded; ambient not counted here).
    pub transfers: usize,
    /// Neighbor + ambient streams.
    pub streams: u32,
    /// Neighbor + ambient offered rate (Mbps), capped at the scaled
    /// link capacity — a link cannot present more pressure than it
    /// carries.
    pub offered_mbps: f64,
    pub epoch: u64,
}

/// Per-request contention attribution: what the transfer experienced
/// on the shared link, chunk by chunk. Rendered into
/// `TransferResponse::contention` and the scenario timeline.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ContentionExposure {
    /// Distinct occupancy epochs observed across the transfer's chunks
    /// (1 = the link's population never changed underneath it).
    pub epochs_observed: u64,
    /// Peak concurrent neighbor transfers seen by any chunk.
    pub peak_neighbors: usize,
    /// Peak neighbor + ambient offered rate seen by any chunk (Mbps).
    pub peak_neighbor_mbps: f64,
    /// Time-weighted mean neighbor + ambient offered rate (Mbps).
    pub mean_neighbor_mbps: f64,
    /// Peak total carried load on the link (self + neighbors + ambient,
    /// saturated at the fault-scaled capacity) — the quantity the
    /// `offered-within-capacity` invariant checks.
    pub peak_carried_mbps: f64,
    /// Seconds spent with at least one neighbor or ambient load present.
    pub contended_s: f64,
    /// Total transfer seconds observed through the lease.
    pub total_s: f64,
}

/// The shared-link contention plane.
#[derive(Debug)]
pub struct LinkPlane {
    mode: PlaneMode,
    config: LinkPlaneConfig,
    /// Fault board supplying the capacity scale factor (the same board
    /// the coordinator shapes testbeds with, so a brownout narrows the
    /// plane's idea of the pipe too). `None` = nominal capacity.
    faults: Option<Arc<FaultBoard>>,
    /// One lock per network, indexed by [`LinkPlane::slot`] — the
    /// network population is closed, so sharding is a fixed array.
    nets: [Mutex<LinkState>; 3],
}

impl LinkPlane {
    /// A shared plane with default knobs: concurrent transfers see each
    /// other and fair-share the stream budget.
    pub fn shared() -> LinkPlane {
        LinkPlane::with_config(PlaneMode::Shared, LinkPlaneConfig::default(), None)
    }

    /// The pre-plane behaviour: every transfer believes it owns the
    /// link. Registration is still tracked for bookkeeping, so
    /// bake-offs can attribute both sides identically.
    pub fn isolated() -> LinkPlane {
        LinkPlane::with_config(PlaneMode::Isolated, LinkPlaneConfig::default(), None)
    }

    pub fn with_config(
        mode: PlaneMode,
        config: LinkPlaneConfig,
        faults: Option<Arc<FaultBoard>>,
    ) -> LinkPlane {
        LinkPlane { mode, config, faults, nets: Default::default() }
    }

    pub fn mode(&self) -> PlaneMode {
        self.mode
    }

    pub fn config(&self) -> &LinkPlaneConfig {
        &self.config
    }

    /// The network's state shard. Each call locks exactly one network;
    /// no plane method ever holds two shards at once.
    fn slot(&self, network: TestbedId) -> &Mutex<LinkState> {
        let idx = match network {
            TestbedId::Xsede => 0,
            TestbedId::Didclab => 1,
            TestbedId::DidclabToXsede => 2,
        };
        &self.nets[idx]
    }

    /// The network's current fault capacity factor (1.0 = healthy).
    /// Touches only the fault board, never any network shard.
    fn capacity_factor(&self, network: TestbedId) -> f64 {
        self.faults
            .as_ref()
            .and_then(|board| board.effect(network))
            .map(|fault| fault.capacity_factor)
            .unwrap_or(1.0)
    }

    /// The network's current link capacity (Mbps), fault scaling
    /// applied — the ceiling the carried-load snapshot saturates at.
    pub fn scaled_capacity_mbps(&self, network: TestbedId) -> f64 {
        Testbed::by_id(network).path.link.bandwidth_mbps * self.capacity_factor(network)
    }

    /// Register a transfer on the network's link (zero load until its
    /// first chunk reports in). The returned lease releases the
    /// registration on drop, so occupancy always drains. Takes an
    /// owned `Arc` (callers clone their handle): `&Arc<Self>` is not a
    /// legal receiver on stable rust and the lease needs to own the
    /// plane for its `Drop` release.
    pub fn admit(self: Arc<Self>, network: TestbedId, id: u64) -> LinkLease {
        {
            let mut state = self.slot(network).lock().expect("link plane poisoned");
            state.active.insert(id, TransferLoad::default());
            state.epoch += 1;
            state.joins += 1;
            state.peak_concurrent = state.peak_concurrent.max(state.active.len());
        }
        let nominal_mbps = Testbed::by_id(network).path.link.bandwidth_mbps;
        LinkLease {
            plane: self,
            network,
            id,
            nominal_mbps,
            released: false,
            acc: ExposureAcc::default(),
        }
    }

    fn release(&self, network: TestbedId, id: u64) {
        let mut state = self.slot(network).lock().expect("link plane poisoned");
        if state.active.remove(&id).is_some() {
            state.epoch += 1;
            state.leaves += 1;
        }
    }

    fn update(&self, network: TestbedId, id: u64, procs: u32, streams: u32, offered_mbps: f64) {
        let offered = if offered_mbps.is_finite() { offered_mbps.max(0.0) } else { 0.0 };
        let mut state = self.slot(network).lock().expect("link plane poisoned");
        if let Some(load) = state.active.get_mut(&id) {
            *load = TransferLoad { procs, streams, offered_mbps: offered };
        }
    }

    /// Inject (or replace) the ambient convoy on a network — the
    /// scenario engine's `contention` fault hook.
    pub fn set_ambient(&self, network: TestbedId, offered_mbps: f64, streams: u32) {
        let offered = if offered_mbps.is_finite() { offered_mbps.max(0.0) } else { 0.0 };
        let mut state = self.slot(network).lock().expect("link plane poisoned");
        state.ambient_mbps = offered;
        state.ambient_streams = streams;
        state.epoch += 1;
    }

    /// Clear the network's ambient convoy (`clear-contention`).
    pub fn clear_ambient(&self, network: TestbedId) {
        self.set_ambient(network, 0.0, 0);
    }

    /// Bookkeeping snapshot of the network's registered occupancy.
    /// Truthful in both modes — isolation hides neighbors from
    /// *transfers*, not from the operator.
    pub fn occupancy(&self, network: TestbedId) -> Occupancy {
        let state = self.slot(network).lock().expect("link plane poisoned");
        Occupancy {
            transfers: state.active.len(),
            streams: state.active.values().map(|l| l.streams).sum(),
            offered_mbps: state.active.values().map(|l| l.offered_mbps).sum(),
            ambient_mbps: state.ambient_mbps,
            ambient_streams: state.ambient_streams,
            epoch: state.epoch,
        }
    }

    /// Registered transfers across every network (0 = fully drained).
    pub fn active_total(&self) -> usize {
        TestbedId::all()
            .iter()
            .map(|id| self.slot(*id).lock().expect("link plane poisoned").active.len())
            .sum()
    }

    /// What a transfer (or a request about to be admitted — pass
    /// `exclude = None`) sees of everyone else on the network. Empty in
    /// isolated mode: the fiction, by request.
    pub fn neighbor_view(&self, network: TestbedId, exclude: Option<u64>) -> NeighborView {
        if self.mode == PlaneMode::Isolated {
            return NeighborView::default();
        }
        let cap = self.scaled_capacity_mbps(network);
        let state = self.slot(network).lock().expect("link plane poisoned");
        let mut transfers = 0usize;
        let mut streams = state.ambient_streams;
        let mut offered = state.ambient_mbps;
        for (id, load) in &state.active {
            if Some(*id) == exclude {
                continue;
            }
            transfers += 1;
            streams = streams.saturating_add(load.streams);
            offered += load.offered_mbps;
        }
        NeighborView { transfers, streams, offered_mbps: offered.min(cap), epoch: state.epoch }
    }

    /// Total carried load on the network — registered + ambient,
    /// saturated at the scaled capacity. This is the quantity the
    /// `offered-within-capacity` invariant bounds.
    pub fn carried_mbps(&self, network: TestbedId) -> f64 {
        let cap = self.scaled_capacity_mbps(network);
        let occ = self.occupancy(network);
        (occ.offered_mbps + occ.ambient_mbps).min(cap)
    }

    /// Fair-share stream allowance for one transfer on the network:
    /// `stream_budget / active` while at least two transfers share the
    /// link; `None` (uncapped) for a solo transfer or in isolated mode.
    pub fn stream_allowance(&self, network: TestbedId) -> Option<u32> {
        if self.mode == PlaneMode::Isolated {
            return None;
        }
        let active = self.slot(network).lock().expect("link plane poisoned").active.len();
        if active < 2 {
            return None;
        }
        Some((self.config.stream_budget / active as u32).max(self.config.min_streams))
    }

    /// The contention metrics block (rendered by `coordinator::Metrics`
    /// when a plane is attached).
    pub fn render(&self) -> String {
        let mode = match self.mode {
            PlaneMode::Shared => "shared",
            PlaneMode::Isolated => "isolated",
        };
        // Snapshot each shard in the fixed network order, one lock at a
        // time (never two at once). Untouched networks (epoch 0) are
        // skipped, matching the old lazily-populated map's render.
        struct NetSnap {
            id: TestbedId,
            active: usize,
            streams: u32,
            offered: f64,
            ambient_mbps: f64,
            ambient_streams: u32,
            epoch: u64,
            peak: usize,
            joins: u64,
            leaves: u64,
        }
        let snaps: Vec<NetSnap> = TestbedId::all()
            .iter()
            .filter_map(|id| {
                let state = self.slot(*id).lock().expect("link plane poisoned");
                if state.epoch == 0 {
                    return None;
                }
                Some(NetSnap {
                    id: *id,
                    active: state.active.len(),
                    streams: state.active.values().map(|l| l.streams).sum(),
                    offered: state.active.values().map(|l| l.offered_mbps).sum(),
                    ambient_mbps: state.ambient_mbps,
                    ambient_streams: state.ambient_streams,
                    epoch: state.epoch,
                    peak: state.peak_concurrent,
                    joins: state.joins,
                    leaves: state.leaves,
                })
            })
            .collect();
        let active: usize = snaps.iter().map(|s| s.active).sum();
        let peak: usize = snaps.iter().map(|s| s.peak).max().unwrap_or(0);
        let joins: u64 = snaps.iter().map(|s| s.joins).sum();
        let leaves: u64 = snaps.iter().map(|s| s.leaves).sum();
        let mut out = format!(
            "link plane: {mode} mode, {active} active transfer(s), peak {peak} concurrent, \
             {joins} joins, {leaves} leaves\n"
        );
        for snap in &snaps {
            let cap = self.scaled_capacity_mbps(snap.id);
            let carried = (snap.offered + snap.ambient_mbps).min(cap);
            out.push_str(&format!(
                "  {}: {} active / {} streams, offered {:.0} Mbps, ambient {:.0} Mbps \
                 ({} streams), carried {:.0}/{:.0} Mbps, epoch {}\n",
                snap.id.name(),
                snap.active,
                snap.streams,
                snap.offered,
                snap.ambient_mbps,
                snap.ambient_streams,
                carried,
                cap,
                snap.epoch,
            ));
        }
        out
    }
}

/// Exposure accumulator (single-threaded: lives inside one lease).
#[derive(Debug, Clone, Copy, Default)]
struct ExposureAcc {
    last_epoch: Option<u64>,
    epochs_observed: u64,
    peak_neighbors: usize,
    peak_neighbor_mbps: f64,
    weighted_neighbor_mbps_s: f64,
    peak_carried_mbps: f64,
    contended_s: f64,
    total_s: f64,
}

/// A transfer's registration on the shared link. Obtained from
/// [`LinkPlane::admit`]; held by the [`TransferEnv`] for the run;
/// releases the registration (and yields the exposure summary) on
/// [`LinkLease::release`] — or on drop, so a panicking worker cannot
/// leak occupancy.
///
/// [`TransferEnv`]: crate::baselines::TransferEnv
#[derive(Debug)]
pub struct LinkLease {
    plane: Arc<LinkPlane>,
    network: TestbedId,
    id: u64,
    /// Nominal link capacity, cached at admission so the per-chunk
    /// exposure path never rebuilds a `Testbed`.
    nominal_mbps: f64,
    released: bool,
    acc: ExposureAcc,
}

impl LinkLease {
    pub fn network(&self) -> TestbedId {
        self.network
    }

    /// What everyone else on the link currently offers (empty in
    /// isolated mode).
    pub fn view(&self) -> NeighborView {
        self.plane.neighbor_view(self.network, Some(self.id))
    }

    /// The fair-share cap on this transfer's cc×p decision right now
    /// (`None` = uncapped).
    pub fn stream_allowance(&self) -> Option<u32> {
        self.plane.stream_allowance(self.network)
    }

    /// Clamp a parameter choice to the current stream allowance:
    /// parallelism sheds first (streams are the contended resource),
    /// then concurrency; pipelining is per-channel and stays.
    pub fn clamp_params(&self, params: Params) -> Params {
        let Some(allowance) = self.stream_allowance() else {
            return params;
        };
        let mut capped = params;
        while capped.streams() > allowance {
            if capped.p > 1 {
                capped.p -= 1;
            } else if capped.cc > 1 {
                capped.cc -= 1;
            } else {
                break;
            }
        }
        capped
    }

    /// Report this transfer's current load so neighbors see it.
    pub fn update(&self, procs: u32, streams: u32, offered_mbps: f64) {
        self.plane.update(self.network, self.id, procs, streams, offered_mbps);
    }

    /// Fold one executed chunk into the exposure summary. `view` is the
    /// neighbor view the chunk ran under and `own_mbps` the steady rate
    /// this transfer just offered — the carried load is derived from
    /// the two (view is already capacity-capped), so the per-chunk hot
    /// path takes no extra pass through the plane's registry lock.
    pub fn observe(&mut self, view: &NeighborView, chunk_s: f64, own_mbps: f64) {
        let chunk_s = if chunk_s.is_finite() { chunk_s.max(0.0) } else { 0.0 };
        if self.acc.last_epoch != Some(view.epoch) {
            self.acc.last_epoch = Some(view.epoch);
            self.acc.epochs_observed += 1;
        }
        self.acc.peak_neighbors = self.acc.peak_neighbors.max(view.transfers);
        self.acc.peak_neighbor_mbps = self.acc.peak_neighbor_mbps.max(view.offered_mbps);
        self.acc.weighted_neighbor_mbps_s += view.offered_mbps * chunk_s;
        let own = if own_mbps.is_finite() { own_mbps.max(0.0) } else { 0.0 };
        let cap = self.nominal_mbps * self.plane.capacity_factor(self.network);
        let carried = (view.offered_mbps + own).min(cap);
        self.acc.peak_carried_mbps = self.acc.peak_carried_mbps.max(carried);
        if view.transfers > 0 || view.offered_mbps > 0.0 {
            self.acc.contended_s += chunk_s;
        }
        self.acc.total_s += chunk_s;
    }

    /// Release the registration and summarize the exposure.
    pub fn release(mut self) -> ContentionExposure {
        self.plane.release(self.network, self.id);
        self.released = true;
        let acc = self.acc;
        ContentionExposure {
            epochs_observed: acc.epochs_observed,
            peak_neighbors: acc.peak_neighbors,
            peak_neighbor_mbps: acc.peak_neighbor_mbps,
            mean_neighbor_mbps: if acc.total_s > 0.0 {
                acc.weighted_neighbor_mbps_s / acc.total_s
            } else {
                0.0
            },
            peak_carried_mbps: acc.peak_carried_mbps,
            contended_s: acc.contended_s,
            total_s: acc.total_s,
        }
    }
}

impl Drop for LinkLease {
    fn drop(&mut self) {
        if !self.released {
            self.plane.release(self.network, self.id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_see_each_other_and_drain_restores_zero() {
        let plane = Arc::new(LinkPlane::shared());
        let a = plane.clone().admit(TestbedId::Xsede, 1);
        let b = plane.clone().admit(TestbedId::Xsede, 2);
        a.update(8, 32, 4_000.0);
        b.update(4, 8, 1_000.0);
        // A sees B, B sees A — never themselves.
        assert_eq!(a.view().offered_mbps, 1_000.0);
        assert_eq!(a.view().streams, 8);
        assert_eq!(b.view().offered_mbps, 4_000.0);
        assert_eq!(b.view().transfers, 1);
        // Another network is untouched.
        assert_eq!(plane.occupancy(TestbedId::Didclab).transfers, 0);
        let occ = plane.occupancy(TestbedId::Xsede);
        assert_eq!(occ.transfers, 2);
        assert_eq!(occ.streams, 40);
        assert!((occ.offered_mbps - 5_000.0).abs() < 1e-9);
        drop(a);
        drop(b);
        let drained = plane.occupancy(TestbedId::Xsede);
        assert_eq!(drained.transfers, 0);
        assert_eq!(drained.offered_mbps, 0.0);
        assert_eq!(plane.active_total(), 0);
    }

    #[test]
    fn epochs_bump_on_join_leave_and_ambient() {
        let plane = Arc::new(LinkPlane::shared());
        let e0 = plane.occupancy(TestbedId::Xsede).epoch;
        let lease = plane.clone().admit(TestbedId::Xsede, 1);
        let e1 = plane.occupancy(TestbedId::Xsede).epoch;
        assert!(e1 > e0);
        lease.update(4, 8, 500.0); // load updates do NOT bump the epoch
        assert_eq!(plane.occupancy(TestbedId::Xsede).epoch, e1);
        plane.set_ambient(TestbedId::Xsede, 2_000.0, 16);
        let e2 = plane.occupancy(TestbedId::Xsede).epoch;
        assert!(e2 > e1);
        drop(lease);
        assert!(plane.occupancy(TestbedId::Xsede).epoch > e2);
    }

    #[test]
    fn isolated_mode_hides_neighbors_but_keeps_books() {
        let plane = Arc::new(LinkPlane::isolated());
        let a = plane.clone().admit(TestbedId::Xsede, 1);
        let b = plane.clone().admit(TestbedId::Xsede, 2);
        b.update(8, 32, 4_000.0);
        // The fiction: a sees nothing...
        assert_eq!(a.view(), NeighborView::default());
        assert_eq!(a.stream_allowance(), None);
        // ...but the operator's books are truthful.
        assert_eq!(plane.occupancy(TestbedId::Xsede).transfers, 2);
        drop(a);
        drop(b);
    }

    #[test]
    fn ambient_convoy_counts_as_neighbor_pressure() {
        let plane = Arc::new(LinkPlane::shared());
        plane.set_ambient(TestbedId::Xsede, 6_000.0, 48);
        let lease = plane.clone().admit(TestbedId::Xsede, 1);
        let view = lease.view();
        assert_eq!(view.transfers, 0, "ambient is not a registered transfer");
        assert_eq!(view.streams, 48);
        assert!((view.offered_mbps - 6_000.0).abs() < 1e-9);
        plane.clear_ambient(TestbedId::Xsede);
        assert_eq!(lease.view().offered_mbps, 0.0);
    }

    #[test]
    fn neighbor_pressure_and_carried_load_saturate_at_scaled_capacity() {
        use crate::sim::fault::FaultBoard;

        let board = Arc::new(FaultBoard::new());
        let plane = Arc::new(LinkPlane::with_config(
            PlaneMode::Shared,
            LinkPlaneConfig::default(),
            Some(board.clone()),
        ));
        plane.set_ambient(TestbedId::Xsede, 50_000.0, 100);
        let lease = plane.clone().admit(TestbedId::Xsede, 1);
        lease.update(8, 32, 9_000.0);
        // Nominal capacity caps the view and the carried load.
        assert!((plane.carried_mbps(TestbedId::Xsede) - 10_000.0).abs() < 1e-9);
        assert!((lease.view().offered_mbps - 10_000.0).abs() < 1e-9);
        // A brownout narrows the plane's pipe too.
        board.degrade_link(TestbedId::Xsede, 0.4);
        assert!((plane.scaled_capacity_mbps(TestbedId::Xsede) - 4_000.0).abs() < 1e-9);
        assert!((plane.carried_mbps(TestbedId::Xsede) - 4_000.0).abs() < 1e-9);
        drop(lease);
    }

    #[test]
    fn stream_allowance_fair_shares_only_under_contention() {
        let plane = Arc::new(LinkPlane::with_config(
            PlaneMode::Shared,
            LinkPlaneConfig { stream_budget: 24, min_streams: 2 },
            None,
        ));
        let a = plane.clone().admit(TestbedId::Xsede, 1);
        // Solo: the transfer owns the link, no cap.
        assert_eq!(a.stream_allowance(), None);
        let b = plane.clone().admit(TestbedId::Xsede, 2);
        assert_eq!(a.stream_allowance(), Some(12));
        let c = plane.clone().admit(TestbedId::Xsede, 3);
        assert_eq!(a.stream_allowance(), Some(8));
        // The clamp sheds parallelism first, then concurrency, and
        // never touches pipelining.
        let clamped = a.clamp_params(Params::new(8, 4, 16));
        assert!(clamped.streams() <= 8, "clamped to {clamped}");
        assert_eq!(clamped.pp, 16);
        assert_eq!(a.clamp_params(Params::new(2, 2, 4)), Params::new(2, 2, 4));
        // The floor holds on a very crowded link.
        let extras: Vec<LinkLease> =
            (4..=30).map(|i| plane.clone().admit(TestbedId::Xsede, i)).collect();
        assert_eq!(a.stream_allowance(), Some(2));
        assert_eq!(a.clamp_params(Params::new(8, 4, 16)).streams(), 2);
        drop(extras);
        drop(c);
        drop(b);
        assert_eq!(a.stream_allowance(), None, "drain lifts the cap");
        drop(a);
    }

    #[test]
    fn exposure_summarizes_what_the_transfer_experienced() {
        let plane = Arc::new(LinkPlane::shared());
        let mut a = plane.clone().admit(TestbedId::Xsede, 1);
        a.update(4, 8, 1_000.0);
        // Quiet chunk.
        let quiet = a.view();
        a.observe(&quiet, 5.0, 1_000.0);
        // A neighbor joins: epoch changes, contended chunk.
        let b = plane.clone().admit(TestbedId::Xsede, 2);
        b.update(8, 32, 3_000.0);
        let busy = a.view();
        assert_eq!(busy.transfers, 1);
        a.observe(&busy, 5.0, 800.0);
        drop(b);
        let exposure = a.release();
        assert_eq!(exposure.epochs_observed, 2);
        assert_eq!(exposure.peak_neighbors, 1);
        assert!((exposure.peak_neighbor_mbps - 3_000.0).abs() < 1e-9);
        assert!((exposure.mean_neighbor_mbps - 1_500.0).abs() < 1e-9);
        assert!((exposure.contended_s - 5.0).abs() < 1e-9);
        assert!((exposure.total_s - 10.0).abs() < 1e-9);
        // Carried = neighbors (3000) + what this transfer offered on
        // the busy chunk (800), well under the 10 Gbps cap.
        assert!((exposure.peak_carried_mbps - 3_800.0).abs() < 1e-9);
        assert_eq!(plane.active_total(), 0, "release drains the registration");
    }

    #[test]
    fn render_reports_mode_occupancy_and_ambient() {
        let plane = Arc::new(LinkPlane::shared());
        let lease = plane.clone().admit(TestbedId::Xsede, 7);
        lease.update(8, 24, 2_500.0);
        plane.set_ambient(TestbedId::Xsede, 4_000.0, 48);
        let rendered = plane.render();
        assert!(rendered.contains("link plane: shared mode, 1 active"), "{rendered}");
        assert!(rendered.contains("xsede: 1 active / 24 streams"), "{rendered}");
        assert!(rendered.contains("ambient 4000 Mbps (48 streams)"), "{rendered}");
        assert!(rendered.contains("carried 6500/10000 Mbps"), "{rendered}");
        // Untouched networks are not rendered (epoch 0 filter).
        assert!(!rendered.contains("didclab:"), "{rendered}");
        drop(lease);
        assert!(plane.render().contains("0 active transfer(s)"));
    }

    /// Stampede-plane sharding: joins/leaves on different networks
    /// never contend, and a cross-network stampede still drains every
    /// shard to exactly zero.
    #[test]
    fn cross_network_stampede_drains_every_shard() {
        let plane = Arc::new(LinkPlane::shared());
        let handles: Vec<_> = (0..6)
            .map(|worker| {
                let plane = plane.clone();
                std::thread::spawn(move || {
                    let network = TestbedId::all()[worker % 3];
                    for i in 0..200u64 {
                        let id = worker as u64 * 1_000 + i;
                        let lease = plane.clone().admit(network, id);
                        lease.update(4, 8, 500.0);
                        let _ = lease.view();
                        let _ = lease.stream_allowance();
                        drop(lease);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(plane.active_total(), 0);
        for id in TestbedId::all() {
            let occ = plane.occupancy(id);
            assert_eq!(occ.transfers, 0, "{} not drained", id.name());
            assert_eq!(occ.offered_mbps, 0.0);
            let state = plane.slot(id).lock().unwrap();
            assert_eq!(state.joins, state.leaves, "{} join/leave imbalance", id.name());
        }
    }
}
