//! Deterministic cohort scoring: what a set of parameter decisions
//! actually achieves when every transfer runs *simultaneously* on one
//! shared link.
//!
//! The convoy bake-off needs a ground truth that is independent of the
//! wall-clock interleaving of a live multi-worker run: given each
//! transfer's final θ and its own hidden network state, solve the
//! mutual-contention fixed point — every transfer's steady rate is
//! computed with all the others' rates and streams folded into its
//! contention, iterated (with damping) until the cohort settles. The
//! solver is a pure function of its inputs, so plane-aware and
//! fiction-scored decision sets are compared on identical footing.

use crate::sim::dataset::Dataset;
use crate::sim::params::Params;
use crate::sim::transfer::{NetState, PathSpec};

/// One transfer in the cohort: the decision under evaluation plus the
/// hidden state its request was served under.
#[derive(Debug, Clone, Copy)]
pub struct CohortMember {
    pub params: Params,
    pub dataset: Dataset,
    pub state: NetState,
}

/// Solve the cohort's mutual-contention fixed point: returns each
/// member's steady rate (Mbps) when all of them share `path`'s link.
/// Deterministic; `rounds` damped iterations (a dozen is plenty — the
/// map is a contraction under the damping).
pub fn solve_cohort(path: &PathSpec, members: &[CohortMember], rounds: usize) -> Vec<f64> {
    let n = members.len();
    if n == 0 {
        return Vec::new();
    }
    let bw = path.link.bandwidth_mbps;
    let streams_total: u32 = members.iter().map(|m| m.params.streams()).sum();
    // Start from an even split; the iteration reshapes it.
    let mut rates = vec![bw / n as f64; n];
    for _ in 0..rounds.max(1) {
        let total: f64 = rates.iter().sum();
        let mut next = Vec::with_capacity(n);
        for (i, member) in members.iter().enumerate() {
            let neighbor_rate = (total - rates[i]).max(0.0).min(bw);
            let neighbor_streams = streams_total.saturating_sub(member.params.streams());
            let state = member.state.with_neighbors(neighbor_rate, neighbor_streams);
            next.push(path.steady_rate_mbps(&member.dataset, &member.params, &state));
        }
        for i in 0..n {
            rates[i] = 0.5 * rates[i] + 0.5 * next[i];
        }
    }
    rates
}

/// Aggregate cohort goodput (Mbps): the fleet-level number a
/// coordinator's decisions are judged on.
pub fn aggregate_mbps(rates: &[f64]) -> f64 {
    rates.iter().sum()
}

/// Fairness spread: `(max − min) / mean` of the cohort rates (0 = every
/// transfer gets the same). 0 for empty or degenerate cohorts.
pub fn fairness_spread(rates: &[f64]) -> f64 {
    if rates.is_empty() {
        return 0.0;
    }
    let mean = rates.iter().sum::<f64>() / rates.len() as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    let max = rates.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    (max - min) / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::testbed::Testbed;

    fn members(n: usize, params: Params) -> Vec<CohortMember> {
        (0..n)
            .map(|_| CohortMember {
                params,
                dataset: Dataset::new(200, 100.0),
                state: NetState::with_load(0.2),
            })
            .collect()
    }

    #[test]
    fn solo_member_matches_the_plain_model() {
        let path = Testbed::xsede().path;
        let member = members(1, Params::new(8, 4, 4));
        let rates = solve_cohort(&path, &member, 16);
        let direct =
            path.steady_rate_mbps(&member[0].dataset, &member[0].params, &member[0].state);
        assert!((rates[0] - direct).abs() < 0.05 * direct, "{} vs {direct}", rates[0]);
    }

    #[test]
    fn crowding_degrades_everyone_and_oversubscription_collapses() {
        let path = Testbed::xsede().path;
        let solo = solve_cohort(&path, &members(1, Params::new(8, 4, 4)), 16)[0];
        let crowded = solve_cohort(&path, &members(12, Params::new(8, 4, 4)), 16);
        assert!(crowded.iter().all(|r| *r > 0.0 && r.is_finite()));
        assert!(
            crowded[0] < 0.5 * solo,
            "12-way contention must bite: {} vs solo {solo}",
            crowded[0]
        );
        // A modestly-parallel cohort beats an over-parallelized one in
        // aggregate — the loss-synchronization penalty is the point.
        let modest = solve_cohort(&path, &members(12, Params::new(2, 2, 4)), 16);
        assert!(
            aggregate_mbps(&modest) > aggregate_mbps(&crowded),
            "modest {} vs oversubscribed {}",
            aggregate_mbps(&modest),
            aggregate_mbps(&crowded)
        );
    }

    #[test]
    fn solver_is_deterministic() {
        let path = Testbed::xsede().path;
        let cohort = members(8, Params::new(4, 4, 2));
        assert_eq!(solve_cohort(&path, &cohort, 16), solve_cohort(&path, &cohort, 16));
    }

    #[test]
    fn spread_and_aggregate_helpers() {
        assert_eq!(aggregate_mbps(&[]), 0.0);
        assert_eq!(fairness_spread(&[]), 0.0);
        assert!((aggregate_mbps(&[100.0, 300.0]) - 400.0).abs() < 1e-9);
        assert!((fairness_spread(&[100.0, 300.0]) - 1.0).abs() < 1e-9);
        assert_eq!(fairness_spread(&[250.0, 250.0]), 0.0);
    }
}
