//! Multivariate quadratic and cubic polynomial regression over the
//! protocol-parameter space (p, cc, pp) — the paper's Eq. 6–9 baseline
//! surface models that piecewise splines are compared against (Fig. 3b).

use super::linsolve::least_squares_ridge;
use super::matrix::Matrix;
use anyhow::Result;

/// Degree of the polynomial surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolyDegree {
    Quadratic,
    Cubic,
}

/// Fitted polynomial surface th ≈ f(p, cc, pp).
#[derive(Debug, Clone)]
pub struct PolySurface {
    pub degree: PolyDegree,
    pub beta: Vec<f64>,
    /// Feature standardization (mean, std) per raw input — conditioning
    /// for the normal equations on the integer grid.
    pub center: [f64; 3],
    pub scale: [f64; 3],
}

/// Full monomial basis up to the requested total degree in 3 variables.
fn basis(degree: PolyDegree, x: [f64; 3]) -> Vec<f64> {
    let max_deg = match degree {
        PolyDegree::Quadratic => 2,
        PolyDegree::Cubic => 3,
    };
    let mut phi = Vec::with_capacity(if max_deg == 2 { 10 } else { 20 });
    for i in 0..=max_deg {
        for j in 0..=(max_deg - i) {
            for k in 0..=(max_deg - i - j) {
                phi.push(x[0].powi(i as i32) * x[1].powi(j as i32) * x[2].powi(k as i32));
            }
        }
    }
    phi
}

impl PolySurface {
    /// Least-squares fit (paper Eq. 7 / Eq. 9) with a small ridge term
    /// for numerical safety. `points` are (p, cc, pp) triples.
    pub fn fit(degree: PolyDegree, points: &[[f64; 3]], th: &[f64]) -> Result<PolySurface> {
        anyhow::ensure!(points.len() == th.len(), "polyfit: length mismatch");
        anyhow::ensure!(points.len() >= 4, "polyfit: need ≥4 samples, got {}", points.len());
        let mut center = [0.0; 3];
        let mut scale = [1.0; 3];
        for d in 0..3 {
            let vals: Vec<f64> = points.iter().map(|p| p[d]).collect();
            center[d] = crate::util::stats::mean(&vals);
            let s = crate::util::stats::std_pop(&vals);
            scale[d] = if s > 1e-9 { s } else { 1.0 };
        }
        let rows: Vec<Vec<f64>> = points
            .iter()
            .map(|p| {
                basis(
                    degree,
                    [
                        (p[0] - center[0]) / scale[0],
                        (p[1] - center[1]) / scale[1],
                        (p[2] - center[2]) / scale[2],
                    ],
                )
            })
            .collect();
        let x = Matrix::from_rows(&rows);
        let beta = least_squares_ridge(&x, th, 1e-6)?;
        Ok(PolySurface { degree, beta, center, scale })
    }

    /// Predict throughput at (p, cc, pp). The paper constrains
    /// f(p,cc,pp) > 0 (Eq. 9); we clamp at zero, which realizes the same
    /// constraint for prediction purposes.
    pub fn eval(&self, p: f64, cc: f64, pp: f64) -> f64 {
        let x = [
            (p - self.center[0]) / self.scale[0],
            (cc - self.center[1]) / self.scale[1],
            (pp - self.center[2]) / self.scale[2],
        ];
        let phi = basis(self.degree, x);
        let v: f64 = phi.iter().zip(&self.beta).map(|(a, b)| a * b).sum();
        v.max(0.0)
    }

    /// Argmax over a bounded integer grid (the paper's Ψ³ domain).
    pub fn argmax_grid(&self, beta_max: u32) -> ((u32, u32, u32), f64) {
        let mut best = ((1u32, 1u32, 1u32), f64::NEG_INFINITY);
        for p in 1..=beta_max {
            for cc in 1..=beta_max {
                for pp in 1..=beta_max {
                    let v = self.eval(p as f64, cc as f64, pp as f64);
                    if v > best.1 {
                        best = ((p, cc, pp), v);
                    }
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_grid(f: impl Fn(f64, f64, f64) -> f64) -> (Vec<[f64; 3]>, Vec<f64>) {
        let mut pts = Vec::new();
        let mut th = Vec::new();
        for p in 1..=6 {
            for cc in 1..=6 {
                for pp in [1.0, 2.0, 4.0, 8.0] {
                    pts.push([p as f64, cc as f64, pp]);
                    th.push(f(p as f64, cc as f64, pp));
                }
            }
        }
        (pts, th)
    }

    #[test]
    fn quadratic_recovers_quadratic() {
        let f = |p: f64, cc: f64, pp: f64| 5.0 + 2.0 * p - 0.3 * p * p + cc - 0.1 * cc * cc + 0.05 * pp;
        let (pts, th) = sample_grid(f);
        let m = PolySurface::fit(PolyDegree::Quadratic, &pts, &th).unwrap();
        for &[p, cc, pp] in pts.iter().step_by(7) {
            assert!((m.eval(p, cc, pp) - f(p, cc, pp)).abs() < 1e-4, "at ({p},{cc},{pp})");
        }
    }

    #[test]
    fn cubic_recovers_cubic() {
        let f = |p: f64, cc: f64, pp: f64| 1.0 + 0.1 * p * p * p - 0.5 * p * cc + 0.02 * pp * pp + cc;
        let (pts, th) = sample_grid(f);
        let m = PolySurface::fit(PolyDegree::Cubic, &pts, &th).unwrap();
        for &[p, cc, pp] in pts.iter().step_by(11) {
            let want = f(p, cc, pp).max(0.0);
            assert!((m.eval(p, cc, pp) - want).abs() < 1e-3, "at ({p},{cc},{pp})");
        }
    }

    #[test]
    fn quadratic_underfits_spliney_data() {
        // A sharply peaked ridge: quadratic R² should be clearly below 1.
        let f = |p: f64, cc: f64, _pp: f64| 10.0 / (1.0 + (p - 4.0).powi(2) + (cc - 2.0).powi(2));
        let (pts, th) = sample_grid(f);
        let m = PolySurface::fit(PolyDegree::Quadratic, &pts, &th).unwrap();
        let pred: Vec<f64> = pts.iter().map(|x| m.eval(x[0], x[1], x[2])).collect();
        let r2 = crate::util::stats::r_squared(&th, &pred);
        assert!(r2 < 0.9, "quadratic unexpectedly fit ridge data: r2={r2}");
    }

    #[test]
    fn eval_is_nonnegative() {
        let mut r = Rng::new(4);
        let pts: Vec<[f64; 3]> = (0..40)
            .map(|_| [r.range_f64(1.0, 8.0), r.range_f64(1.0, 8.0), r.range_f64(1.0, 8.0)])
            .collect();
        let th: Vec<f64> = (0..40).map(|_| r.range_f64(-5.0, 5.0)).collect();
        let m = PolySurface::fit(PolyDegree::Cubic, &pts, &th).unwrap();
        for _ in 0..100 {
            let v = m.eval(r.range_f64(0.0, 10.0), r.range_f64(0.0, 10.0), r.range_f64(0.0, 10.0));
            assert!(v >= 0.0 && v.is_finite());
        }
    }

    #[test]
    fn argmax_grid_finds_interior_peak() {
        let f = |p: f64, cc: f64, pp: f64| {
            20.0 - (p - 3.0).powi(2) - (cc - 5.0).powi(2) - 0.5 * (pp - 2.0).powi(2)
        };
        let (pts, th) = sample_grid(f);
        let m = PolySurface::fit(PolyDegree::Quadratic, &pts, &th).unwrap();
        let ((p, cc, pp), _) = m.argmax_grid(8);
        assert_eq!((p, cc, pp), (3, 5, 2));
    }

    #[test]
    fn rejects_too_few_samples() {
        assert!(PolySurface::fit(PolyDegree::Quadratic, &[[1.0, 1.0, 1.0]], &[1.0]).is_err());
    }
}
