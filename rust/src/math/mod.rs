//! Numerical substrate: dense linear algebra, tridiagonal solves, 1-D
//! and 2-D cubic-spline interpolation, polynomial regression, and
//! Nelder–Mead direct search. These implement the paper's Eq. 2–19
//! machinery natively in rust; the batched/hot variants are mirrored as
//! L1/L2 PJRT artifacts (see `crate::runtime`).

pub mod bicubic;
pub mod linsolve;
pub mod matrix;
pub mod neldermead;
pub mod polyfit;
pub mod spline;
pub mod tridiag;
