//! Direct linear solvers: Cholesky (SPD normal equations), LU with
//! partial pivoting (general square systems from the spline continuity
//! constraints), and a ridge-regularized least-squares helper used by the
//! quadratic/cubic regression surface models (paper Eq. 7 and Eq. 9).

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Cholesky factorization A = L·Lᵀ of a symmetric positive-definite
/// matrix; returns the lower factor. Fails on non-SPD input.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    if a.rows != a.cols {
        bail!("cholesky: non-square {}x{}", a.rows, a.cols);
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: matrix not positive definite (pivot {sum:.3e} at {i})");
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve A·x = b with A SPD via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows;
    if b.len() != n {
        bail!("solve_spd: rhs length {} != {}", b.len(), n);
    }
    let l = cholesky(a)?;
    // Forward substitution L·y = b.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    // Back substitution Lᵀ·x = y.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    Ok(x)
}

/// LU decomposition with partial pivoting; solves A·x = b for general
/// square A.
pub fn solve_lu(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    if a.rows != a.cols {
        bail!("solve_lu: non-square {}x{}", a.rows, a.cols);
    }
    let n = a.rows;
    if b.len() != n {
        bail!("solve_lu: rhs length {} != {}", b.len(), n);
    }
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // Pivot selection.
        let mut pivot_row = col;
        let mut pivot_val = lu[(col, col)].abs();
        for r in (col + 1)..n {
            let v = lu[(r, col)].abs();
            if v > pivot_val {
                pivot_val = v;
                pivot_row = r;
            }
        }
        if pivot_val < 1e-13 {
            bail!("solve_lu: singular matrix (pivot {pivot_val:.3e} at column {col})");
        }
        if pivot_row != col {
            for j in 0..n {
                let tmp = lu[(col, j)];
                lu[(col, j)] = lu[(pivot_row, j)];
                lu[(pivot_row, j)] = tmp;
            }
            perm.swap(col, pivot_row);
        }
        // Elimination.
        let inv_p = 1.0 / lu[(col, col)];
        for r in (col + 1)..n {
            let factor = lu[(r, col)] * inv_p;
            lu[(r, col)] = factor;
            for j in (col + 1)..n {
                let v = lu[(col, j)];
                lu[(r, j)] -= factor * v;
            }
        }
    }
    // Apply permutation to rhs, then forward/back substitution.
    let mut y: Vec<f64> = perm.iter().map(|&p| b[p]).collect();
    for i in 0..n {
        for k in 0..i {
            let f = lu[(i, k)];
            y[i] -= f * y[k];
        }
    }
    let mut x = y;
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            let f = lu[(i, k)];
            x[i] -= f * x[k];
        }
        x[i] /= lu[(i, i)];
    }
    Ok(x)
}

/// Ridge-regularized linear least squares: minimize |X·β − y|² + λ|β|².
/// λ > 0 keeps the normal equations SPD even for rank-deficient designs
/// (e.g. a constant pipelining column when the log only contains pp=1).
pub fn least_squares_ridge(x: &Matrix, y: &[f64], lambda: f64) -> Result<Vec<f64>> {
    if x.rows != y.len() {
        bail!("least_squares: {} rows vs {} targets", x.rows, y.len());
    }
    let mut gram = x.gram();
    for i in 0..gram.rows {
        gram[(i, i)] += lambda;
    }
    let xty = x.t_vec(y);
    solve_spd(&gram, &xty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_default, gen};

    #[test]
    fn cholesky_known() {
        let a = Matrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        let back = l.matmul(&l.transpose());
        assert!(back.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let a = Matrix::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let x_true = [1.0, -2.0, 3.0];
        let b: Vec<f64> = (0..3)
            .map(|i| (0..3).map(|j| a[(i, j)] * x_true[j]).sum())
            .collect();
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_lu_with_pivoting_needed() {
        // Leading zero forces a row swap.
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = solve_lu(&a, &[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_lu_rejects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve_lu(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn least_squares_recovers_line() {
        // y = 3 + 2 t, exactly representable → residual 0.
        let t: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let rows: Vec<Vec<f64>> = t.iter().map(|&ti| vec![1.0, ti]).collect();
        let x = Matrix::from_rows(&rows);
        let y: Vec<f64> = t.iter().map(|&ti| 3.0 + 2.0 * ti).collect();
        let beta = least_squares_ridge(&x, &y, 1e-9).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-5);
        assert!((beta[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn least_squares_handles_rank_deficiency_with_ridge() {
        // Two identical columns: unregularized normal equations are
        // singular; ridge must still return a finite solution.
        let rows: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, i as f64]).collect();
        let x = Matrix::from_rows(&rows);
        let y: Vec<f64> = (0..8).map(|i| 2.0 * i as f64).collect();
        let beta = least_squares_ridge(&x, &y, 1e-6).unwrap();
        assert!(beta.iter().all(|b| b.is_finite()));
        // Combined slope should be ~2.
        assert!((beta[0] + beta[1] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn prop_lu_solves_random_diagonally_dominant_systems() {
        forall_default(
            |r| {
                let n = r.range_u(2, 8) as usize;
                let mut rows = Vec::with_capacity(n);
                for i in 0..n {
                    let mut row: Vec<f64> = (0..n).map(|_| r.range_f64(-1.0, 1.0)).collect();
                    row[i] += n as f64; // diagonal dominance → nonsingular
                    rows.push(row);
                }
                let x_true: Vec<f64> = (0..n).map(|_| r.range_f64(-5.0, 5.0)).collect();
                (rows, x_true)
            },
            |(rows, x_true)| {
                let a = Matrix::from_rows(rows);
                let n = x_true.len();
                let b: Vec<f64> = (0..n)
                    .map(|i| (0..n).map(|j| a[(i, j)] * x_true[j]).sum())
                    .collect();
                let x = solve_lu(&a, &b).map_err(|e| e.to_string())?;
                for (xi, ti) in x.iter().zip(x_true) {
                    if (xi - ti).abs() > 1e-7 {
                        return Err(format!("solution mismatch: {xi} vs {ti}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_spd_solver_matches_lu_on_gram_matrices() {
        forall_default(
            |r| {
                let n = r.range_u(2, 6) as usize;
                let m = n + r.range_u(2, 6) as usize;
                let rows: Vec<Vec<f64>> = (0..m)
                    .map(|_| (0..n).map(|_| r.range_f64(-2.0, 2.0)).collect())
                    .collect();
                let b = gen::vec_f64(r, n, n, -3.0, 3.0);
                (rows, b)
            },
            |(rows, b)| {
                let x = Matrix::from_rows(rows);
                let mut g = x.gram();
                for i in 0..g.rows {
                    g[(i, i)] += 0.1; // ensure SPD
                }
                let via_chol = solve_spd(&g, b).map_err(|e| e.to_string())?;
                let via_lu = solve_lu(&g, b).map_err(|e| e.to_string())?;
                for (a_, b_) in via_chol.iter().zip(&via_lu) {
                    if (a_ - b_).abs() > 1e-7 {
                        return Err(format!("chol {a_} vs lu {b_}"));
                    }
                }
                Ok(())
            },
        );
    }
}
