//! Dense row-major f64 matrix with just the operations the offline
//! analysis needs: products, transpose, and Gram matrices for the
//! least-squares fits. Deliberately simple — hot loops that matter for
//! performance live either in the PJRT artifacts (L1/L2) or in
//! specialized routines (`tridiag`), not here.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        assert!(!rows.is_empty(), "from_rows: empty");
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            data.extend_from_slice(r);
        }
        Matrix { rows: rows.len(), cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(xs: &[f64]) -> Matrix {
        Matrix { rows: xs.len(), cols: 1, data: xs.to_vec() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product with the classic i-k-j loop order (cache-friendly
    /// for row-major without blocking; fine at the sizes used here).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dims {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = k * other.cols;
                let crow = i * other.cols;
                for j in 0..other.cols {
                    out.data[crow + j] += a * other.data[orow + j];
                }
            }
        }
        out
    }

    /// Gram matrix AᵀA — the normal-equations building block.
    pub fn gram(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..self.cols {
                    out.data[i * self.cols + j] += xi * row[j];
                }
            }
        }
        // Mirror the upper triangle.
        for i in 0..self.cols {
            for j in 0..i {
                out.data[i * self.cols + j] = out.data[j * self.cols + i];
            }
        }
        out
    }

    /// Aᵀ·y for a response vector y.
    pub fn t_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, y.len());
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            let yr = y[r];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += x * yr;
            }
        }
        out
    }

    /// Max |a_ij - b_ij| — used by tests.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Display for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.rows {
            for j in 0..self.cols {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(&[vec![7.0, 8.0], vec![9.0, 10.0], vec![11.0, 12.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[vec![58.0, 64.0], vec![139.0, 154.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_equals_at_a() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        let direct = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&direct) < 1e-12);
    }

    #[test]
    fn t_vec_equals_transpose_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let y = [1.0, -1.0, 2.0];
        let v = a.t_vec(&y);
        let direct = a.transpose().matmul(&Matrix::col_vec(&y));
        assert!((v[0] - direct[(0, 0)]).abs() < 1e-12);
        assert!((v[1] - direct[(1, 0)]).abs() < 1e-12);
    }
}
