//! Natural ("relaxed") 1-D cubic spline interpolation — paper Eq. 10–14.
//!
//! The paper models throughput over pipelining with a 2-D (x, th) cubic
//! spline (its Fig. 2); this module is that construction: piecewise cubic
//! polynomials through the knots, C² continuity at interior knots, zero
//! second derivative at the boundary (Eq. 14). Coefficients come from the
//! tridiagonal system in the knot second derivatives (solved with the
//! Thomas algorithm).

use super::tridiag::solve_tridiag;
use anyhow::{bail, Result};

/// A fitted natural cubic spline over strictly increasing knots.
#[derive(Debug, Clone, PartialEq)]
pub struct CubicSpline {
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    /// Second derivatives at the knots (m[0] = m[n−1] = 0 for natural BC).
    pub m: Vec<f64>,
}

impl CubicSpline {
    /// Fit the spline. Requires ≥ 2 strictly increasing knots.
    pub fn fit(xs: &[f64], ys: &[f64]) -> Result<CubicSpline> {
        if xs.len() != ys.len() {
            bail!("spline: {} xs vs {} ys", xs.len(), ys.len());
        }
        let n = xs.len();
        if n < 2 {
            bail!("spline: need at least 2 knots, got {n}");
        }
        for w in xs.windows(2) {
            if w[1] <= w[0] {
                bail!("spline: knots must be strictly increasing ({} then {})", w[0], w[1]);
            }
        }
        if n == 2 {
            // Degenerate: straight line, zero curvature.
            return Ok(CubicSpline { xs: xs.to_vec(), ys: ys.to_vec(), m: vec![0.0; 2] });
        }
        // Interior system (n−2 unknown second derivatives):
        //   h[i−1]·m[i−1] + 2(h[i−1]+h[i])·m[i] + h[i]·m[i+1] = 6·(d[i] − d[i−1])
        // with d[i] = (y[i+1]−y[i])/h[i].
        let h: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
        let d: Vec<f64> = ys
            .windows(2)
            .zip(&h)
            .map(|(w, hi)| (w[1] - w[0]) / hi)
            .collect();
        let k = n - 2;
        let mut lower = vec![0.0; k];
        let mut diag = vec![0.0; k];
        let mut upper = vec![0.0; k];
        let mut rhs = vec![0.0; k];
        for i in 0..k {
            lower[i] = if i == 0 { 0.0 } else { h[i] };
            diag[i] = 2.0 * (h[i] + h[i + 1]);
            upper[i] = if i == k - 1 { 0.0 } else { h[i + 1] };
            rhs[i] = 6.0 * (d[i + 1] - d[i]);
        }
        let interior = solve_tridiag(&lower, &diag, &upper, &rhs)?;
        let mut m = vec![0.0; n];
        m[1..(k + 1)].copy_from_slice(&interior);
        Ok(CubicSpline { xs: xs.to_vec(), ys: ys.to_vec(), m })
    }

    /// Index of the piece containing `x` (clamped to the domain — the
    /// bounded integer parameter space of the paper never extrapolates
    /// far, and clamping keeps the online module robust to queries at
    /// the search-space boundary).
    fn piece(&self, x: f64) -> usize {
        let n = self.xs.len();
        if x <= self.xs[0] {
            return 0;
        }
        if x >= self.xs[n - 1] {
            return n - 2;
        }
        // Binary search for the rightmost knot ≤ x.
        match self
            .xs
            .binary_search_by(|probe| probe.partial_cmp(&x).unwrap())
        {
            Ok(i) => i.min(n - 2),
            Err(i) => i - 1,
        }
    }

    /// Evaluate the spline at `x` (clamped extrapolation beyond ends).
    pub fn eval(&self, x: f64) -> f64 {
        let i = self.piece(x);
        let h = self.xs[i + 1] - self.xs[i];
        let a = (self.xs[i + 1] - x) / h;
        let b = (x - self.xs[i]) / h;
        a * self.ys[i]
            + b * self.ys[i + 1]
            + ((a * a * a - a) * self.m[i] + (b * b * b - b) * self.m[i + 1]) * h * h / 6.0
    }

    /// First derivative.
    pub fn deriv(&self, x: f64) -> f64 {
        let i = self.piece(x);
        let h = self.xs[i + 1] - self.xs[i];
        let a = (self.xs[i + 1] - x) / h;
        let b = (x - self.xs[i]) / h;
        (self.ys[i + 1] - self.ys[i]) / h
            + ((3.0 * b * b - 1.0) * self.m[i + 1] - (3.0 * a * a - 1.0) * self.m[i]) * h / 6.0
    }

    /// Second derivative (linear between knot values of m).
    pub fn deriv2(&self, x: f64) -> f64 {
        let i = self.piece(x);
        let h = self.xs[i + 1] - self.xs[i];
        let a = (self.xs[i + 1] - x) / h;
        let b = (x - self.xs[i]) / h;
        a * self.m[i] + b * self.m[i + 1]
    }

    /// Power-basis coefficients `c0 + c1·t + c2·t² + c3·t³` of piece `i`
    /// in the *local* coordinate `t = x − xs[i]` (Eq. 10's form). These
    /// feed the AOT surface-evaluation artifact and the maxima finder.
    pub fn piece_coeffs(&self, i: usize) -> [f64; 4] {
        assert!(i + 1 < self.xs.len());
        let h = self.xs[i + 1] - self.xs[i];
        let (y0, y1) = (self.ys[i], self.ys[i + 1]);
        let (m0, m1) = (self.m[i], self.m[i + 1]);
        let c0 = y0;
        let c1 = (y1 - y0) / h - h * (2.0 * m0 + m1) / 6.0;
        let c2 = m0 / 2.0;
        let c3 = (m1 - m0) / (6.0 * h);
        [c0, c1, c2, c3]
    }

    /// Argmax over the domain by dense scan + local refinement. The
    /// paper's domain is a small bounded integer grid, so resolution 512
    /// is far beyond what the online module needs.
    pub fn argmax(&self, resolution: usize) -> (f64, f64) {
        let (lo, hi) = (self.xs[0], *self.xs.last().unwrap());
        let mut best_x = lo;
        let mut best_y = f64::NEG_INFINITY;
        for k in 0..=resolution {
            let x = lo + (hi - lo) * k as f64 / resolution as f64;
            let y = self.eval(x);
            if y > best_y {
                best_y = y;
                best_x = x;
            }
        }
        (best_x, best_y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_default, gen};
    use crate::util::rng::Rng;

    fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
        assert!((a - b).abs() < tol, "{what}: {a} vs {b}");
    }

    #[test]
    fn interpolates_knots_exactly() {
        let xs = [1.0, 2.0, 4.0, 5.0, 8.0];
        let ys = [3.0, -1.0, 2.0, 2.5, 0.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            assert_close(s.eval(*x), *y, 1e-12, "knot value");
        }
    }

    #[test]
    fn natural_boundary_conditions() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 0.0, 1.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        assert_close(s.deriv2(0.0), 0.0, 1e-12, "left d2");
        assert_close(s.deriv2(3.0), 0.0, 1e-12, "right d2");
    }

    #[test]
    fn two_knots_is_linear() {
        let s = CubicSpline::fit(&[0.0, 2.0], &[1.0, 5.0]).unwrap();
        assert_close(s.eval(1.0), 3.0, 1e-12, "midpoint");
        assert_close(s.deriv(0.5), 2.0, 1e-12, "slope");
    }

    #[test]
    fn c1_c2_continuity_at_interior_knots() {
        let xs = [0.0, 1.0, 2.5, 3.0, 4.2];
        let ys = [1.0, -2.0, 0.5, 3.0, 2.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        let eps = 1e-6;
        for &x in &xs[1..xs.len() - 1] {
            assert_close(s.eval(x - eps), s.eval(x + eps), 1e-4, "C0");
            assert_close(s.deriv(x - eps), s.deriv(x + eps), 1e-3, "C1");
            assert_close(s.deriv2(x - eps), s.deriv2(x + eps), 1e-2, "C2");
        }
    }

    #[test]
    fn reproduces_linear_function_exactly() {
        // A natural spline through samples of a line IS that line.
        let xs: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x - 1.0).collect();
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for k in 0..60 {
            let x = k as f64 * 0.1;
            assert_close(s.eval(x), 2.0 * x - 1.0, 1e-10, "line");
        }
    }

    #[test]
    fn piece_coeffs_match_eval() {
        let xs = [0.0, 1.0, 2.0, 4.0];
        let ys = [0.0, 2.0, -1.0, 3.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        for i in 0..xs.len() - 1 {
            let c = s.piece_coeffs(i);
            for k in 0..=10 {
                let t = (xs[i + 1] - xs[i]) * k as f64 / 10.0;
                let via_coeffs = c[0] + c[1] * t + c[2] * t * t + c[3] * t * t * t;
                assert_close(via_coeffs, s.eval(xs[i] + t), 1e-10, "coeff eval");
            }
        }
    }

    #[test]
    fn clamped_extrapolation_is_finite() {
        let s = CubicSpline::fit(&[0.0, 1.0, 2.0], &[0.0, 1.0, 0.0]).unwrap();
        assert!(s.eval(-5.0).is_finite());
        assert!(s.eval(10.0).is_finite());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(CubicSpline::fit(&[0.0], &[1.0]).is_err());
        assert!(CubicSpline::fit(&[0.0, 0.0], &[1.0, 2.0]).is_err());
        assert!(CubicSpline::fit(&[1.0, 0.5], &[1.0, 2.0]).is_err());
        assert!(CubicSpline::fit(&[0.0, 1.0], &[1.0]).is_err());
    }

    #[test]
    fn argmax_finds_peak() {
        // Unimodal data: peak at knot 2.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 4.0, 9.0, 4.0, 1.0];
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        let (x_star, y_star) = s.argmax(512);
        assert!((x_star - 3.0).abs() < 0.15, "argmax at {x_star}");
        assert!(y_star >= 9.0 - 1e-9);
    }

    #[test]
    fn prop_interpolation_and_smoothness_on_random_knots() {
        forall_default(
            |r: &mut Rng| {
                let n = r.range_u(3, 12) as usize;
                let lo = r.range_f64(-3.0, 3.0);
                let xs = gen::increasing(r, n, lo, 1.5);
                let ys = gen::vec_f64(r, n, n, -10.0, 10.0);
                (xs, ys)
            },
            |(xs, ys)| {
                let s = CubicSpline::fit(xs, ys).map_err(|e| e.to_string())?;
                for (x, y) in xs.iter().zip(ys) {
                    if (s.eval(*x) - y).abs() > 1e-8 {
                        return Err(format!("knot not interpolated: {x}"));
                    }
                }
                // Natural BCs.
                if s.deriv2(xs[0]).abs() > 1e-8 || s.deriv2(*xs.last().unwrap()).abs() > 1e-8 {
                    return Err("non-natural boundary".into());
                }
                // C1 continuity at interior knots.
                for &x in &xs[1..xs.len() - 1] {
                    let eps = 1e-7;
                    if (s.deriv(x - eps) - s.deriv(x + eps)).abs() > 1e-2 {
                        return Err(format!("C1 break at {x}"));
                    }
                }
                Ok(())
            },
        );
    }
}
