//! Bicubic spline surface interpolation on a rectangular grid — the
//! paper's 2-D extension of Eq. 10–14 used to model `th = f(p, cc)` per
//! cluster/load-bin (its Fig. 1 surfaces).
//!
//! Construction: 1-D natural cubic splines along both grid axes give the
//! nodal partial derivatives `f_x`, `f_y` and the cross derivative
//! `f_xy`; each grid cell then gets a 4×4 power-basis coefficient matrix
//! through the standard bicubic Hermite system, yielding a C¹ surface
//! that interpolates every grid node (C² along grid lines by
//! construction of the 1-D splines). The per-patch coefficient tensor is
//! exactly what the L1 Pallas `surface_eval` kernel consumes, so the
//! rust evaluation here doubles as the native reference for the PJRT
//! differential tests.

use super::spline::CubicSpline;
use anyhow::{bail, Result};

/// Inverse Hermite basis: with f(t,u) = Σ_{i,j} a[i][j]·tⁱ·uʲ on the unit
/// square, A = M · F · Mᵀ where F packs values/derivatives at the 4
/// corners (see `patch_coeffs`).
const M: [[f64; 4]; 4] = [
    [1.0, 0.0, 0.0, 0.0],
    [0.0, 0.0, 1.0, 0.0],
    [-3.0, 3.0, -2.0, -1.0],
    [2.0, -2.0, 1.0, 1.0],
];

/// A bicubic spline surface over `xs × ys` with values `z[i][j] =
/// f(xs[i], ys[j])` (row-major: `z[i*ny + j]`).
#[derive(Debug, Clone)]
pub struct BicubicSurface {
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub z: Vec<f64>,
    /// Per-cell power-basis coefficients, `(nx−1)·(ny−1)` patches of 16,
    /// patch (i, j) at `coeffs[(i*(ny−1)+j)*16 ..]`, local coordinates
    /// t = (x − xs[i]) / hx, u = (y − ys[j]) / hy in [0, 1].
    pub coeffs: Vec<f64>,
}

impl BicubicSurface {
    pub fn nx(&self) -> usize {
        self.xs.len()
    }

    pub fn ny(&self) -> usize {
        self.ys.len()
    }

    /// Fit the surface. `z` is row-major with `xs.len()·ys.len()`
    /// entries; both knot vectors must be strictly increasing with ≥ 2
    /// entries.
    pub fn fit(xs: &[f64], ys: &[f64], z: &[f64]) -> Result<BicubicSurface> {
        let (nx, ny) = (xs.len(), ys.len());
        if nx < 2 || ny < 2 {
            bail!("bicubic: need ≥2 knots per axis ({nx}×{ny})");
        }
        if z.len() != nx * ny {
            bail!("bicubic: z has {} entries, expected {}", z.len(), nx * ny);
        }
        for w in xs.windows(2).chain(ys.windows(2)) {
            if w[1] <= w[0] {
                bail!("bicubic: knots must be strictly increasing");
            }
        }

        // Nodal derivative fields via 1-D natural splines.
        let mut fx = vec![0.0; nx * ny]; // ∂f/∂x at nodes
        let mut fy = vec![0.0; nx * ny]; // ∂f/∂y at nodes
        let mut fxy = vec![0.0; nx * ny]; // ∂²f/∂x∂y at nodes

        // ∂/∂y: spline each row (fixed x_i) over ys.
        for i in 0..nx {
            let row: Vec<f64> = (0..ny).map(|j| z[i * ny + j]).collect();
            let s = CubicSpline::fit(ys, &row)?;
            for j in 0..ny {
                fy[i * ny + j] = s.deriv(ys[j]);
            }
        }
        // ∂/∂x: spline each column (fixed y_j) over xs.
        for j in 0..ny {
            let col: Vec<f64> = (0..nx).map(|i| z[i * ny + j]).collect();
            let s = CubicSpline::fit(xs, &col)?;
            for i in 0..nx {
                fx[i * ny + j] = s.deriv(xs[i]);
            }
        }
        // Cross derivative: spline the fy field along x.
        for j in 0..ny {
            let col: Vec<f64> = (0..nx).map(|i| fy[i * ny + j]).collect();
            let s = CubicSpline::fit(xs, &col)?;
            for i in 0..nx {
                fxy[i * ny + j] = s.deriv(xs[i]);
            }
        }

        // Per-cell Hermite → power-basis coefficients.
        let mut coeffs = vec![0.0; (nx - 1) * (ny - 1) * 16];
        for i in 0..nx - 1 {
            let hx = xs[i + 1] - xs[i];
            for j in 0..ny - 1 {
                let hy = ys[j + 1] - ys[j];
                let at = |field: &[f64], di: usize, dj: usize| field[(i + di) * ny + (j + dj)];
                // F packs [f, fy; fx, fxy] blocks, derivatives scaled to
                // the unit square (∂t = hx·∂x, ∂u = hy·∂y).
                let f = [
                    [at(&z, 0, 0), at(&z, 0, 1), hy * at(&fy, 0, 0), hy * at(&fy, 0, 1)],
                    [at(&z, 1, 0), at(&z, 1, 1), hy * at(&fy, 1, 0), hy * at(&fy, 1, 1)],
                    [
                        hx * at(&fx, 0, 0),
                        hx * at(&fx, 0, 1),
                        hx * hy * at(&fxy, 0, 0),
                        hx * hy * at(&fxy, 0, 1),
                    ],
                    [
                        hx * at(&fx, 1, 0),
                        hx * at(&fx, 1, 1),
                        hx * hy * at(&fxy, 1, 0),
                        hx * hy * at(&fxy, 1, 1),
                    ],
                ];
                // A = M · F · Mᵀ
                let mut mf = [[0.0; 4]; 4];
                for r in 0..4 {
                    for c in 0..4 {
                        let mut acc = 0.0;
                        for k in 0..4 {
                            acc += M[r][k] * f[k][c];
                        }
                        mf[r][c] = acc;
                    }
                }
                let base = (i * (ny - 1) + j) * 16;
                for r in 0..4 {
                    for c in 0..4 {
                        let mut acc = 0.0;
                        for k in 0..4 {
                            acc += mf[r][k] * M[c][k];
                        }
                        coeffs[base + r * 4 + c] = acc;
                    }
                }
            }
        }

        Ok(BicubicSurface { xs: xs.to_vec(), ys: ys.to_vec(), z: z.to_vec(), coeffs })
    }

    /// Locate the cell containing (x, y), clamped to the domain, and the
    /// unit-square local coordinates.
    fn locate(&self, x: f64, y: f64) -> (usize, usize, f64, f64) {
        let i = cell_index(&self.xs, x);
        let j = cell_index(&self.ys, y);
        let t = (x - self.xs[i]) / (self.xs[i + 1] - self.xs[i]);
        let u = (y - self.ys[j]) / (self.ys[j + 1] - self.ys[j]);
        (i, j, t.clamp(0.0, 1.0), u.clamp(0.0, 1.0))
    }

    #[inline]
    fn patch(&self, i: usize, j: usize) -> &[f64] {
        let base = (i * (self.ny() - 1) + j) * 16;
        &self.coeffs[base..base + 16]
    }

    /// Evaluate the surface at (x, y); clamped at the domain boundary.
    pub fn eval(&self, x: f64, y: f64) -> f64 {
        let (i, j, t, u) = self.locate(x, y);
        let a = self.patch(i, j);
        // Horner in u inside Horner in t.
        let mut acc = 0.0;
        for r in (0..4).rev() {
            let row = &a[r * 4..r * 4 + 4];
            let pu = ((row[3] * u + row[2]) * u + row[1]) * u + row[0];
            acc = acc * t + pu;
        }
        acc
    }

    /// Gradient (∂f/∂x, ∂f/∂y).
    pub fn grad(&self, x: f64, y: f64) -> (f64, f64) {
        let (i, j, t, u) = self.locate(x, y);
        let a = self.patch(i, j);
        let hx = self.xs[i + 1] - self.xs[i];
        let hy = self.ys[j + 1] - self.ys[j];
        let (mut dt, mut du) = (0.0, 0.0);
        for r in 0..4 {
            for c in 0..4 {
                let coeff = a[r * 4 + c];
                if r > 0 {
                    dt += coeff * r as f64 * t.powi(r as i32 - 1) * u.powi(c as i32);
                }
                if c > 0 {
                    du += coeff * t.powi(r as i32) * c as f64 * u.powi(c as i32 - 1);
                }
            }
        }
        (dt / hx, du / hy)
    }

    /// Hessian [[fxx, fxy], [fxy, fyy]] — the paper's second-partial-
    /// derivative test (Eq. 18) runs on this.
    pub fn hessian(&self, x: f64, y: f64) -> [[f64; 2]; 2] {
        let (i, j, t, u) = self.locate(x, y);
        let a = self.patch(i, j);
        let hx = self.xs[i + 1] - self.xs[i];
        let hy = self.ys[j + 1] - self.ys[j];
        let (mut dtt, mut duu, mut dtu) = (0.0, 0.0, 0.0);
        for r in 0..4 {
            for c in 0..4 {
                let coeff = a[r * 4 + c];
                if r > 1 {
                    dtt += coeff * (r * (r - 1)) as f64 * t.powi(r as i32 - 2) * u.powi(c as i32);
                }
                if c > 1 {
                    duu += coeff * (c * (c - 1)) as f64 * t.powi(r as i32) * u.powi(c as i32 - 2);
                }
                if r > 0 && c > 0 {
                    dtu += coeff
                        * (r * c) as f64
                        * t.powi(r as i32 - 1)
                        * u.powi(c as i32 - 1);
                }
            }
        }
        let fxx = dtt / (hx * hx);
        let fyy = duu / (hy * hy);
        let fxy = dtu / (hx * hy);
        [[fxx, fxy], [fxy, fyy]]
    }

    /// Evaluate on a dense `rx × ry` grid covering the domain — the
    /// native counterpart of the PJRT `surface_eval` artifact.
    pub fn eval_grid(&self, rx: usize, ry: usize) -> Vec<f64> {
        let (x0, x1) = (self.xs[0], *self.xs.last().unwrap());
        let (y0, y1) = (self.ys[0], *self.ys.last().unwrap());
        let mut out = Vec::with_capacity(rx * ry);
        for ix in 0..rx {
            let x = x0 + (x1 - x0) * ix as f64 / (rx - 1).max(1) as f64;
            for iy in 0..ry {
                let y = y0 + (y1 - y0) * iy as f64 / (ry - 1).max(1) as f64;
                out.push(self.eval(x, y));
            }
        }
        out
    }
}

/// Rightmost cell whose left knot ≤ x, clamped into [0, n−2].
fn cell_index(knots: &[f64], x: f64) -> usize {
    let n = knots.len();
    if x <= knots[0] {
        return 0;
    }
    if x >= knots[n - 1] {
        return n - 2;
    }
    match knots.binary_search_by(|probe| probe.partial_cmp(&x).unwrap()) {
        Ok(i) => i.min(n - 2),
        Err(i) => i - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_default;
    use crate::util::rng::Rng;

    fn grid_z(xs: &[f64], ys: &[f64], f: impl Fn(f64, f64) -> f64) -> Vec<f64> {
        let mut z = Vec::with_capacity(xs.len() * ys.len());
        for &x in xs {
            for &y in ys {
                z.push(f(x, y));
            }
        }
        z
    }

    #[test]
    fn interpolates_grid_nodes() {
        let xs = [0.0, 1.0, 2.0, 3.5];
        let ys = [0.0, 0.5, 2.0];
        let z = grid_z(&xs, &ys, |x, y| (x * 1.3).sin() + y * y);
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in ys.iter().enumerate() {
                let v = s.eval(x, y);
                assert!((v - z[i * ys.len() + j]).abs() < 1e-10, "node ({x},{y}): {v}");
            }
        }
    }

    #[test]
    fn reproduces_bilinear_exactly() {
        // f(x,y) = 2 + x − 3y + 0.5xy is in the bicubic space; natural
        // splines reproduce its (linear) cross-sections exactly.
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 2.0];
        let f = |x: f64, y: f64| 2.0 + x - 3.0 * y + 0.5 * x * y;
        let z = grid_z(&xs, &ys, f);
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        for k in 0..50 {
            let x = 3.0 * (k as f64) / 49.0;
            let y = 2.0 * ((k * 7 % 50) as f64) / 49.0;
            assert!((s.eval(x, y) - f(x, y)).abs() < 1e-9, "at ({x},{y})");
        }
    }

    #[test]
    fn continuity_across_cell_boundaries() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 2.0, 3.0];
        let z = grid_z(&xs, &ys, |x, y| (x - 1.5).powi(2) * (y * 0.7).cos());
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        let eps = 1e-7;
        for &xb in &[1.0, 2.0] {
            for k in 0..20 {
                let y = 3.0 * k as f64 / 19.0;
                let l = s.eval(xb - eps, y);
                let r = s.eval(xb + eps, y);
                assert!((l - r).abs() < 1e-5, "C0 x-break at ({xb},{y}): {l} vs {r}");
                let (gl, _) = s.grad(xb - eps, y);
                let (gr, _) = s.grad(xb + eps, y);
                assert!((gl - gr).abs() < 1e-3, "C1 x-break at ({xb},{y})");
            }
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 2.0, 3.0];
        let z = grid_z(&xs, &ys, |x, y| x * x - y * x + 2.0 * y);
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        let eps = 1e-6;
        for &(x, y) in &[(0.4, 0.7), (1.5, 1.5), (2.3, 0.9)] {
            let (gx, gy) = s.grad(x, y);
            let fdx = (s.eval(x + eps, y) - s.eval(x - eps, y)) / (2.0 * eps);
            let fdy = (s.eval(x, y + eps) - s.eval(x, y - eps)) / (2.0 * eps);
            assert!((gx - fdx).abs() < 1e-5, "gx at ({x},{y}): {gx} vs {fdx}");
            assert!((gy - fdy).abs() < 1e-5, "gy at ({x},{y}): {gy} vs {fdy}");
        }
    }

    #[test]
    fn hessian_matches_finite_difference() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 1.0, 2.0, 3.0];
        let z = grid_z(&xs, &ys, |x, y| x * x * y + y * y);
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        let eps = 1e-4;
        let (x, y) = (1.4, 1.6);
        let h = s.hessian(x, y);
        let fxx = (s.eval(x + eps, y) - 2.0 * s.eval(x, y) + s.eval(x - eps, y)) / (eps * eps);
        let fyy = (s.eval(x, y + eps) - 2.0 * s.eval(x, y) + s.eval(x, y - eps)) / (eps * eps);
        let fxy = (s.eval(x + eps, y + eps) - s.eval(x + eps, y - eps) - s.eval(x - eps, y + eps)
            + s.eval(x - eps, y - eps))
            / (4.0 * eps * eps);
        assert!((h[0][0] - fxx).abs() < 1e-2, "fxx {} vs {}", h[0][0], fxx);
        assert!((h[1][1] - fyy).abs() < 1e-2, "fyy {} vs {}", h[1][1], fyy);
        assert!((h[0][1] - fxy).abs() < 1e-2, "fxy {} vs {}", h[0][1], fxy);
    }

    #[test]
    fn eval_grid_corners_match_nodes() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [1.0, 2.0];
        let z = grid_z(&xs, &ys, |x, y| x + 10.0 * y);
        let s = BicubicSurface::fit(&xs, &ys, &z).unwrap();
        let g = s.eval_grid(5, 3);
        assert_eq!(g.len(), 15);
        assert!((g[0] - s.eval(1.0, 1.0)).abs() < 1e-12);
        assert!((g[14] - s.eval(3.0, 2.0)).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(BicubicSurface::fit(&[0.0], &[0.0, 1.0], &[1.0, 2.0]).is_err());
        assert!(BicubicSurface::fit(&[0.0, 1.0], &[0.0, 1.0], &[1.0, 2.0, 3.0]).is_err());
        assert!(BicubicSurface::fit(&[1.0, 0.0], &[0.0, 1.0], &[1.0; 4]).is_err());
    }

    #[test]
    fn prop_random_grids_interpolate_and_stay_bounded() {
        forall_default(
            |r: &mut Rng| {
                let nx = r.range_u(2, 7) as usize;
                let ny = r.range_u(2, 7) as usize;
                let mut acc = 0.0;
                let xs: Vec<f64> = (0..nx)
                    .map(|_| {
                        let v = acc;
                        acc += r.range_f64(0.5, 2.0);
                        v
                    })
                    .collect();
                acc = 0.0;
                let ys: Vec<f64> = (0..ny)
                    .map(|_| {
                        let v = acc;
                        acc += r.range_f64(0.5, 2.0);
                        v
                    })
                    .collect();
                let z: Vec<f64> = (0..nx * ny).map(|_| r.range_f64(0.0, 100.0)).collect();
                (xs, ys, z)
            },
            |(xs, ys, z)| {
                let s = BicubicSurface::fit(xs, ys, z).map_err(|e| e.to_string())?;
                let ny = ys.len();
                for (i, &x) in xs.iter().enumerate() {
                    for (j, &y) in ys.iter().enumerate() {
                        if (s.eval(x, y) - z[i * ny + j]).abs() > 1e-7 {
                            return Err(format!("node ({i},{j}) not interpolated"));
                        }
                    }
                }
                // Interior evaluations remain finite & loosely bounded
                // (cubics can overshoot but not explode).
                for k in 0..25 {
                    let x = xs[0] + (xs[xs.len() - 1] - xs[0]) * k as f64 / 24.0;
                    let y = ys[0] + (ys[ny - 1] - ys[0]) * ((k * 7) % 25) as f64 / 24.0;
                    let v = s.eval(x, y);
                    if !v.is_finite() || v.abs() > 1e4 {
                        return Err(format!("unbounded value {v} at ({x},{y})"));
                    }
                }
                Ok(())
            },
        );
    }
}
