//! Thomas algorithm for tridiagonal systems.
//!
//! Natural cubic-spline coefficient computation (paper Eq. 10–14) reduces
//! to a tridiagonal solve in the knot second-derivatives; this is the
//! O(n) hot path of offline surface construction on the rust side.

use anyhow::{bail, Result};

/// Solve a tridiagonal system
/// `lower[i]·x[i−1] + diag[i]·x[i] + upper[i]·x[i+1] = rhs[i]`.
/// `lower[0]` and `upper[n−1]` are ignored. Requires a (numerically)
/// non-singular system; diagonal dominance — which spline systems have —
/// guarantees stability without pivoting.
pub fn solve_tridiag(lower: &[f64], diag: &[f64], upper: &[f64], rhs: &[f64]) -> Result<Vec<f64>> {
    let n = diag.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    if lower.len() != n || upper.len() != n || rhs.len() != n {
        bail!("tridiag: inconsistent lengths");
    }
    let mut c_prime = vec![0.0; n];
    let mut d_prime = vec![0.0; n];
    if diag[0].abs() < 1e-300 {
        bail!("tridiag: zero pivot at row 0");
    }
    c_prime[0] = upper[0] / diag[0];
    d_prime[0] = rhs[0] / diag[0];
    for i in 1..n {
        let denom = diag[i] - lower[i] * c_prime[i - 1];
        if denom.abs() < 1e-300 {
            bail!("tridiag: zero pivot at row {i}");
        }
        c_prime[i] = upper[i] / denom;
        d_prime[i] = (rhs[i] - lower[i] * d_prime[i - 1]) / denom;
    }
    let mut x = d_prime;
    for i in (0..n - 1).rev() {
        let next = x[i + 1];
        x[i] -= c_prime[i] * next;
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_default;

    #[test]
    fn solves_known_system() {
        // [2 1 0; 1 2 1; 0 1 2] x = [4; 8; 8] → x = [1; 2; 3]
        let x = solve_tridiag(
            &[0.0, 1.0, 1.0],
            &[2.0, 2.0, 2.0],
            &[1.0, 1.0, 0.0],
            &[4.0, 8.0, 8.0],
        )
        .unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
        assert!((x[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn single_element() {
        let x = solve_tridiag(&[0.0], &[4.0], &[0.0], &[8.0]).unwrap();
        assert_eq!(x, vec![2.0]);
    }

    #[test]
    fn empty_is_ok() {
        assert!(solve_tridiag(&[], &[], &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn rejects_length_mismatch() {
        assert!(solve_tridiag(&[0.0], &[1.0, 2.0], &[0.0, 0.0], &[1.0, 1.0]).is_err());
    }

    #[test]
    fn prop_random_dominant_systems_roundtrip() {
        forall_default(
            |r| {
                let n = r.range_u(1, 40) as usize;
                let lower: Vec<f64> = (0..n).map(|_| r.range_f64(-1.0, 1.0)).collect();
                let upper: Vec<f64> = (0..n).map(|_| r.range_f64(-1.0, 1.0)).collect();
                let diag: Vec<f64> = (0..n).map(|_| r.range_f64(3.0, 6.0)).collect();
                let x_true: Vec<f64> = (0..n).map(|_| r.range_f64(-10.0, 10.0)).collect();
                (lower, diag, upper, x_true)
            },
            |(lower, diag, upper, x_true)| {
                let n = diag.len();
                let mut rhs = vec![0.0; n];
                for i in 0..n {
                    rhs[i] = diag[i] * x_true[i];
                    if i > 0 {
                        rhs[i] += lower[i] * x_true[i - 1];
                    }
                    if i + 1 < n {
                        rhs[i] += upper[i] * x_true[i + 1];
                    }
                }
                let x = solve_tridiag(lower, diag, upper, &rhs).map_err(|e| e.to_string())?;
                for (a, b) in x.iter().zip(x_true) {
                    if (a - b).abs() > 1e-8 {
                        return Err(format!("{a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}
