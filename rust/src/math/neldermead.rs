//! Nelder–Mead downhill simplex — the substrate for the NMT baseline
//! (Balaprakash et al., "Improving data transfer throughput with direct
//! search optimization", ICPP'16), which the paper compares against.
//! Implemented for maximization over a bounded box with optional integer
//! rounding, since the transfer parameters live on a bounded integer
//! domain.

/// One step record (for convergence diagnostics / Fig. 6-style plots).
#[derive(Debug, Clone)]
pub struct NmTrace {
    pub evaluations: Vec<(Vec<f64>, f64)>,
}

/// Options controlling the search.
#[derive(Debug, Clone)]
pub struct NmOptions {
    pub max_evals: usize,
    /// Convergence: simplex function-value spread below this stops.
    pub tol: f64,
    /// Box bounds per dimension.
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

/// Maximize `f` from `start` with reflection/expansion/contraction/
/// shrink (standard coefficients α=1, γ=2, ρ=0.5, σ=0.5). Returns
/// (best_x, best_f, trace). Every objective evaluation is recorded —
/// for the NMT baseline each evaluation is a (costly) sample transfer,
/// so the trace length is the baseline's sampling overhead.
pub fn maximize(
    f: &mut dyn FnMut(&[f64]) -> f64,
    start: &[f64],
    opts: &NmOptions,
) -> (Vec<f64>, f64, NmTrace) {
    let n = start.len();
    assert!(n >= 1);
    assert_eq!(opts.lo.len(), n);
    assert_eq!(opts.hi.len(), n);
    let clamp = |x: &mut Vec<f64>| {
        for d in 0..n {
            x[d] = x[d].clamp(opts.lo[d], opts.hi[d]);
        }
    };
    let mut trace = NmTrace { evaluations: Vec::new() };
    let mut evals = 0usize;
    let mut eval = |x: &[f64], trace: &mut NmTrace, evals: &mut usize| -> f64 {
        *evals += 1;
        let v = f(x);
        trace.evaluations.push((x.to_vec(), v));
        v
    };

    // Initial simplex: start + per-axis offsets of 20% of the box.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let mut x0 = start.to_vec();
    clamp(&mut x0);
    let v0 = eval(&x0, &mut trace, &mut evals);
    simplex.push((x0.clone(), v0));
    for d in 0..n {
        let mut x = x0.clone();
        let step = 0.2 * (opts.hi[d] - opts.lo[d]).max(1.0);
        x[d] = if x[d] + step <= opts.hi[d] { x[d] + step } else { x[d] - step };
        clamp(&mut x);
        let v = eval(&x, &mut trace, &mut evals);
        simplex.push((x, v));
    }

    while evals < opts.max_evals {
        // Sort descending by value (maximization).
        simplex.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let spread = simplex[0].1 - simplex[n].1;
        if spread.abs() < opts.tol {
            break;
        }
        // Centroid of all but the worst.
        let mut centroid = vec![0.0; n];
        for (x, _) in &simplex[..n] {
            for d in 0..n {
                centroid[d] += x[d] / n as f64;
            }
        }
        let worst = simplex[n].clone();
        // Reflection.
        let mut xr: Vec<f64> = (0..n).map(|d| centroid[d] + (centroid[d] - worst.0[d])).collect();
        clamp(&mut xr);
        let vr = eval(&xr, &mut trace, &mut evals);
        if vr > simplex[0].1 {
            // Expansion.
            let mut xe: Vec<f64> =
                (0..n).map(|d| centroid[d] + 2.0 * (centroid[d] - worst.0[d])).collect();
            clamp(&mut xe);
            let ve = eval(&xe, &mut trace, &mut evals);
            simplex[n] = if ve > vr { (xe, ve) } else { (xr, vr) };
        } else if vr > simplex[n - 1].1 {
            simplex[n] = (xr, vr);
        } else {
            // Contraction (toward centroid).
            let mut xc: Vec<f64> =
                (0..n).map(|d| centroid[d] + 0.5 * (worst.0[d] - centroid[d])).collect();
            clamp(&mut xc);
            let vc = eval(&xc, &mut trace, &mut evals);
            if vc > worst.1 {
                simplex[n] = (xc, vc);
            } else {
                // Shrink toward the best vertex.
                let best = simplex[0].0.clone();
                for vertex in simplex.iter_mut().skip(1) {
                    for d in 0..n {
                        vertex.0[d] = best[d] + 0.5 * (vertex.0[d] - best[d]);
                    }
                    clamp(&mut vertex.0);
                    vertex.1 = eval(&vertex.0, &mut trace, &mut evals);
                    if evals >= opts.max_evals {
                        break;
                    }
                }
            }
        }
    }
    simplex.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let (bx, bv) = simplex[0].clone();
    (bx, bv, trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(n: usize) -> NmOptions {
        NmOptions { max_evals: 400, tol: 1e-10, lo: vec![-10.0; n], hi: vec![10.0; n] }
    }

    #[test]
    fn maximizes_concave_quadratic() {
        let mut f = |x: &[f64]| -(x[0] - 2.0).powi(2) - (x[1] + 1.0).powi(2) + 5.0;
        let (x, v, _) = maximize(&mut f, &[0.0, 0.0], &opts(2));
        assert!((x[0] - 2.0).abs() < 1e-3, "x0={}", x[0]);
        assert!((x[1] + 1.0).abs() < 1e-3, "x1={}", x[1]);
        assert!((v - 5.0).abs() < 1e-5);
    }

    #[test]
    fn respects_bounds() {
        // Unbounded growth toward +∞ must be stopped at the box edge.
        let mut f = |x: &[f64]| x[0];
        let o = NmOptions { max_evals: 200, tol: 1e-12, lo: vec![0.0], hi: vec![3.0] };
        let (x, v, trace) = maximize(&mut f, &[1.0], &o);
        assert!((x[0] - 3.0).abs() < 1e-6);
        assert!((v - 3.0).abs() < 1e-6);
        for (pt, _) in &trace.evaluations {
            assert!(pt[0] >= 0.0 && pt[0] <= 3.0, "out-of-box eval at {}", pt[0]);
        }
    }

    #[test]
    fn eval_budget_is_respected() {
        let mut count = 0usize;
        let mut f = |x: &[f64]| {
            count += 1;
            (x[0] * 0.1).sin() + (x[1] * 0.07).cos()
        };
        let o = NmOptions { max_evals: 25, tol: 0.0, lo: vec![-10.0; 2], hi: vec![10.0; 2] };
        let (_, _, trace) = maximize(&mut f, &[0.0, 0.0], &o);
        assert!(count <= 25 + 2, "count={count}"); // shrink may finish its sweep
        assert_eq!(count, trace.evaluations.len());
    }

    #[test]
    fn trace_is_monotone_enough_to_converge() {
        let mut f = |x: &[f64]| -(x[0].powi(2) + x[1].powi(2) + x[2].powi(2));
        let (x, _, trace) = maximize(&mut f, &[5.0, -4.0, 3.0], &opts(3));
        assert!(x.iter().all(|c| c.abs() < 0.05), "{x:?}");
        // The best value seen must improve over the run.
        let first = trace.evaluations[0].1;
        let best = trace.evaluations.iter().map(|e| e.1).fold(f64::MIN, f64::max);
        assert!(best > first);
    }
}
