//! dtopt CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is unreachable offline):
//!   testbed                      print Table 1
//!   gen-logs   --testbed T --days N --out DIR [--seed S] [--rate R]
//!   offline    --logs DIR --out KB.json [--backend native|pjrt|auto]
//!   transfer   --testbed T --files N --avg-mb M [--optimizer O]
//!              [--kb KB.json] [--load L] [--seed S]
//!   serve      [--requests N] [--workers W] [--optimizer O] [--fabric]
//!              [--metrics-out F]
//!   experiment fig1|fig2|fig3a|fig3b|fig5|fig6|fig7|live|fleet|rush|convoy|stampede|ingest|all
//!              [--quick|--full] [--metrics-out F]
//!   logs       compact DIR        rewrite JSONL partitions as columnar
//!              `.dtc` (idempotent; originals removed only after a
//!              verified re-read)
//!   scenario   <name|file> [--seed S] [--full] [--timeline] [--alerts] [--json]
//!              [--list] [--metrics-out F]
//!              deterministic fault-injecting replay + invariant verdict
//!   trace      <name|file> [--request N] [--json] [--seed S] [--full]
//!              [--metrics-out F]
//!              per-request decision-provenance traces for one replay
//!   obs        [--scenario NAME|FILE] [--seed S] [--prom|--json|--alerts|--recent N]
//!              fleet health plane: registry export, flight recorder,
//!              ledger, sentry alert timeline
//!   selftest                     quick end-to-end sanity run
//!
//! `--metrics-out F` writes the run's unified registry snapshot to F:
//! Prometheus text when F ends in `.prom`, compact JSON otherwise.
//! Scenario exports are deterministic (same seed → byte-identical;
//! CI's obs-conformance job diffs two runs).

use anyhow::{bail, Context, Result};
use dtopt::coordinator::{Coordinator, CoordinatorConfig, OptimizerKind, TransferRequest};
use dtopt::experiments::common::{default_backend, ExpConfig, World};
use dtopt::experiments::{convoy, fig12, fig3, fig5, fig6, fig7, fleet, ingest, live, rush, stampede};
use dtopt::probe::ProbePlane;
use dtopt::logs::generate::{generate, GenConfig};
use dtopt::logs::store::{LogStore, StoreFormat};
use dtopt::offline::pipeline::{build, OfflineConfig};
use dtopt::sim::dataset::Dataset;
use dtopt::sim::testbed::{Testbed, TestbedId};
use dtopt::sim::traffic::Contention;
use dtopt::sim::transfer::NetState;
use dtopt::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` and `--flag` style options.
struct Opts {
    values: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

fn parse_opts(args: &[String]) -> Opts {
    let mut values = HashMap::new();
    let mut flags = Vec::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                values.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Opts { values, flags, positional }
}

impl Opts {
    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number")),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let opts = parse_opts(&args[1..]);
    match cmd.as_str() {
        "testbed" => {
            print!("{}", Testbed::table1());
            Ok(())
        }
        "gen-logs" => cmd_gen_logs(&opts),
        "offline" => cmd_offline(&opts),
        "transfer" => cmd_transfer(&opts),
        "serve" => cmd_serve(&opts),
        "experiment" => cmd_experiment(&opts),
        "logs" => cmd_logs(&opts),
        "scenario" => cmd_scenario(&opts),
        "trace" => cmd_trace(&opts),
        "obs" => cmd_obs(&opts),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `dtopt help`)"),
    }
}

fn print_help() {
    println!(
        "dtopt — data transfer optimization via offline knowledge discovery\n\
         and adaptive real-time sampling (Nine et al., 2017 reproduction)\n\n\
         commands:\n  \
         testbed                              print Table 1\n  \
         gen-logs --testbed T --days N --out DIR [--rate R] [--seed S]\n  \
         offline --logs DIR --out KB.json [--backend native|pjrt|auto]\n  \
         transfer --testbed T --files N --avg-mb M [--optimizer O] [--kb F] [--load L]\n  \
         serve [--requests N] [--workers W] [--optimizer O] [--fabric] [--metrics-out F]\n  \
         experiment fig1|fig2|fig3a|fig3b|fig5|fig6|fig7|live|fleet|rush|convoy|stampede|ingest|all [--quick|--full] [--metrics-out F]\n  \
         logs compact <dir>                   rewrite JSONL partitions as columnar .dtc (idempotent)\n  \
         scenario <name|file> [--seed S] [--full] [--timeline] [--alerts] [--json] [--metrics-out F] (--list prints bundled names)\n  \
         trace <name|file> [--request N] [--json] [--seed S] [--full] [--metrics-out F]\n  \
         obs [--scenario NAME|FILE] [--seed S] [--prom|--json|--alerts|--recent N]\n  \
         selftest"
    );
}

fn parse_testbed(opts: &Opts) -> Result<TestbedId> {
    let name = opts.get("testbed").unwrap_or("xsede");
    TestbedId::parse(name).with_context(|| format!("unknown testbed '{name}'"))
}

fn cmd_gen_logs(opts: &Opts) -> Result<()> {
    let testbed = Testbed::by_id(parse_testbed(opts)?);
    let days = opts.get_u64("days", 7)?;
    let rate = opts.get_f64("rate", 40.0)?;
    let seed = opts.get_u64("seed", 0xC0FFEE)?;
    let out = opts.get("out").context("--out DIR required")?;
    let rows = generate(
        &testbed,
        &GenConfig { days, arrivals_per_hour: rate, start_day: 0, seed },
    );
    let store = LogStore::open(out)?;
    store.append(&rows)?;
    println!("wrote {} log rows across {} day partitions to {}", rows.len(), days, out);
    Ok(())
}

fn cmd_offline(opts: &Opts) -> Result<()> {
    let logs_dir = opts.get("logs").context("--logs DIR required")?;
    let out = opts.get("out").unwrap_or("kb.json");
    let store = LogStore::open(logs_dir)?;
    let rows = store.read_all()?;
    anyhow::ensure!(!rows.is_empty(), "no log rows in {logs_dir}");
    let mut backend = match opts.get("backend").unwrap_or("auto") {
        "native" => dtopt::runtime::Backend::Native,
        "pjrt" => dtopt::runtime::Backend::pjrt(std::path::Path::new("artifacts"))?,
        _ => default_backend(),
    };
    let start = std::time::Instant::now();
    let kb = backend.with_assign(|assign| build(&rows, &OfflineConfig::default(), assign))?;
    let elapsed = start.elapsed();
    kb.save(std::path::Path::new(out))?;
    println!(
        "offline analysis ({} backend): {} rows → {} clusters, {} surfaces in {:.2?}; saved {out}",
        backend.name(),
        rows.len(),
        kb.clusters.len(),
        kb.clusters.iter().map(|c| c.surfaces.len()).sum::<usize>(),
        elapsed
    );
    for (k, score) in &kb.k_scores {
        println!("  CH(k={k}) = {score:.1}");
    }
    Ok(())
}

fn cmd_transfer(opts: &Opts) -> Result<()> {
    let testbed_id = parse_testbed(opts)?;
    let testbed = Testbed::by_id(testbed_id);
    let files = opts.get_u64("files", 100)?;
    let avg_mb = opts.get_f64("avg-mb", 64.0)?;
    let seed = opts.get_u64("seed", 7)?;
    let load = opts.get_f64("load", 0.3)?;
    let optimizer = match opts.get("optimizer") {
        None => OptimizerKind::Asm,
        Some(o) => OptimizerKind::parse(o).with_context(|| format!("unknown optimizer '{o}'"))?,
    };
    // Knowledge base: load from --kb, else build from a quick history.
    let kb = match opts.get("kb") {
        Some(path) => dtopt::offline::knowledge::KnowledgeBase::load(std::path::Path::new(path))?,
        None => {
            eprintln!("note: no --kb given; building a quick in-memory history first");
            let rows = generate(
                &testbed,
                &GenConfig { days: 5, arrivals_per_hour: 30.0, start_day: 0, seed: seed ^ 1 },
            );
            build(&rows, &OfflineConfig::default(), &mut dtopt::offline::kmeans::NativeAssign)?
        }
    };
    let history = generate(
        &testbed,
        &GenConfig { days: 3, arrivals_per_hour: 20.0, start_day: 0, seed: seed ^ 2 },
    );
    let coord = Coordinator::new(
        Arc::new(kb),
        Arc::new(history),
        CoordinatorConfig {
            workers: 1,
            default_optimizer: optimizer,
            seed,
            probe: None,
            faults: None,
            tap: None,
            links: None,
            traces: None,
        },
    );
    let mut rng = Rng::new(seed);
    let contention = Contention::sample(&mut rng, testbed.path.link.bandwidth_mbps, load);
    let request = TransferRequest {
        id: coord.fresh_id(),
        testbed: testbed_id,
        dataset: Dataset::new(files, avg_mb),
        t_submit: 0.0,
        state_override: Some(NetState { external_load: load, contention }),
        optimizer: Some(optimizer),
        seed,
    };
    let response = &coord.run_batch(vec![request])[0];
    let r = &response.report;
    println!(
        "{}: {:.0} MB in {:.1}s → {:.0} Mbps end-to-end (steady {:.0}, optimal {:.0}, {} samples, θ = {})",
        r.optimizer,
        r.total_mb(),
        r.total_s(),
        r.achieved_mbps(),
        r.final_steady_mbps(),
        response.optimal_mbps,
        r.sample_transfers(),
        r.final_params,
    );
    for (i, phase) in r.phases.iter().enumerate() {
        println!(
            "  phase {i}: {} {:>9.1} MB {:>7.2}s steady {:>6.0} Mbps {}",
            if phase.is_sample { "sample" } else { "bulk  " },
            phase.mb,
            phase.seconds,
            phase.steady_mbps,
            phase.params
        );
    }
    coord.shutdown();
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<()> {
    use std::time::Duration;

    let n = opts.get_u64("requests", 24)? as usize;
    let workers = opts.get_u64("workers", 4)? as usize;
    let optimizer = match opts.get("optimizer") {
        None => None,
        Some(o) => Some(OptimizerKind::parse(o).with_context(|| format!("unknown '{o}'"))?),
    };
    let mut backend = default_backend();
    let world = World::prepare(ExpConfig::quick(), &mut backend);
    let scratch = std::env::temp_dir().join(format!("dtopt_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    // ASM requests share the probe plane in both modes: coalesced
    // sampling ladders, decaying per-shard estimates, probe budgets.
    let plane = Arc::new(ProbePlane::default());
    // Transfers on one network share its link: concurrent requests see
    // each other's occupancy and fair-share the stream budget instead
    // of each being scored against a private-testbed fiction.
    let links = Arc::new(dtopt::netplane::LinkPlane::shared());
    // --fabric serves through the sharded knowledge fabric (per-network
    // shards cold-started from the global KB) instead of one global
    // snapshot slot; the metrics block then includes the shard table.
    // Without it, a global feedback service ingests completed transfers
    // so the closed loop runs (and drains) in both modes.
    let fabric = if opts.has("fabric") {
        Some(Arc::new(dtopt::fabric::ShardRouter::open(
            &scratch.join("fabric"),
            world.kb.clone(),
            dtopt::fabric::FabricConfig::default(),
        )?))
    } else {
        None
    };
    let service = if fabric.is_none() {
        Some(dtopt::feedback::FeedbackService::start(
            world.kb.clone(),
            dtopt::logs::store::LogStore::open(scratch.join("logs"))?,
            dtopt::feedback::FeedbackConfig::default(),
        )?)
    } else {
        None
    };
    // The fabric's lifecycle driver: sweeps every shard's refresh
    // policy in the background while requests are served, so borrowed
    // shards can fit natively mid-run (the fabric counterpart of the
    // feedback service's background refresher).
    let pollster = fabric.as_ref().map(|router| {
        dtopt::fabric::FabricPollster::spawn(router.clone(), Duration::from_millis(50))
    });
    let coordinator_config = CoordinatorConfig {
        workers,
        default_optimizer: OptimizerKind::Asm,
        seed: world.config.seed,
        probe: Some(plane),
        faults: None,
        tap: None,
        links: Some(links),
        traces: None,
    };
    let coord = match (&fabric, &service) {
        (Some(router), _) => {
            Coordinator::with_fabric(router.clone(), world.rows.clone(), coordinator_config)
        }
        (None, Some(service)) => {
            Coordinator::with_feedback(service, world.rows.clone(), coordinator_config)
        }
        (None, None) => unreachable!("one knowledge source is always wired"),
    };
    let mut rng = Rng::new(world.config.seed);
    let requests: Vec<TransferRequest> = (0..n)
        .map(|i| {
            let tb = TestbedId::all()[rng.index(3)];
            let class = dtopt::sim::dataset::SizeClass::all()[rng.index(3)];
            TransferRequest {
                id: coord.fresh_id(),
                testbed: tb,
                dataset: Dataset::sample(class, &mut rng),
                t_submit: (world.config.history_days + 1) as f64 * 86_400.0
                    + rng.range_f64(0.0, 86_400.0),
                state_override: None,
                optimizer,
                seed: 5_000 + i as u64,
            }
        })
        .collect();
    let start = std::time::Instant::now();
    let responses = coord.run_batch(requests);
    let wall = start.elapsed();
    println!(
        "served {} requests on {} workers in {wall:.2?} ({:.1} req/s wall)\n",
        responses.len(),
        workers,
        responses.len() as f64 / wall.as_secs_f64()
    );

    // --- Graceful shutdown: stop accepting work, drain every ingest
    // queue so rows accepted before shutdown reach their partitions,
    // fold them into the knowledge source, then render the final state.
    let metrics = coord.metrics.clone();
    coord.shutdown();
    let drained = match (&fabric, &service) {
        (Some(router), _) => {
            let drained = router.flush_all(Duration::from_secs(30));
            let _ = router.tick_all();
            drained
        }
        (_, Some(service)) => {
            let drained = service.flush_barrier(Duration::from_secs(30));
            let _ = service.tick();
            drained
        }
        _ => true,
    };
    let flushed = match (&fabric, &service) {
        (Some(router), _) => router
            .live_shards()
            .iter()
            .map(|s| s.stats.rows_flushed.load(std::sync::atomic::Ordering::Relaxed))
            .sum::<u64>(),
        (_, Some(service)) => {
            service.stats.rows_flushed.load(std::sync::atomic::Ordering::Relaxed)
        }
        _ => 0,
    };
    println!(
        "graceful shutdown: ingest queues {} ({flushed} rows flushed to partitions)\n",
        if drained { "drained" } else { "DRAIN TIMED OUT" }
    );
    print!("{}", metrics.render());
    if let Some(path) = opts.get("metrics-out") {
        write_metrics_out(path, &metrics.export_snapshot())?;
    }
    if let Some(pollster) = pollster {
        pollster.stop();
    }
    if let Some(router) = fabric {
        router.shutdown();
    }
    if let Some(service) = service {
        service.shutdown();
    }
    let _ = std::fs::remove_dir_all(&scratch);
    Ok(())
}

/// Every experiment the CLI can regenerate (`all` runs them in order).
const EXPERIMENT_NAMES: [&str; 13] = [
    "fig1", "fig2", "fig3a", "fig3b", "fig5", "fig6", "fig7", "live", "fleet", "rush", "convoy",
    "stampede", "ingest",
];

fn cmd_experiment(opts: &Opts) -> Result<()> {
    let Some(which) = opts.positional.first().map(|s| s.as_str()) else {
        bail!(
            "experiment name required; available: {}|all",
            EXPERIMENT_NAMES.join("|")
        );
    };
    let config = if opts.has("full") { ExpConfig::full() } else { ExpConfig::quick() };
    let reps = if opts.has("full") { 4 } else { 2 };
    let needs_world_list =
        ["fig5", "fig6", "fig7", "live", "fleet", "rush", "convoy", "stampede", "all"];
    let needs_world = needs_world_list.contains(&which);
    let world = if needs_world {
        let mut backend = default_backend();
        eprintln!("preparing world ({} backend)...", backend.name());
        Some(World::prepare(config, &mut backend))
    } else {
        None
    };
    // Harness-level health registry: every experiment's headline
    // checks land as ok/miss counters so `--metrics-out` captures a
    // machine-readable pass/fail tally alongside the rendered tables.
    let registry = dtopt::telemetry::Registry::new();
    let tally = |name: &str, checks: Vec<(String, bool)>| -> Result<()> {
        let ok = registry.counter(&format!("experiment.{name}.headline_ok"))?;
        let miss = registry.counter(&format!("experiment.{name}.headline_miss"))?;
        for (desc, passed) in checks {
            println!("[{}] {desc}", if passed { "ok" } else { "MISS" });
            if passed {
                ok.inc();
            } else {
                miss.inc();
            }
        }
        Ok(())
    };
    let run_one = |name: &str, world: Option<&World>| -> Result<()> {
        match name {
            "fig1" => print!("{}", fig12::run_fig1(reps, 11)),
            "fig2" => print!("{}", fig12::run_fig2(reps, 12)),
            "fig3a" => print!("{}", fig3::render_3a(&fig3::run_3a(300, 13))),
            "fig3b" => {
                let r = fig3::run_3b(reps, 128, 14);
                print!("{}", fig3::render_3b(&r));
                tally("fig3b", fig3::headline_checks_3b(&r))?;
            }
            "fig5" => {
                let r = fig5::run(world.unwrap(), 4);
                print!("{}", fig5::render(&r));
                tally("fig5", fig5::headline_checks(&r))?;
            }
            "fig6" => {
                let r = fig6::run(world.unwrap());
                print!("{}", fig6::render(&r));
                tally("fig6", fig6::headline_checks(&r))?;
            }
            "fig7" => {
                let eval_days = if opts.has("full") { 20 } else { 6 };
                let periods: &[u64] = if opts.has("full") { &[1, 2, 5, 10] } else { &[1, 3] };
                let r = fig7::run(world.unwrap(), eval_days, periods);
                print!("{}", fig7::render(&r));
                tally("fig7", fig7::headline_checks(&r))?;
            }
            "live" => {
                let eval_days = if opts.has("full") { 12 } else { 4 };
                let dir = std::env::temp_dir()
                    .join(format!("dtopt_live_exp_{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                let r = live::run(world.unwrap(), eval_days, &dir)?;
                let _ = std::fs::remove_dir_all(&dir);
                print!("{}", live::render(&r));
                tally("live", live::headline_checks(&r))?;
            }
            "rush" => {
                let (burst, workers) = if opts.has("full") { (64, 8) } else { (24, 6) };
                let r = rush::run(world.unwrap(), burst, workers);
                print!("{}", rush::render(&r));
                tally("rush", rush::headline_checks(&r))?;
            }
            "convoy" => {
                let (cohort, workers) = if opts.has("full") { (32, 8) } else { (16, 6) };
                let r = convoy::run(world.unwrap(), cohort, workers);
                print!("{}", convoy::render(&r));
                tally("convoy", convoy::headline_checks(&r))?;
            }
            "stampede" => {
                // Full mode clears the 10^5-request bar across the
                // sweep (6 points x 17k); quick keeps CI smoke fast.
                let per_point = if opts.has("full") { 17_000 } else { 200 };
                let r = stampede::run(world.unwrap(), per_point);
                print!("{}", stampede::render(&r));
                tally("stampede", stampede::headline_checks(&r))?;
            }
            "ingest" => {
                let dir = std::env::temp_dir()
                    .join(format!("dtopt_ingest_exp_{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                let r = ingest::run(!opts.has("full"), &dir)?;
                let _ = std::fs::remove_dir_all(&dir);
                print!("{}", ingest::render(&r));
                tally("ingest", ingest::headline_checks(&r))?;
            }
            "fleet" => {
                let eval_days = if opts.has("full") { 8 } else { 3 };
                let dir = std::env::temp_dir()
                    .join(format!("dtopt_fleet_exp_{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                let r = fleet::run(world.unwrap(), eval_days, &dir)?;
                let _ = std::fs::remove_dir_all(&dir);
                print!("{}", fleet::render(&r));
                tally("fleet", fleet::headline_checks(&r))?;
            }
            other => bail!(
                "unknown experiment '{other}'; available: {}|all",
                EXPERIMENT_NAMES.join("|")
            ),
        }
        Ok(())
    };
    if which == "all" {
        for name in EXPERIMENT_NAMES {
            println!("==================== {name} ====================");
            run_one(name, world.as_ref())?;
        }
    } else {
        run_one(which, world.as_ref())?;
    }
    if let Some(path) = opts.get("metrics-out") {
        write_metrics_out(path, &registry.snapshot())?;
    }
    Ok(())
}

/// Log-store maintenance. `logs compact <dir>` rewrites every JSONL
/// partition as a columnar `.dtc` twin and removes the original only
/// after a verified row-for-row re-read (`LogStore::compact`); a
/// directory already fully columnar is a no-op, so the command is
/// idempotent and crash-safe to re-run. Bad paths and unknown actions
/// exit non-zero via the error path.
fn cmd_logs(opts: &Opts) -> Result<()> {
    const USAGE: &str = "usage: dtopt logs compact <dir>";
    let Some(action) = opts.positional.first().map(|s| s.as_str()) else {
        bail!("logs action required; {USAGE}");
    };
    anyhow::ensure!(action == "compact", "unknown logs action '{action}'; {USAGE}");
    let Some(dir) = opts.positional.get(1).map(|s| s.as_str()) else {
        bail!("logs compact needs a log directory; {USAGE}");
    };
    anyhow::ensure!(opts.positional.len() == 2, "logs compact takes one directory; {USAGE}");
    let path = std::path::Path::new(dir);
    // Validate before open: LogStore::open would create a missing
    // directory, silently "compacting" a typo to an empty store.
    anyhow::ensure!(path.is_dir(), "no such log directory: {dir}");
    let store = LogStore::open_with_format(path, StoreFormat::Columnar)?;
    let report = store.compact()?;
    let rows: usize = store
        .days()?
        .iter()
        .map(|&d| store.row_count(d))
        .collect::<Result<Vec<_>>>()?
        .iter()
        .sum();
    println!(
        "compacted {dir}: {} partition(s) migrated to columnar, {} already columnar, {} row(s) total",
        report.migrated.len(),
        report.already_columnar.len(),
        rows
    );
    Ok(())
}

/// Run one scenario by bundled name or fixture-file path. Exits
/// non-zero (via the error path) on an unknown/missing name AND on any
/// invariant violation, so CI and scripts can gate on it.
fn cmd_scenario(opts: &Opts) -> Result<()> {
    use dtopt::scenario::{render_timeline, render_verdict, run, timeline_to_json};
    use dtopt::telemetry::{alerts_to_json, render_alerts};

    // `dtopt scenario --list` prints the bundled library (one name per
    // line, exit 0) for scripts; a missing name still exits non-zero
    // with the list on stderr, matching `dtopt experiment`'s behavior.
    if opts.has("list") {
        for name in dtopt::scenario::script::bundled_names() {
            println!("{name}");
        }
        return Ok(());
    }
    let scenario = resolve_scenario(opts)?;
    let outcome = run(&scenario, &run_options(opts)?)?;
    if opts.has("timeline") {
        if opts.has("json") {
            println!("{}", timeline_to_json(&outcome.timeline).to_string_compact());
        } else {
            print!("{}", render_timeline(&outcome.timeline));
            println!();
        }
    }
    // The sentry's raise/clear timeline, in scenario seconds. The JSON
    // form is what CI's alert-conformance job byte-diffs across two
    // same-seed runs, and what the alert goldens are built from.
    if opts.has("alerts") {
        if opts.has("json") {
            println!("{}", alerts_to_json(&outcome.alerts).to_string_compact());
        } else {
            print!("{}", render_alerts(&outcome.alerts));
        }
    }
    print!("{}", render_verdict(&outcome));
    // Written before the pass/fail gate so a violating run still
    // leaves its export behind for postmortems.
    if let Some(path) = opts.get("metrics-out") {
        write_metrics_out(path, &outcome.metrics.export_snapshot())?;
    }
    let violations: usize = outcome.reports.iter().map(|r| r.violations.len()).sum();
    anyhow::ensure!(
        outcome.passed(),
        "scenario '{}' violated {violations} invariant check(s)",
        outcome.name
    );
    Ok(())
}

/// Resolve the first positional argument to a parsed scenario: bundled
/// name first, then fixture-file path. Shared by `scenario` and
/// `trace` so both report the same errors (and exit codes) for missing
/// or unknown names.
fn resolve_scenario(opts: &Opts) -> Result<dtopt::scenario::Scenario> {
    let names = dtopt::scenario::script::bundled_names().join("|");
    let Some(which) = opts.positional.first().map(|s| s.as_str()) else {
        bail!("scenario name or file required; bundled: {names}");
    };
    resolve_scenario_name(which)
}

/// Bundled name first, then fixture-file path (shared with `obs`,
/// which names its scenario via `--scenario` instead of a positional).
fn resolve_scenario_name(which: &str) -> Result<dtopt::scenario::Scenario> {
    use dtopt::scenario::Scenario;

    match dtopt::scenario::script::bundled(which) {
        Some(text) => Scenario::parse(text)
            .with_context(|| format!("bundled scenario '{which}' failed to parse")),
        None => {
            let path = std::path::Path::new(which);
            if !path.is_file() {
                let names = dtopt::scenario::script::bundled_names().join("|");
                bail!("unknown scenario '{which}' and no such file; bundled: {names}");
            }
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading scenario file '{which}'"))?;
            Scenario::parse(&text)
                .with_context(|| format!("scenario file '{which}' failed to parse"))
        }
    }
}

/// Write one export of `snap` to `path`: Prometheus text when the path
/// ends in `.prom`, compact JSON otherwise. Backs `--metrics-out` on
/// scenario/serve/experiment runs; scenario exports are deterministic,
/// which CI's obs-conformance job enforces by diffing two same-seed
/// runs byte-for-byte.
fn write_metrics_out(path: &str, snap: &dtopt::telemetry::Snapshot) -> Result<()> {
    use dtopt::telemetry::export;

    let body = if path.ends_with(".prom") {
        export::to_prometheus(snap)
    } else {
        let mut text = export::to_json(snap).to_string_compact();
        text.push('\n');
        text
    };
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, &body).with_context(|| format!("writing --metrics-out {path}"))?;
    eprintln!("wrote {} metric families to {path}", snap.len());
    Ok(())
}

/// Fleet health plane viewer: replay one bundled scenario (default
/// `flash-crowd`) and print the unified registry's export — Prometheus
/// text (default / `--prom`), compact JSON (`--json`), or the flight
/// recorder's last N flights plus the accuracy ledger (`--recent N`).
/// Same seed → byte-identical output; no wall-clock family ever enters
/// an export (DESIGN.md §Fleet health plane, determinism contract).
fn cmd_obs(opts: &Opts) -> Result<()> {
    use dtopt::telemetry::export;

    // The shared parser swallows unknown `--flags` silently; obs
    // validates strictly so a typo exits non-zero instead of quietly
    // printing the default export.
    const USAGE: &str = "obs takes [--scenario NAME|FILE] [--seed S] [--full] and one of \
         [--prom|--json|--alerts|--recent N] (--alerts --json for machine-readable alerts)";
    for key in opts.values.keys() {
        anyhow::ensure!(
            matches!(key.as_str(), "scenario" | "seed" | "recent"),
            "unknown option '--{key} <value>'; {USAGE}"
        );
    }
    for flag in &opts.flags {
        anyhow::ensure!(flag != "recent", "--recent expects a count; {USAGE}");
        anyhow::ensure!(
            matches!(flag.as_str(), "prom" | "json" | "full" | "alerts"),
            "unknown flag '--{flag}'; {USAGE}"
        );
    }
    anyhow::ensure!(opts.positional.is_empty(), "obs takes no positional arguments; {USAGE}");
    let scenario = resolve_scenario_name(opts.get("scenario").unwrap_or("flash-crowd"))?;
    let outcome = dtopt::scenario::run(&scenario, &run_options(opts)?)?;
    if let Some(n) = opts.get("recent") {
        let n: usize = n.parse().context("--recent expects a count")?;
        // The recorder is a bounded ring: asking past its capacity is
        // reported, never silently truncated to the ring size.
        let capacity = outcome.metrics.recorder.capacity();
        if n > capacity {
            eprintln!(
                "note: --recent {n} exceeds the flight recorder's capacity of {capacity} \
                 flights; showing the newest {capacity}"
            );
        }
        print!("{}", outcome.metrics.recorder.render_recent(n));
        print!("{}", outcome.metrics.ledger.render());
    } else if opts.has("alerts") {
        if opts.has("json") {
            println!(
                "{}",
                dtopt::telemetry::alerts_to_json(&outcome.alerts).to_string_compact()
            );
        } else {
            print!("{}", dtopt::telemetry::render_alerts(&outcome.alerts));
        }
    } else if opts.has("json") {
        println!("{}", export::to_json(&outcome.metrics.export_snapshot()).to_string_compact());
    } else {
        print!("{}", export::to_prometheus(&outcome.metrics.export_snapshot()));
    }
    Ok(())
}

fn run_options(opts: &Opts) -> Result<dtopt::scenario::RunOptions> {
    Ok(dtopt::scenario::RunOptions {
        quick: !opts.has("full"),
        seed_override: opts.get("seed").map(|s| s.parse::<u64>()).transpose()
            .context("--seed expects an integer")?,
    })
}

/// Replay one scenario and print the decision-provenance trace of every
/// served request (or one request via `--request N`, a 0-based index
/// into the id-sorted traces). `--json` emits the same machine-readable
/// form the trace goldens are built from; both forms are byte-identical
/// across same-seed runs.
fn cmd_trace(opts: &Opts) -> Result<()> {
    use dtopt::scenario::run;
    use dtopt::telemetry::traces_to_json;

    // Strict validation, matching `obs`: a typo exits non-zero instead
    // of silently replaying with the option ignored.
    const USAGE: &str =
        "trace takes <name|file> [--request N] [--json] [--seed S] [--full] [--metrics-out F]";
    for key in opts.values.keys() {
        anyhow::ensure!(
            matches!(key.as_str(), "request" | "seed" | "metrics-out"),
            "unknown option '--{key} <value>'; {USAGE}"
        );
    }
    for flag in &opts.flags {
        anyhow::ensure!(
            matches!(flag.as_str(), "json" | "full"),
            "unknown flag '--{flag}'; {USAGE}"
        );
    }
    anyhow::ensure!(
        opts.positional.len() <= 1,
        "trace takes one scenario name or file; {USAGE}"
    );
    let scenario = resolve_scenario(opts)?;
    let outcome = run(&scenario, &run_options(opts)?)?;
    let picked = match opts.get("request") {
        None => None,
        Some(v) => {
            let n: usize = v.parse().context("--request expects a 0-based index")?;
            anyhow::ensure!(
                n < outcome.traces.len(),
                "--request {n} out of range; scenario '{}' served {} request(s)",
                outcome.name,
                outcome.traces.len()
            );
            Some(n)
        }
    };
    if opts.has("json") {
        let json = match picked {
            Some(n) => outcome.traces[n].to_json(),
            None => traces_to_json(&outcome.traces),
        };
        println!("{}", json.to_string_compact());
    } else if let Some(n) = picked {
        print!("{}", outcome.traces[n].render_text());
    } else {
        for trace in &outcome.traces {
            print!("{}", trace.render_text());
        }
    }
    // Same export hook the scenario/serve/experiment commands have:
    // the replay's unified registry snapshot, `.prom` or JSON by
    // extension (see `write_metrics_out`).
    if let Some(path) = opts.get("metrics-out") {
        write_metrics_out(path, &outcome.metrics.export_snapshot())?;
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    println!("{}", Testbed::table1());
    let mut backend = default_backend();
    println!("backend: {}", backend.name());
    let world = World::prepare(ExpConfig::quick(), &mut backend);
    println!(
        "history: {} rows → {} clusters",
        world.rows.len(),
        world.kb.clusters.len()
    );
    let coord = world.coordinator(2);
    let req = TransferRequest {
        id: coord.fresh_id(),
        testbed: TestbedId::Xsede,
        dataset: Dataset::new(100, 64.0),
        t_submit: 6.5 * 86_400.0,
        state_override: None,
        optimizer: Some(OptimizerKind::Asm),
        seed: 1,
    };
    let resp = &coord.run_batch(vec![req])[0];
    println!(
        "ASM selftest: {:.0} Mbps achieved vs {:.0} optimal ({} samples)",
        resp.report.achieved_mbps(),
        resp.optimal_mbps,
        resp.report.sample_transfers()
    );
    coord.shutdown();
    println!("selftest OK");
    Ok(())
}
