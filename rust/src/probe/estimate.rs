//! Decaying per-shard network-state estimates.
//!
//! The knowledge base describes a network's *long-run* behavior; the
//! estimate store remembers what the most recent transfers learned
//! about its state *right now*: the surface index the sampling ladder
//! (or the drift monitor) last settled on and that surface's load
//! intensity. An estimate's confidence decays on a freshness half-life
//! — "the obtained information is *partial* and the network is
//! *dynamic*" — so a stale observation gracefully stops short-circuiting
//! the ladder instead of serving wrong parameters forever.
//!
//! Estimates are fed from three directions, in decreasing strength:
//! a sampling ladder the shard led (direct measurement), a completed
//! bulk transfer (the steady phase confirmed the surface), and a
//! mid-transfer drift re-tune (the monitor moved to a new surface
//! without fresh sampling).

use crate::fabric::ShardKey;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The link-occupancy observation an estimate was recorded under (and
/// a request is admitted under): the contention plane's join/leave
/// epoch plus the concurrent self-traffic streams (neighbors + any
/// ambient convoy) on the network at that moment. Zero everywhere when
/// no link plane is attached — which keeps the pre-plane behaviour
/// bit-for-bit (no penalty can ever fire on matching zero classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ProbeOcc {
    /// `netplane::LinkPlane` epoch at observation time.
    pub epoch: u64,
    /// Concurrent self-traffic streams on the link (neighbors + ambient).
    pub streams: u32,
}

impl ProbeOcc {
    /// Coarse busy class: 0 = quiet link, 1 = moderate self-traffic,
    /// 2 = heavy. An estimate learned in one class is demoted when
    /// served in another — a surface measured under a convoy is not
    /// quiet-network truth, and vice versa — while chunk-to-chunk
    /// jitter inside a class never churns confidence.
    pub fn class(&self) -> u8 {
        match self.streams {
            0 => 0,
            1..=16 => 1,
            _ => 2,
        }
    }
}

/// Estimate tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EstimateConfig {
    /// Confidence halves every `half_life` of wall time.
    pub half_life: Duration,
    /// Serve the estimate (skip sampling entirely) at or above this
    /// decayed confidence.
    pub serve_threshold: f64,
    /// Multiplier applied when the serving KB generation differs from
    /// the one the estimate was recorded under (the surface stack may
    /// have shifted under the index).
    pub generation_penalty: f64,
    /// Confidence of an estimate written by a led sampling ladder.
    pub lead_confidence: f64,
    /// Confidence when a led run never actually sampled (short-transfer
    /// fast path): the surface is an unmeasured guess, so this sits
    /// *below* `serve_threshold` by default — strong enough to
    /// warm-start later ladders, never strong enough to suppress their
    /// sampling. Bulk completions then reinforce it toward the
    /// threshold if the guess keeps holding up.
    pub lead_unsampled_confidence: f64,
    /// Confidence bump from a completed bulk transfer that confirmed
    /// the estimate (no drift re-tunes).
    pub bulk_bonus: f64,
    /// Confidence of an estimate re-pointed by a mid-transfer drift
    /// re-tune (the monitor's surface re-selection, not a fresh probe).
    pub drift_confidence: f64,
    /// Multiplier applied when the link's occupancy class at admission
    /// differs from the class the estimate was recorded under (see
    /// [`ProbeOcc::class`]): knowledge learned under heavy self-traffic
    /// must not be served as quiet-network truth. Sized so a
    /// full-confidence estimate drops below the serve threshold on a
    /// class change and re-leads (warm-started) instead — and so that
    /// even one cross-class bulk reinforcement (penalized base +
    /// `bulk_bonus`) still sits below the threshold; only repeated
    /// confirmations under the *new* class earn a serve.
    pub occupancy_penalty: f64,
}

impl Default for EstimateConfig {
    fn default() -> Self {
        EstimateConfig {
            half_life: Duration::from_secs(60),
            serve_threshold: 0.6,
            generation_penalty: 0.5,
            lead_confidence: 1.0,
            lead_unsampled_confidence: 0.5,
            bulk_bonus: 0.1,
            drift_confidence: 0.7,
            occupancy_penalty: 0.45,
        }
    }
}

/// One shard's current network-state estimate.
#[derive(Debug, Clone, Copy)]
pub struct NetworkEstimate {
    /// KB cluster whose surface stack `surface_idx` indexes — a surface
    /// index is meaningless in any other cluster, so lookups for a
    /// different cluster miss.
    pub cluster_idx: usize,
    /// Index into the cluster's ascending-intensity surface stack.
    pub surface_idx: usize,
    /// That surface's external-load intensity.
    pub intensity: f64,
    /// Confidence at `updated_at` (decays from there).
    pub confidence: f64,
    /// KB generation the index refers to.
    pub generation: u64,
    /// Link occupancy the observation was made under — recorded
    /// alongside cluster and generation so the serve path can tell
    /// "learned under a convoy" from "learned on a quiet link".
    pub occ: ProbeOcc,
    pub updated_at: Instant,
}

impl NetworkEstimate {
    /// Confidence as of now: exponential decay on the half-life, with
    /// the generation penalty applied when the serving KB has moved on.
    pub fn decayed(&self, config: &EstimateConfig, serving_generation: u64) -> f64 {
        let age = self.updated_at.elapsed().as_secs_f64();
        let half_life = config.half_life.as_secs_f64().max(1e-9);
        let mut confidence = self.confidence * 0.5_f64.powf(age / half_life);
        if serving_generation != self.generation {
            confidence *= config.generation_penalty;
        }
        confidence.clamp(0.0, 1.0)
    }

    /// Full serve-path confidence: [`Self::decayed`] with the
    /// occupancy penalty applied on top when the link's busy class has
    /// changed since the estimate was recorded. This is what admission
    /// compares against the serve threshold.
    pub fn decayed_for(
        &self,
        config: &EstimateConfig,
        serving_generation: u64,
        occ_now: ProbeOcc,
    ) -> f64 {
        let mut confidence = self.decayed(config, serving_generation);
        if occ_now.class() != self.occ.class() {
            confidence *= config.occupancy_penalty;
        }
        confidence.clamp(0.0, 1.0)
    }
}

/// Thread-safe map of per-shard estimates.
#[derive(Debug)]
pub struct EstimateStore {
    config: EstimateConfig,
    inner: Mutex<HashMap<ShardKey, NetworkEstimate>>,
}

impl EstimateStore {
    pub fn new(config: EstimateConfig) -> EstimateStore {
        EstimateStore { config, inner: Mutex::new(HashMap::new()) }
    }

    pub fn config(&self) -> &EstimateConfig {
        &self.config
    }

    /// The shard's estimate plus its decayed confidence under the
    /// serving generation and the link occupancy observed at admission;
    /// `None` when nothing has been observed yet or the stored estimate
    /// indexes a different cluster's surface stack.
    pub fn current(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        serving_generation: u64,
        occ_now: ProbeOcc,
    ) -> Option<(NetworkEstimate, f64)> {
        let map = self.inner.lock().expect("estimate store poisoned");
        map.get(&key)
            .filter(|e| e.cluster_idx == cluster_idx)
            .map(|e| (*e, e.decayed_for(&self.config, serving_generation, occ_now)))
    }

    /// The raw stored estimate for `key`, regardless of cluster or
    /// generation — an observation hook for harnesses (the scenario
    /// engine's invariant checkers peek before admission to verify the
    /// plane never serves a cluster- or generation-mismatched
    /// estimate). Request-path lookups go through [`Self::current`],
    /// which enforces the cluster guard.
    pub fn peek(&self, key: ShardKey) -> Option<NetworkEstimate> {
        self.inner.lock().expect("estimate store poisoned").get(&key).copied()
    }

    /// Record a fresh observation, ranked by evidence: re-recording the
    /// estimate the shard already holds (same cluster, surface, and
    /// generation) never *lowers* its confidence — weaker evidence for
    /// the same conclusion must not erase stronger evidence. An
    /// observation that re-points the estimate (different surface,
    /// cluster, or generation) is new information and replaces the old
    /// record outright, whatever its confidence.
    ///
    /// Inherited confidence is discounted across occupancy classes
    /// (`decayed_for` with the incoming `occ`): evidence gathered on a
    /// quiet link must not be laundered into full-confidence convoy
    /// truth through a merge, nor vice versa — the merged record is
    /// stamped with the *new* occupancy, so the old class's penalty is
    /// applied exactly once, here.
    pub fn record(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        surface_idx: usize,
        intensity: f64,
        confidence: f64,
        generation: u64,
        occ: ProbeOcc,
    ) {
        let mut map = self.inner.lock().expect("estimate store poisoned");
        let confidence = match map.get(&key) {
            Some(e)
                if e.cluster_idx == cluster_idx
                    && e.surface_idx == surface_idx
                    && e.generation == generation =>
            {
                confidence.max(e.decayed_for(&self.config, generation, occ))
            }
            _ => confidence,
        };
        map.insert(
            key,
            NetworkEstimate {
                cluster_idx,
                surface_idx,
                intensity,
                confidence: confidence.clamp(0.0, 1.0),
                generation,
                occ,
                updated_at: Instant::now(),
            },
        );
    }

    /// A completed bulk transfer confirmed the surface: bump the
    /// decayed confidence by the bulk bonus (capped at 1) and refresh
    /// the timestamp. Creates the estimate at bonus confidence when the
    /// shard had none (or held another cluster's estimate). The base
    /// confidence is discounted across occupancy classes (see
    /// [`Self::record`]): a convoy-time completion reinforcing a
    /// quiet-learned surface starts from the penalized confidence, so
    /// one bulk run can never promote cross-class knowledge straight
    /// past the serve threshold.
    pub fn reinforce(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        surface_idx: usize,
        intensity: f64,
        generation: u64,
        occ: ProbeOcc,
    ) {
        let mut map = self.inner.lock().expect("estimate store poisoned");
        let confidence = map
            .get(&key)
            .filter(|e| e.cluster_idx == cluster_idx)
            .map(|e| e.decayed_for(&self.config, generation, occ) + self.config.bulk_bonus)
            .unwrap_or(self.config.bulk_bonus)
            .clamp(0.0, 1.0);
        map.insert(
            key,
            NetworkEstimate {
                cluster_idx,
                surface_idx,
                intensity,
                confidence,
                generation,
                occ,
                updated_at: Instant::now(),
            },
        );
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("estimate store poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sorted snapshot for rendering.
    pub fn entries(&self) -> Vec<(ShardKey, NetworkEstimate)> {
        let map = self.inner.lock().expect("estimate store poisoned");
        let mut out: Vec<(ShardKey, NetworkEstimate)> =
            map.iter().map(|(k, e)| (*k, *e)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::TestbedId;

    fn key() -> ShardKey {
        ShardKey::new(TestbedId::Xsede, SizeClass::Large)
    }

    #[test]
    fn fresh_estimate_keeps_its_confidence() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        assert!(store.current(key(), 0, 0, ProbeOcc::default()).is_none());
        store.record(key(), 0, 3, 0.5, 1.0, 0, ProbeOcc::default());
        let (est, confidence) = store.current(key(), 0, 0, ProbeOcc::default()).unwrap();
        assert_eq!(est.surface_idx, 3);
        assert!(confidence > 0.9, "fresh confidence decayed to {confidence}");
    }

    #[test]
    fn cluster_mismatch_is_a_miss() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        store.record(key(), 2, 3, 0.5, 1.0, 0, ProbeOcc::default());
        // A surface index only means something within its own cluster.
        assert!(store.current(key(), 1, 0, ProbeOcc::default()).is_none());
        assert!(store.current(key(), 2, 0, ProbeOcc::default()).is_some());
        // Reinforcing under another cluster starts fresh instead of
        // bumping the stale cluster's confidence.
        store.reinforce(key(), 5, 1, 0.3, 0, ProbeOcc::default());
        let (est, confidence) = store.current(key(), 5, 0, ProbeOcc::default()).unwrap();
        assert_eq!(est.surface_idx, 1);
        assert!(confidence <= store.config().bulk_bonus + 1e-9);
    }

    #[test]
    fn confidence_decays_on_the_half_life() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_millis(20),
            ..Default::default()
        });
        store.record(key(), 0, 2, 0.4, 1.0, 0, ProbeOcc::default());
        std::thread::sleep(Duration::from_millis(80));
        let (_, confidence) = store.current(key(), 0, 0, ProbeOcc::default()).unwrap();
        // ≥ 4 half-lives have passed ⇒ ≤ 1/16 (with slack for timing).
        assert!(confidence < 0.2, "stale confidence still {confidence}");
    }

    #[test]
    fn generation_mismatch_applies_penalty() {
        let config = EstimateConfig { half_life: Duration::from_secs(500), ..Default::default() };
        let store = EstimateStore::new(config);
        store.record(key(), 0, 1, 0.2, 1.0, 7, ProbeOcc::default());
        let (_, same_gen) = store.current(key(), 0, 7, ProbeOcc::default()).unwrap();
        let (_, new_gen) = store.current(key(), 0, 8, ProbeOcc::default()).unwrap();
        assert!(new_gen < same_gen);
        assert!(
            (new_gen - same_gen * config.generation_penalty).abs() < 0.05,
            "penalty not applied: {new_gen} vs {same_gen}"
        );
    }

    #[test]
    fn occupancy_class_change_applies_penalty_both_ways() {
        let config = EstimateConfig { half_life: Duration::from_secs(500), ..Default::default() };
        let store = EstimateStore::new(config);
        let busy = ProbeOcc { epoch: 9, streams: 48 };
        let quiet = ProbeOcc::default();
        // Learned under a convoy: quiet admission is demoted...
        store.record(key(), 0, 3, 0.8, 1.0, 0, busy);
        let (est, under_convoy) = store.current(key(), 0, 0, busy).unwrap();
        assert_eq!(est.occ, busy, "the occupancy observation is recorded");
        let (_, on_quiet) = store.current(key(), 0, 0, quiet).unwrap();
        assert!(on_quiet < under_convoy);
        assert!(
            (on_quiet - under_convoy * config.occupancy_penalty).abs() < 0.05,
            "penalty not applied: {on_quiet} vs {under_convoy}"
        );
        // ...and vice versa: quiet knowledge is not convoy truth.
        store.record(key(), 0, 3, 0.8, 1.0, 0, quiet);
        let (_, served_quiet) = store.current(key(), 0, 0, quiet).unwrap();
        let (_, served_busy) = store.current(key(), 0, 0, busy).unwrap();
        assert!(served_busy < served_quiet);
        // Jitter inside one class never churns confidence.
        let jitter = ProbeOcc { epoch: 11, streams: 0 };
        let (_, same_class) = store.current(key(), 0, 0, jitter).unwrap();
        assert!((same_class - served_quiet).abs() < 0.02);
        // Default sizing: a full-confidence estimate drops below the
        // serve threshold on a class change.
        assert!(served_busy < config.serve_threshold);
    }

    #[test]
    fn cross_class_reinforcement_cannot_launder_confidence() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        let quiet = ProbeOcc::default();
        let busy = ProbeOcc { epoch: 3, streams: 48 };
        // Full-confidence quiet knowledge...
        store.record(key(), 0, 3, 0.5, 1.0, 0, quiet);
        // ...confirmed once by a bulk completion under a convoy: the
        // bonus applies to the *penalized* base, so one cross-class
        // confirmation cannot clear the serve threshold.
        store.reinforce(key(), 0, 3, 0.5, 0, busy);
        let (est, confidence) = store.current(key(), 0, 0, busy).unwrap();
        assert_eq!(est.occ, busy, "the merge is stamped with the new occupancy");
        assert!(
            confidence < store.config().serve_threshold,
            "one convoy-time confirmation laundered quiet confidence to {confidence}"
        );
        // The same guard holds on the record max-merge path.
        store.record(key(), 0, 3, 0.5, 0.2, 0, quiet);
        let (_, merged) = store.current(key(), 0, 0, quiet).unwrap();
        assert!(
            merged < store.config().serve_threshold,
            "a weak quiet re-record inherited busy confidence at {merged}"
        );
        // Repeated confirmations under the new class do earn a serve.
        for _ in 0..4 {
            store.reinforce(key(), 0, 3, 0.5, 0, quiet);
        }
        let (_, earned) = store.current(key(), 0, 0, quiet).unwrap();
        assert!(earned >= store.config().serve_threshold, "{earned}");
    }

    // --- property tests (same `util::proptest` harness as budget.rs) ---

    use crate::util::proptest::{forall, Config};

    #[test]
    fn property_decay_is_monotone_in_elapsed_time() {
        forall(
            Config { cases: 200, seed: 0xDECA1 },
            |rng| {
                (
                    rng.range_f64(0.0, 1.0), // confidence
                    rng.range_u(10, 2_000),  // half-life (ms)
                    rng.range_u(0, 2_000),   // younger age (ms)
                    rng.range_u(1, 3_000),   // extra age of the older twin (ms)
                )
            },
            |&(confidence, half_life_ms, young_ms, extra_ms)| {
                let config = EstimateConfig {
                    half_life: Duration::from_millis(half_life_ms),
                    ..Default::default()
                };
                let now = Instant::now();
                let estimate_aged = |age_ms: u64| {
                    now.checked_sub(Duration::from_millis(age_ms)).map(|updated_at| {
                        NetworkEstimate {
                            cluster_idx: 0,
                            surface_idx: 0,
                            intensity: 0.5,
                            confidence,
                            generation: 0,
                            occ: ProbeOcc::default(),
                            updated_at,
                        }
                    })
                };
                let (Some(young), Some(old)) =
                    (estimate_aged(young_ms), estimate_aged(young_ms + extra_ms))
                else {
                    return Ok(()); // clock too close to boot to back-date
                };
                // The older estimate is evaluated second, so its true age
                // is strictly larger; monotone decay must hold anyway.
                let young_conf = young.decayed(&config, 0);
                let old_conf = old.decayed(&config, 0);
                if old_conf > young_conf + 1e-9 {
                    return Err(format!(
                        "confidence rose with age: {old_conf} (older) > {young_conf} (younger)"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_generation_penalty_never_raises_confidence() {
        forall(
            Config { cases: 200, seed: 0x6E4A },
            |rng| {
                (
                    rng.range_f64(0.0, 1.0), // recorded confidence
                    rng.range_f64(0.0, 1.0), // generation penalty
                    rng.range_u(0, 40),      // recorded generation
                )
            },
            |&(confidence, penalty, generation)| {
                let config = EstimateConfig {
                    half_life: Duration::from_secs(500),
                    generation_penalty: penalty,
                    ..Default::default()
                };
                let store = EstimateStore::new(config);
                store.record(key(), 0, 1, 0.5, confidence, generation, ProbeOcc::default());
                let (_, same_gen) = store.current(key(), 0, generation, ProbeOcc::default()).unwrap();
                let (_, cross_gen) = store.current(key(), 0, generation + 1, ProbeOcc::default()).unwrap();
                if cross_gen > same_gen + 1e-9 {
                    return Err(format!(
                        "cross-generation penalty raised confidence: {cross_gen} > {same_gen}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_occupancy_penalty_never_raises_confidence() {
        forall(
            Config { cases: 200, seed: 0x0CC0 },
            |rng| {
                (
                    rng.range_f64(0.0, 1.0),  // recorded confidence
                    rng.range_f64(0.0, 1.0),  // occupancy penalty
                    rng.range_u(0, 64) as u32, // recorded occ streams
                    rng.range_u(0, 64) as u32, // admission occ streams
                )
            },
            |&(confidence, penalty, recorded_streams, now_streams)| {
                let config = EstimateConfig {
                    half_life: Duration::from_secs(500),
                    occupancy_penalty: penalty,
                    ..Default::default()
                };
                let store = EstimateStore::new(config);
                let recorded = ProbeOcc { epoch: 1, streams: recorded_streams };
                let now = ProbeOcc { epoch: 2, streams: now_streams };
                store.record(key(), 0, 1, 0.5, confidence, 0, recorded);
                let (_, matched) = store.current(key(), 0, 0, recorded).unwrap();
                let (_, shifted) = store.current(key(), 0, 0, now).unwrap();
                // Tolerances cover the sub-millisecond wall decay
                // between the two lookups.
                if shifted > matched + 1e-6 {
                    return Err(format!(
                        "occupancy shift raised confidence: {shifted} > {matched}"
                    ));
                }
                if recorded.class() == now.class() && (shifted - matched).abs() > 1e-4 {
                    return Err(format!(
                        "same busy class must not change confidence: {shifted} vs {matched}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_record_never_replaces_stronger_evidence_for_same_conclusion() {
        forall(
            Config { cases: 300, seed: 0xE71D },
            |rng| -> Vec<(usize, usize, u64, f64)> {
                (0..rng.range_u(1, 30))
                    .map(|_| {
                        (
                            rng.index(2),            // cluster
                            rng.index(3),            // surface
                            rng.range_u(0, 2),       // generation
                            rng.range_f64(0.0, 1.0), // confidence
                        )
                    })
                    .collect()
            },
            |ops| {
                let store = EstimateStore::new(EstimateConfig {
                    half_life: Duration::from_secs(500),
                    ..Default::default()
                });
                for &(cluster, surface, generation, confidence) in ops {
                    let before = store.peek(key());
                    store.record(key(), cluster, surface, 0.4, confidence, generation, ProbeOcc::default());
                    let after = store.peek(key()).expect("just recorded");
                    // Incoming evidence is always at least honored.
                    if after.confidence + 1e-9 < confidence.min(1.0) {
                        return Err(format!(
                            "recorded at {confidence} but stored only {}",
                            after.confidence
                        ));
                    }
                    // Same conclusion (cluster, surface, generation):
                    // stronger prior evidence must survive a weaker
                    // re-record. The floor is computed after the record,
                    // so it has decayed at least as much as the value the
                    // store compared against.
                    if let Some(prev) = before {
                        if prev.cluster_idx == cluster
                            && prev.surface_idx == surface
                            && prev.generation == generation
                        {
                            let floor = prev.decayed(store.config(), generation);
                            if after.confidence + 1e-6 < floor.min(1.0) {
                                return Err(format!(
                                    "weaker re-record dropped confidence to {} (floor {floor})",
                                    after.confidence
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn peek_returns_raw_estimate_across_clusters() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        assert!(store.peek(key()).is_none());
        store.record(key(), 2, 3, 0.5, 1.0, 7, ProbeOcc::default());
        // `current` under another cluster misses; `peek` still sees it.
        assert!(store.current(key(), 0, 7, ProbeOcc::default()).is_none());
        let raw = store.peek(key()).unwrap();
        assert_eq!((raw.cluster_idx, raw.surface_idx, raw.generation), (2, 3, 7));
    }

    #[test]
    fn reinforce_bumps_and_caps_confidence() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            bulk_bonus: 0.3,
            ..Default::default()
        });
        // Creates at bonus confidence when absent.
        store.reinforce(key(), 0, 2, 0.4, 0, ProbeOcc::default());
        let (est, confidence) = store.current(key(), 0, 0, ProbeOcc::default()).unwrap();
        assert_eq!(est.surface_idx, 2);
        assert!((0.2..=0.3001).contains(&confidence), "created at {confidence}");
        // Repeated confirmations approach — and never exceed — 1.
        for _ in 0..10 {
            store.reinforce(key(), 0, 2, 0.4, 0, ProbeOcc::default());
        }
        let (_, confidence) = store.current(key(), 0, 0, ProbeOcc::default()).unwrap();
        assert!(confidence <= 1.0);
        assert!(confidence > 0.9);
    }
}
