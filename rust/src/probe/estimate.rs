//! Decaying per-shard network-state estimates.
//!
//! The knowledge base describes a network's *long-run* behavior; the
//! estimate store remembers what the most recent transfers learned
//! about its state *right now*: the surface index the sampling ladder
//! (or the drift monitor) last settled on and that surface's load
//! intensity. An estimate's confidence decays on a freshness half-life
//! — "the obtained information is *partial* and the network is
//! *dynamic*" — so a stale observation gracefully stops short-circuiting
//! the ladder instead of serving wrong parameters forever.
//!
//! Estimates are fed from three directions, in decreasing strength:
//! a sampling ladder the shard led (direct measurement), a completed
//! bulk transfer (the steady phase confirmed the surface), and a
//! mid-transfer drift re-tune (the monitor moved to a new surface
//! without fresh sampling).

use crate::fabric::ShardKey;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Estimate tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EstimateConfig {
    /// Confidence halves every `half_life` of wall time.
    pub half_life: Duration,
    /// Serve the estimate (skip sampling entirely) at or above this
    /// decayed confidence.
    pub serve_threshold: f64,
    /// Multiplier applied when the serving KB generation differs from
    /// the one the estimate was recorded under (the surface stack may
    /// have shifted under the index).
    pub generation_penalty: f64,
    /// Confidence of an estimate written by a led sampling ladder.
    pub lead_confidence: f64,
    /// Confidence when a led run never actually sampled (short-transfer
    /// fast path): the surface is an unmeasured guess, so this sits
    /// *below* `serve_threshold` by default — strong enough to
    /// warm-start later ladders, never strong enough to suppress their
    /// sampling. Bulk completions then reinforce it toward the
    /// threshold if the guess keeps holding up.
    pub lead_unsampled_confidence: f64,
    /// Confidence bump from a completed bulk transfer that confirmed
    /// the estimate (no drift re-tunes).
    pub bulk_bonus: f64,
    /// Confidence of an estimate re-pointed by a mid-transfer drift
    /// re-tune (the monitor's surface re-selection, not a fresh probe).
    pub drift_confidence: f64,
}

impl Default for EstimateConfig {
    fn default() -> Self {
        EstimateConfig {
            half_life: Duration::from_secs(60),
            serve_threshold: 0.6,
            generation_penalty: 0.5,
            lead_confidence: 1.0,
            lead_unsampled_confidence: 0.5,
            bulk_bonus: 0.1,
            drift_confidence: 0.7,
        }
    }
}

/// One shard's current network-state estimate.
#[derive(Debug, Clone, Copy)]
pub struct NetworkEstimate {
    /// KB cluster whose surface stack `surface_idx` indexes — a surface
    /// index is meaningless in any other cluster, so lookups for a
    /// different cluster miss.
    pub cluster_idx: usize,
    /// Index into the cluster's ascending-intensity surface stack.
    pub surface_idx: usize,
    /// That surface's external-load intensity.
    pub intensity: f64,
    /// Confidence at `updated_at` (decays from there).
    pub confidence: f64,
    /// KB generation the index refers to.
    pub generation: u64,
    pub updated_at: Instant,
}

impl NetworkEstimate {
    /// Confidence as of now: exponential decay on the half-life, with
    /// the generation penalty applied when the serving KB has moved on.
    pub fn decayed(&self, config: &EstimateConfig, serving_generation: u64) -> f64 {
        let age = self.updated_at.elapsed().as_secs_f64();
        let half_life = config.half_life.as_secs_f64().max(1e-9);
        let mut confidence = self.confidence * 0.5_f64.powf(age / half_life);
        if serving_generation != self.generation {
            confidence *= config.generation_penalty;
        }
        confidence.clamp(0.0, 1.0)
    }
}

/// Thread-safe map of per-shard estimates.
#[derive(Debug)]
pub struct EstimateStore {
    config: EstimateConfig,
    inner: Mutex<HashMap<ShardKey, NetworkEstimate>>,
}

impl EstimateStore {
    pub fn new(config: EstimateConfig) -> EstimateStore {
        EstimateStore { config, inner: Mutex::new(HashMap::new()) }
    }

    pub fn config(&self) -> &EstimateConfig {
        &self.config
    }

    /// The shard's estimate plus its decayed confidence under the
    /// serving generation; `None` when nothing has been observed yet or
    /// the stored estimate indexes a different cluster's surface stack.
    pub fn current(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        serving_generation: u64,
    ) -> Option<(NetworkEstimate, f64)> {
        let map = self.inner.lock().expect("estimate store poisoned");
        map.get(&key)
            .filter(|e| e.cluster_idx == cluster_idx)
            .map(|e| (*e, e.decayed(&self.config, serving_generation)))
    }

    /// Overwrite the shard's estimate with a fresh observation.
    pub fn record(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        surface_idx: usize,
        intensity: f64,
        confidence: f64,
        generation: u64,
    ) {
        let mut map = self.inner.lock().expect("estimate store poisoned");
        map.insert(
            key,
            NetworkEstimate {
                cluster_idx,
                surface_idx,
                intensity,
                confidence: confidence.clamp(0.0, 1.0),
                generation,
                updated_at: Instant::now(),
            },
        );
    }

    /// A completed bulk transfer confirmed the surface: bump the
    /// decayed confidence by the bulk bonus (capped at 1) and refresh
    /// the timestamp. Creates the estimate at bonus confidence when the
    /// shard had none (or held another cluster's estimate).
    pub fn reinforce(
        &self,
        key: ShardKey,
        cluster_idx: usize,
        surface_idx: usize,
        intensity: f64,
        generation: u64,
    ) {
        let mut map = self.inner.lock().expect("estimate store poisoned");
        let confidence = map
            .get(&key)
            .filter(|e| e.cluster_idx == cluster_idx)
            .map(|e| e.decayed(&self.config, generation) + self.config.bulk_bonus)
            .unwrap_or(self.config.bulk_bonus)
            .clamp(0.0, 1.0);
        map.insert(
            key,
            NetworkEstimate {
                cluster_idx,
                surface_idx,
                intensity,
                confidence,
                generation,
                updated_at: Instant::now(),
            },
        );
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("estimate store poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sorted snapshot for rendering.
    pub fn entries(&self) -> Vec<(ShardKey, NetworkEstimate)> {
        let map = self.inner.lock().expect("estimate store poisoned");
        let mut out: Vec<(ShardKey, NetworkEstimate)> =
            map.iter().map(|(k, e)| (*k, *e)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::TestbedId;

    fn key() -> ShardKey {
        ShardKey::new(TestbedId::Xsede, SizeClass::Large)
    }

    #[test]
    fn fresh_estimate_keeps_its_confidence() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        assert!(store.current(key(), 0, 0).is_none());
        store.record(key(), 0, 3, 0.5, 1.0, 0);
        let (est, confidence) = store.current(key(), 0, 0).unwrap();
        assert_eq!(est.surface_idx, 3);
        assert!(confidence > 0.9, "fresh confidence decayed to {confidence}");
    }

    #[test]
    fn cluster_mismatch_is_a_miss() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            ..Default::default()
        });
        store.record(key(), 2, 3, 0.5, 1.0, 0);
        // A surface index only means something within its own cluster.
        assert!(store.current(key(), 1, 0).is_none());
        assert!(store.current(key(), 2, 0).is_some());
        // Reinforcing under another cluster starts fresh instead of
        // bumping the stale cluster's confidence.
        store.reinforce(key(), 5, 1, 0.3, 0);
        let (est, confidence) = store.current(key(), 5, 0).unwrap();
        assert_eq!(est.surface_idx, 1);
        assert!(confidence <= store.config().bulk_bonus + 1e-9);
    }

    #[test]
    fn confidence_decays_on_the_half_life() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_millis(20),
            ..Default::default()
        });
        store.record(key(), 0, 2, 0.4, 1.0, 0);
        std::thread::sleep(Duration::from_millis(80));
        let (_, confidence) = store.current(key(), 0, 0).unwrap();
        // ≥ 4 half-lives have passed ⇒ ≤ 1/16 (with slack for timing).
        assert!(confidence < 0.2, "stale confidence still {confidence}");
    }

    #[test]
    fn generation_mismatch_applies_penalty() {
        let config = EstimateConfig { half_life: Duration::from_secs(500), ..Default::default() };
        let store = EstimateStore::new(config);
        store.record(key(), 0, 1, 0.2, 1.0, 7);
        let (_, same_gen) = store.current(key(), 0, 7).unwrap();
        let (_, new_gen) = store.current(key(), 0, 8).unwrap();
        assert!(new_gen < same_gen);
        assert!(
            (new_gen - same_gen * config.generation_penalty).abs() < 0.05,
            "penalty not applied: {new_gen} vs {same_gen}"
        );
    }

    #[test]
    fn reinforce_bumps_and_caps_confidence() {
        let store = EstimateStore::new(EstimateConfig {
            half_life: Duration::from_secs(500),
            bulk_bonus: 0.3,
            ..Default::default()
        });
        // Creates at bonus confidence when absent.
        store.reinforce(key(), 0, 2, 0.4, 0);
        let (est, confidence) = store.current(key(), 0, 0).unwrap();
        assert_eq!(est.surface_idx, 2);
        assert!((0.2..=0.3001).contains(&confidence), "created at {confidence}");
        // Repeated confirmations approach — and never exceed — 1.
        for _ in 0..10 {
            store.reinforce(key(), 0, 2, 0.4, 0);
        }
        let (_, confidence) = store.current(key(), 0, 0).unwrap();
        assert!(confidence <= 1.0);
        assert!(confidence > 0.9);
    }
}
