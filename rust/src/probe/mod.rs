//! The shared probe plane — the paper's "real-time investigation is
//! expensive and provides partial knowledge" (§1) made into a fleet-wide
//! invariant.
//!
//! Historically every request ran its own private ASM sampling ladder:
//! a burst of concurrent requests on the same network re-probed it
//! redundantly, multiplying exactly the overhead the knowledge base
//! exists to avoid. The probe plane sits between the coordinator (or a
//! fabric shard) and the ASM and treats the online probe as a scarce
//! *shared* resource, the way HARP's historical tuning and the
//! two-phase model treat their online phases:
//!
//! ```text
//!             ┌──────────────────────────────────────────────────┐
//!  ASM req ──▶│ estimate fresh enough? ──yes──▶ serve estimate   │
//!             │        │ no                     (no sampling)    │
//!             │        ▼                                         │
//!             │ flight in progress? ──yes──▶ piggyback on leader │
//!             │        │ no                   (bounded wait)     │
//!             │        ▼                                         │
//!             │ probe budget left? ──no───▶ forced estimate use  │
//!             │        │ yes                                     │
//!             │        ▼                                         │
//!             │ lead the sampling ladder (warm-started at the    │
//!             │ estimated surface), publish result to followers  │
//!             └──────────────────────────────────────────────────┘
//! ```
//!
//! * [`estimate`] — per-[`ShardKey`] network-state estimates (last
//!   converged surface index + load intensity) whose confidence decays
//!   on a freshness half-life; fed by sampling outcomes and passively
//!   by completed bulk transfers and mid-transfer drift re-tunes.
//! * [`singleflight`] — concurrent requests for the same shard
//!   coalesce: one leader runs the ladder, followers piggyback on its
//!   result (bounded wait) or proceed on the current estimate.
//! * [`budget`] — a token-bucket probe budget per shard capping the
//!   fraction of bytes spent on sampling; exhaustion forces estimate
//!   reuse instead of probing.
//! * [`plane`] — the [`ProbePlane`] facade the coordinator calls:
//!   admission (`led` / `piggybacked` / `estimate-served`), outcome
//!   settlement, and the probe metrics block.
//!
//! [`ShardKey`]: crate::fabric::ShardKey

pub mod budget;
pub mod estimate;
pub mod plane;
pub mod singleflight;

pub use budget::{BudgetConfig, TokenBucket};
pub use estimate::{EstimateConfig, EstimateStore, NetworkEstimate, ProbeOcc};
pub use plane::{Admission, ProbeConfig, ProbeMode, ProbePlane, ProbeStats};
pub use singleflight::{FlightGuard, FollowOutcome, ProbeResult, Role, SingleFlight};
