//! Single-flight coalescing of sampling ladders.
//!
//! When concurrent requests hit the same shard with no fresh estimate,
//! only one of them — the *leader* — should pay for sampling; the rest
//! — *followers* — wait (bounded) and piggyback on the leader's result,
//! or fall back to whatever estimate exists if the wait runs out. The
//! map entry lives exactly as long as the leader's [`FlightGuard`]:
//! completion and abort both publish to waiting followers and clear the
//! key, and a leader that panics mid-ladder aborts via `Drop`, so
//! followers can never wait on a flight nobody is flying.

use crate::fabric::ShardKey;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a completed sampling ladder hands to its followers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeResult {
    /// KB cluster whose surface stack `surface_idx` indexes; a follower
    /// whose request maps to a different cluster must not use it.
    pub cluster_idx: usize,
    /// KB generation the leader sampled under; a refresh can rebuild
    /// the stack, so a follower pinned to another generation must not
    /// reuse the index.
    pub generation: u64,
    /// Surface index the leader's run settled on.
    pub surface_idx: usize,
    /// That surface's external-load intensity.
    pub intensity: f64,
}

enum FlightState {
    Pending,
    Done(Option<ProbeResult>),
}

/// One in-progress sampling ladder that followers can wait on.
pub struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
    /// Followers currently blocked in [`Flight::wait`] — an observation
    /// hook for harnesses that need to know a coalesced cohort has
    /// fully joined before releasing the leader (scenario engine).
    waiters: AtomicUsize,
}

impl Flight {
    fn new() -> Flight {
        Flight {
            state: Mutex::new(FlightState::Pending),
            cv: Condvar::new(),
            waiters: AtomicUsize::new(0),
        }
    }

    /// Followers currently blocked in [`Flight::wait`].
    pub fn waiting(&self) -> usize {
        self.waiters.load(Ordering::SeqCst)
    }

    /// Wait (bounded) for the leader's result.
    pub fn wait(&self, timeout: Duration) -> FollowOutcome {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let outcome = self.wait_inner(timeout);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        outcome
    }

    fn wait_inner(&self, timeout: Duration) -> FollowOutcome {
        let deadline = Instant::now() + timeout;
        let mut state = self.state.lock().expect("flight poisoned");
        loop {
            match &*state {
                FlightState::Done(Some(result)) => return FollowOutcome::Result(*result),
                FlightState::Done(None) => return FollowOutcome::Aborted,
                FlightState::Pending => {
                    let now = Instant::now();
                    if now >= deadline {
                        return FollowOutcome::TimedOut;
                    }
                    let (next, _) = self
                        .cv
                        .wait_timeout(state, deadline - now)
                        .expect("flight poisoned");
                    state = next;
                }
            }
        }
    }
}

/// How a follower's wait ended.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FollowOutcome {
    /// The leader converged; here is its result.
    Result(ProbeResult),
    /// The leader finished without a usable result (e.g. cold-start KB).
    Aborted,
    /// The bounded wait ran out before the leader finished.
    TimedOut,
}

type FlightMap = Arc<Mutex<HashMap<ShardKey, Arc<Flight>>>>;

/// Per-shard coalescing map. Cloning shares the same map.
#[derive(Clone, Default)]
pub struct SingleFlight {
    inner: FlightMap,
}

/// What `lead_or_join` decided for the caller.
pub enum Role {
    /// No flight was active: the caller leads. It MUST `complete` or
    /// `abort` the guard (dropping it aborts).
    Leader(FlightGuard),
    /// A flight is active: wait on it.
    Follower(Arc<Flight>),
}

impl SingleFlight {
    pub fn new() -> SingleFlight {
        SingleFlight::default()
    }

    /// Atomically either register a new flight for `key` (caller
    /// becomes the leader) or hand back the in-progress flight to wait
    /// on.
    pub fn lead_or_join(&self, key: ShardKey) -> Role {
        let mut map = self.inner.lock().expect("flight map poisoned");
        if let Some(flight) = map.get(&key) {
            return Role::Follower(flight.clone());
        }
        let flight = Arc::new(Flight::new());
        map.insert(key, flight.clone());
        Role::Leader(FlightGuard { map: self.inner.clone(), key, flight, settled: false })
    }

    /// Number of in-progress flights (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.inner.lock().expect("flight map poisoned").len()
    }

    /// Followers currently blocked on `key`'s in-progress flight (0
    /// when no flight is registered). Harness hook: lets a driver know
    /// a coalesced cohort has joined before the leader converges.
    pub fn waiters(&self, key: ShardKey) -> usize {
        self.inner
            .lock()
            .expect("flight map poisoned")
            .get(&key)
            .map_or(0, |flight| flight.waiting())
    }
}

/// The leader's obligation: publish a result (or an abort) exactly
/// once, clearing the key so the next cold request can lead again.
pub struct FlightGuard {
    map: FlightMap,
    key: ShardKey,
    flight: Arc<Flight>,
    settled: bool,
}

impl FlightGuard {
    /// Publish the ladder's result to every waiting follower.
    pub fn complete(mut self, result: ProbeResult) {
        self.settle(Some(result));
    }

    /// The ladder learned nothing (cold-start KB, error path); wake
    /// followers so they fall back instead of timing out.
    pub fn abort(mut self) {
        self.settle(None);
    }

    fn settle(&mut self, result: Option<ProbeResult>) {
        if self.settled {
            return;
        }
        self.settled = true;
        {
            let mut state = self.flight.state.lock().expect("flight poisoned");
            *state = FlightState::Done(result);
        }
        self.flight.cv.notify_all();
        // Only this guard ever inserted for the key, and it holds the
        // entry until settled — the removal cannot hit a newer flight.
        self.map.lock().expect("flight map poisoned").remove(&self.key);
    }
}

impl Drop for FlightGuard {
    fn drop(&mut self) {
        self.settle(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dataset::SizeClass;
    use crate::sim::testbed::TestbedId;

    fn key() -> ShardKey {
        ShardKey::new(TestbedId::Xsede, SizeClass::Large)
    }

    #[test]
    fn one_leader_many_followers_observe_the_result() {
        let flights = SingleFlight::new();
        let guard = match flights.lead_or_join(key()) {
            Role::Leader(guard) => guard,
            Role::Follower(_) => panic!("fresh map must elect a leader"),
        };
        // Followers spawned while the flight is registered are
        // deterministically followers.
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let flights = flights.clone();
                std::thread::spawn(move || match flights.lead_or_join(key()) {
                    Role::Follower(flight) => flight.wait(Duration::from_secs(30)),
                    Role::Leader(_) => panic!("second leader elected"),
                })
            })
            .collect();
        // Give the followers a moment to start waiting (correctness
        // does not depend on it — late waiters see the Done state).
        std::thread::sleep(Duration::from_millis(10));
        let published =
            ProbeResult { cluster_idx: 0, generation: 0, surface_idx: 2, intensity: 0.4 };
        guard.complete(published);
        for handle in handles {
            match handle.join().unwrap() {
                FollowOutcome::Result(result) => {
                    assert_eq!(result, published);
                }
                other => panic!("follower missed the leader's result: {other:?}"),
            }
        }
        // The key is clear again: the next request leads.
        assert_eq!(flights.in_flight(), 0);
        assert!(matches!(flights.lead_or_join(key()), Role::Leader(_)));
    }

    #[test]
    fn waiters_counts_blocked_followers() {
        let flights = SingleFlight::new();
        assert_eq!(flights.waiters(key()), 0, "no flight, no waiters");
        let guard = match flights.lead_or_join(key()) {
            Role::Leader(guard) => guard,
            Role::Follower(_) => panic!("fresh map must elect a leader"),
        };
        assert_eq!(flights.waiters(key()), 0, "a flight with no followers yet");
        let waiter = {
            let flights = flights.clone();
            std::thread::spawn(move || match flights.lead_or_join(key()) {
                Role::Follower(flight) => flight.wait(Duration::from_secs(30)),
                Role::Leader(_) => panic!("second leader elected"),
            })
        };
        let deadline = Instant::now() + Duration::from_secs(30);
        while flights.waiters(key()) < 1 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(flights.waiters(key()), 1);
        guard.complete(ProbeResult { cluster_idx: 0, generation: 0, surface_idx: 1, intensity: 0.2 });
        assert!(matches!(waiter.join().unwrap(), FollowOutcome::Result(_)));
        assert_eq!(flights.waiters(key()), 0, "flight cleared with its waiters");
    }

    #[test]
    fn follower_wait_is_bounded() {
        let flights = SingleFlight::new();
        let _guard = match flights.lead_or_join(key()) {
            Role::Leader(guard) => guard,
            Role::Follower(_) => panic!("fresh map must elect a leader"),
        };
        let flight = match flights.lead_or_join(key()) {
            Role::Follower(flight) => flight,
            Role::Leader(_) => panic!("flight already registered"),
        };
        let started = Instant::now();
        assert_eq!(flight.wait(Duration::from_millis(20)), FollowOutcome::TimedOut);
        assert!(started.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn abort_and_drop_wake_followers() {
        for explicit in [true, false] {
            let flights = SingleFlight::new();
            let guard = match flights.lead_or_join(key()) {
                Role::Leader(guard) => guard,
                Role::Follower(_) => panic!("fresh map must elect a leader"),
            };
            let flight = match flights.lead_or_join(key()) {
                Role::Follower(flight) => flight,
                Role::Leader(_) => panic!("flight already registered"),
            };
            let waiter = std::thread::spawn(move || flight.wait(Duration::from_secs(30)));
            if explicit {
                guard.abort();
            } else {
                drop(guard); // a panicking leader unwinds through Drop
            }
            assert_eq!(waiter.join().unwrap(), FollowOutcome::Aborted);
            assert_eq!(flights.in_flight(), 0);
        }
    }
}
