//! Per-shard probe budgets: a token bucket denominated in megabytes.
//!
//! Sampling is useful but not free — every probe byte is a byte of the
//! user's transfer moved at possibly-wrong parameters. The budget caps
//! the long-run fraction of bytes spent probing: bulk bytes *earn*
//! tokens at `earn_fraction`, probes *spend* them, and an empty bucket
//! forces the plane to reuse the current estimate instead of sampling.
//! The capacity bounds how large a probing burst can ever get, no
//! matter how much credit quiet bulk traffic has accrued.
//!
//! Invariants (property-tested below): tokens never go negative, and no
//! refill ever pushes them past capacity.

use std::sync::Mutex;

/// Budget tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct BudgetConfig {
    /// Token ceiling (MB). Bounds probe bursts.
    pub capacity_mb: f64,
    /// Tokens at startup (clamped to capacity) — a full bucket lets a
    /// cold system learn before any bulk bytes have been earned.
    pub initial_mb: f64,
    /// Tokens earned per bulk megabyte moved: the long-run cap on the
    /// probe-byte fraction.
    pub earn_fraction: f64,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        BudgetConfig { capacity_mb: 8192.0, initial_mb: 8192.0, earn_fraction: 0.05 }
    }
}

/// A megabyte-denominated token bucket. All operations are total: bad
/// inputs (negative, NaN, infinite) are ignored rather than corrupting
/// the invariants.
#[derive(Debug)]
pub struct TokenBucket {
    capacity_mb: f64,
    tokens: Mutex<f64>,
}

impl TokenBucket {
    pub fn new(capacity_mb: f64, initial_mb: f64) -> TokenBucket {
        let capacity = if capacity_mb.is_finite() { capacity_mb.max(0.0) } else { 0.0 };
        let initial = if initial_mb.is_finite() { initial_mb.clamp(0.0, capacity) } else { 0.0 };
        TokenBucket { capacity_mb: capacity, tokens: Mutex::new(initial) }
    }

    pub fn of(config: &BudgetConfig) -> TokenBucket {
        TokenBucket::new(config.capacity_mb, config.initial_mb)
    }

    pub fn capacity_mb(&self) -> f64 {
        self.capacity_mb
    }

    pub fn available_mb(&self) -> f64 {
        *self.tokens.lock().expect("token bucket poisoned")
    }

    /// All-or-nothing reservation: deduct `mb` iff that many tokens are
    /// available. Non-finite or negative requests are refused.
    pub fn try_take(&self, mb: f64) -> bool {
        if !mb.is_finite() || mb < 0.0 {
            return false;
        }
        let mut tokens = self.tokens.lock().expect("token bucket poisoned");
        if *tokens >= mb {
            *tokens -= mb;
            true
        } else {
            false
        }
    }

    /// Add tokens (earned bulk bytes, or a reservation refund), capped
    /// at capacity.
    pub fn credit(&self, mb: f64) {
        if !mb.is_finite() || mb <= 0.0 {
            return;
        }
        let mut tokens = self.tokens.lock().expect("token bucket poisoned");
        *tokens = (*tokens + mb).min(self.capacity_mb);
    }

    /// Charge actual probe bytes, saturating at zero (the reservation
    /// was an estimate; actuals can overshoot it).
    pub fn drain(&self, mb: f64) {
        if !mb.is_finite() || mb <= 0.0 {
            return;
        }
        let mut tokens = self.tokens.lock().expect("token bucket poisoned");
        *tokens = (*tokens - mb).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn take_credit_drain_basics() {
        let bucket = TokenBucket::new(100.0, 40.0);
        assert_eq!(bucket.available_mb(), 40.0);
        assert!(bucket.try_take(40.0));
        assert!(!bucket.try_take(0.001), "empty bucket must refuse");
        assert!(bucket.try_take(0.0), "zero-size take always succeeds");
        bucket.credit(1_000.0);
        assert_eq!(bucket.available_mb(), 100.0, "credit caps at capacity");
        bucket.drain(1_000.0);
        assert_eq!(bucket.available_mb(), 0.0, "drain saturates at zero");
    }

    #[test]
    fn initial_tokens_clamped_to_capacity() {
        assert_eq!(TokenBucket::new(50.0, 500.0).available_mb(), 50.0);
        assert_eq!(TokenBucket::new(50.0, -3.0).available_mb(), 0.0);
        assert_eq!(TokenBucket::new(-10.0, 5.0).capacity_mb(), 0.0);
    }

    /// One random operation on the bucket.
    #[derive(Debug, Clone, Copy)]
    enum Op {
        Take(f64),
        Credit(f64),
        Drain(f64),
    }

    fn gen_ops(rng: &mut Rng) -> (f64, f64, Vec<Op>) {
        let capacity = rng.range_f64(0.0, 2_000.0);
        let initial = rng.range_f64(-100.0, 3_000.0);
        let ops = (0..rng.range_u(1, 60))
            .map(|_| {
                // Amounts deliberately include negatives and values far
                // beyond capacity.
                let amount = rng.range_f64(-500.0, 4_000.0);
                match rng.index(3) {
                    0 => Op::Take(amount),
                    1 => Op::Credit(amount),
                    _ => Op::Drain(amount),
                }
            })
            .collect();
        (capacity, initial, ops)
    }

    #[test]
    fn property_tokens_stay_within_bounds() {
        forall(
            Config { cases: 200, seed: 0xB4D6E7 },
            gen_ops,
            |(capacity, initial, ops)| {
                let bucket = TokenBucket::new(*capacity, *initial);
                for op in ops {
                    match *op {
                        Op::Take(mb) => {
                            let _ = bucket.try_take(mb);
                        }
                        Op::Credit(mb) => bucket.credit(mb),
                        Op::Drain(mb) => bucket.drain(mb),
                    }
                    let tokens = bucket.available_mb();
                    if tokens < 0.0 {
                        return Err(format!("tokens went negative: {tokens}"));
                    }
                    if tokens > bucket.capacity_mb() {
                        return Err(format!(
                            "refill exceeded capacity: {tokens} > {}",
                            bucket.capacity_mb()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_take_matches_model() {
        forall(
            Config { cases: 200, seed: 0x7A4E },
            gen_ops,
            |(capacity, initial, ops)| {
                let bucket = TokenBucket::new(*capacity, *initial);
                let mut model = bucket.available_mb();
                for op in ops {
                    match *op {
                        Op::Take(mb) => {
                            let took = bucket.try_take(mb);
                            let expect = mb >= 0.0 && model >= mb;
                            if took != expect {
                                return Err(format!(
                                    "try_take({mb}) = {took}, model had {model}"
                                ));
                            }
                            if took {
                                model -= mb;
                            }
                        }
                        Op::Credit(mb) => {
                            bucket.credit(mb);
                            if mb > 0.0 {
                                model = (model + mb).min(capacity.max(0.0));
                            }
                        }
                        Op::Drain(mb) => {
                            bucket.drain(mb);
                            if mb > 0.0 {
                                model = (model - mb).max(0.0);
                            }
                        }
                    }
                    if (bucket.available_mb() - model).abs() > 1e-6 {
                        return Err(format!(
                            "model diverged: bucket {} vs model {model}",
                            bucket.available_mb()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
