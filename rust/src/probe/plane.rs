//! The [`ProbePlane`] facade — what the coordinator's request path
//! actually talks to.
//!
//! Admission decides how a request obtains network knowledge, in
//! decreasing order of reuse: serve the decayed per-shard estimate
//! outright; piggyback on a sampling ladder another request is already
//! flying; or lead a new ladder — warm-started at the estimated surface
//! and paid for from the shard's probe budget. After the transfer the
//! plane settles the books: the budget is charged actual probe bytes
//! and earns on bulk bytes, the estimate absorbs what the run learned
//! (sampling outcome, bulk confirmation, or drift re-tune), and the
//! flight's followers are released.

use super::budget::{BudgetConfig, TokenBucket};
use super::estimate::{EstimateConfig, EstimateStore, ProbeOcc};
use super::singleflight::{FlightGuard, FollowOutcome, ProbeResult, Role, SingleFlight};
use crate::baselines::RunReport;
use crate::fabric::ShardKey;
use crate::online::asm::AsmOutcome;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Probe-plane tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ProbeConfig {
    pub estimate: EstimateConfig,
    pub budget: BudgetConfig,
    /// How long a follower waits for the leader before falling back to
    /// the estimate (or probing independently).
    pub follower_wait: Duration,
    /// Budget reservation for one sampling ladder, as a fraction of the
    /// dataset (settled against actual probe bytes after the run).
    pub expected_sample_fraction: f64,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig {
            estimate: EstimateConfig::default(),
            budget: BudgetConfig::default(),
            follower_wait: Duration::from_millis(250),
            expected_sample_fraction: 0.05,
        }
    }
}

/// How the plane served one request — attributed on every response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeMode {
    /// This request ran the sampling ladder itself.
    Led,
    /// Coalesced onto a concurrent leader's ladder.
    Piggybacked,
    /// Served from the decayed estimate (or, budget-forced with no
    /// estimate, the median surface) without any sampling.
    EstimateServed,
}

impl ProbeMode {
    pub fn name(&self) -> &'static str {
        match self {
            ProbeMode::Led => "led",
            ProbeMode::Piggybacked => "piggybacked",
            ProbeMode::EstimateServed => "estimate-served",
        }
    }
}

impl std::fmt::Display for ProbeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// What `admit` tells the request path to do.
pub enum Admission {
    /// Run the sampling ladder (warm-started at `warm_start` when an
    /// unconfident estimate exists). `guard` is `None` for the rare
    /// unregistered probe after a follower timeout.
    Lead { guard: Option<FlightGuard>, warm_start: Option<usize> },
    /// Skip sampling; start at the leader's converged surface.
    Piggyback(ProbeResult),
    /// Skip sampling; start at the estimated surface (`None` = median:
    /// the budget is exhausted and nothing has been observed yet).
    Serve(Option<usize>),
}

/// Plane-wide counters, rendered in the coordinator metrics block.
#[derive(Debug, Default)]
pub struct ProbeStats {
    pub led: AtomicU64,
    pub piggybacked: AtomicU64,
    pub estimate_served: AtomicU64,
    /// Estimate-served admissions forced by an exhausted budget rather
    /// than by confidence.
    pub budget_forced: AtomicU64,
    pub follower_timeouts: AtomicU64,
    /// Leaders whose run produced no usable outcome (cold-start KB).
    pub leader_aborts: AtomicU64,
    /// Admissions that consulted an estimate recorded under an older KB
    /// generation than the one the request is pinned to — the estimate
    /// is confidence-demoted, and the sentry's stale-knowledge detector
    /// watches this rate.
    pub stale_demotions: AtomicU64,
    /// (sample_mb, bulk_mb) moved through the plane.
    bytes: Mutex<(f64, f64)>,
}

impl ProbeStats {
    pub fn note_bytes(&self, sample_mb: f64, bulk_mb: f64) {
        let mut bytes = self.bytes.lock().expect("probe bytes poisoned");
        bytes.0 += sample_mb.max(0.0);
        bytes.1 += bulk_mb.max(0.0);
    }

    /// (sample_mb, bulk_mb) totals.
    pub fn bytes(&self) -> (f64, f64) {
        *self.bytes.lock().expect("probe bytes poisoned")
    }

    pub fn admissions(&self) -> u64 {
        self.led.load(Ordering::Relaxed)
            + self.piggybacked.load(Ordering::Relaxed)
            + self.estimate_served.load(Ordering::Relaxed)
    }
}

/// The shared probe plane.
pub struct ProbePlane {
    config: ProbeConfig,
    estimates: EstimateStore,
    flights: SingleFlight,
    budgets: Mutex<HashMap<ShardKey, Arc<TokenBucket>>>,
    pub stats: ProbeStats,
}

impl Default for ProbePlane {
    fn default() -> Self {
        ProbePlane::new(ProbeConfig::default())
    }
}

impl ProbePlane {
    pub fn new(config: ProbeConfig) -> ProbePlane {
        ProbePlane {
            config,
            estimates: EstimateStore::new(config.estimate),
            flights: SingleFlight::new(),
            budgets: Mutex::new(HashMap::new()),
            stats: ProbeStats::default(),
        }
    }

    pub fn config(&self) -> &ProbeConfig {
        &self.config
    }

    pub fn estimates(&self) -> &EstimateStore {
        &self.estimates
    }

    /// The shard's token bucket (created full on first contact).
    pub fn budget(&self, key: ShardKey) -> Arc<TokenBucket> {
        let mut budgets = self.budgets.lock().expect("budget map poisoned");
        budgets
            .entry(key)
            .or_insert_with(|| Arc::new(TokenBucket::of(&self.config.budget)))
            .clone()
    }

    /// The budget reservation for a sampling ladder over `total_mb` of
    /// data — an expectation, settled against actuals after the run.
    pub fn expected_sample_mb(&self, total_mb: f64) -> f64 {
        (total_mb * self.config.expected_sample_fraction).clamp(1.0, 4096.0)
    }

    /// Fault hook: drain the shard's probe budget to zero (the scenario
    /// engine's probe-famine injection). Until bulk traffic earns
    /// tokens back, admissions on the shard are budget-forced onto the
    /// current estimate.
    pub fn starve_budget(&self, key: ShardKey) {
        let budget = self.budget(key);
        budget.drain(budget.capacity_mb());
    }

    /// Followers currently blocked on `key`'s in-progress sampling
    /// ladder (0 when none is flying). Harness hook: the scenario
    /// engine's coalesced bursts wait for their cohort to join before
    /// running the leader, so replay admission is deterministic.
    pub fn waiting_followers(&self, key: ShardKey) -> usize {
        self.flights.waiters(key)
    }

    /// Sampling ladders currently in flight (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.flights.in_flight()
    }

    /// Decide how a request for `key` (mapping to KB cluster
    /// `cluster_idx`, served at `generation`, admitted under link
    /// occupancy `occ`) obtains network knowledge. Never blocks longer
    /// than `follower_wait`. `cluster_idx` is `None` only for an empty
    /// (cold-start) KB, where estimates and piggybacked surface indices
    /// mean nothing. `occ` is the contention plane's view at admission
    /// (`ProbeOcc::default()` when no plane is attached): an estimate
    /// recorded under a different link busy class is demoted, so
    /// knowledge learned under heavy self-traffic is never served as
    /// quiet-network truth.
    pub fn admit(
        &self,
        key: ShardKey,
        cluster_idx: Option<usize>,
        generation: u64,
        expected_sample_mb: f64,
        occ: ProbeOcc,
    ) -> Admission {
        let estimate =
            cluster_idx.and_then(|ci| self.estimates.current(key, ci, generation, occ));
        if let Some((est, _)) = &estimate {
            if est.generation != generation {
                self.stats.stale_demotions.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some((est, confidence)) = estimate {
            if confidence >= self.config.estimate.serve_threshold {
                self.stats.estimate_served.fetch_add(1, Ordering::Relaxed);
                return Admission::Serve(Some(est.surface_idx));
            }
        }
        let warm_start = estimate.map(|(est, _)| est.surface_idx);
        // Probing costs budget; reserve before trying to lead, refund
        // if another request turns out to already be flying the ladder.
        let budget = self.budget(key);
        if !budget.try_take(expected_sample_mb) {
            self.stats.budget_forced.fetch_add(1, Ordering::Relaxed);
            self.stats.estimate_served.fetch_add(1, Ordering::Relaxed);
            return Admission::Serve(warm_start);
        }
        match self.flights.lead_or_join(key) {
            Role::Leader(guard) => {
                self.stats.led.fetch_add(1, Ordering::Relaxed);
                Admission::Lead { guard: Some(guard), warm_start }
            }
            Role::Follower(flight) => {
                budget.credit(expected_sample_mb); // the leader pays, not us
                match flight.wait(self.config.follower_wait) {
                    // The leader's surface index is only usable when the
                    // follower's request maps to the same cluster AND is
                    // pinned to the same KB generation — a refresh can
                    // rebuild the stack under the index.
                    FollowOutcome::Result(result)
                        if Some(result.cluster_idx) == cluster_idx
                            && result.generation == generation =>
                    {
                        self.stats.piggybacked.fetch_add(1, Ordering::Relaxed);
                        Admission::Piggyback(result)
                    }
                    FollowOutcome::Result(_) | FollowOutcome::Aborted => {
                        self.follower_fallback(key, warm_start, expected_sample_mb)
                    }
                    FollowOutcome::TimedOut => {
                        self.stats.follower_timeouts.fetch_add(1, Ordering::Relaxed);
                        self.follower_fallback(key, warm_start, expected_sample_mb)
                    }
                }
            }
        }
    }

    /// A follower whose leader vanished (abort, timeout, or a result
    /// this request can't use): probe independently — unregistered,
    /// warm-started at whatever estimate exists — if the budget allows;
    /// otherwise fall back to the estimate. Serving a low-confidence
    /// estimate outright is NOT an option here: by construction the
    /// confident case was handled before joining the flight.
    fn follower_fallback(
        &self,
        key: ShardKey,
        warm_start: Option<usize>,
        expected_sample_mb: f64,
    ) -> Admission {
        let budget = self.budget(key);
        if budget.try_take(expected_sample_mb) {
            self.stats.led.fetch_add(1, Ordering::Relaxed);
            Admission::Lead { guard: None, warm_start }
        } else {
            self.stats.budget_forced.fetch_add(1, Ordering::Relaxed);
            self.stats.estimate_served.fetch_add(1, Ordering::Relaxed);
            Admission::Serve(warm_start)
        }
    }

    /// The leader's sampling ladder just converged (mid-run, before the
    /// bulk transfer): record the estimate and release the flight's
    /// followers *now* — a follower's bounded wait covers the ladder,
    /// never the leader's whole transfer. Wired into the ASM's
    /// `on_converged` hook.
    ///
    /// A ladder that never actually sampled (short-transfer fast path)
    /// measured nothing: its surface is recorded only at warm-start
    /// strength and its flight is *aborted*, never handed to followers
    /// as if it were a measurement.
    pub fn lead_converged(
        &self,
        key: ShardKey,
        cluster_idx: Option<usize>,
        guard: Option<FlightGuard>,
        outcome: AsmOutcome,
        generation: u64,
        occ: ProbeOcc,
    ) {
        let Some(cluster_idx) = cluster_idx else {
            // Unreachable in practice: the ladder only runs when the KB
            // has a cluster. Wake followers rather than strand them.
            if let Some(guard) = guard {
                guard.abort();
            }
            return;
        };
        let confidence = if outcome.sampled {
            self.config.estimate.lead_confidence
        } else {
            self.config.estimate.lead_unsampled_confidence
        };
        self.estimates.record(
            key,
            cluster_idx,
            outcome.surface_idx,
            outcome.intensity,
            confidence,
            generation,
            occ,
        );
        if let Some(guard) = guard {
            if outcome.sampled {
                guard.complete(ProbeResult {
                    cluster_idx,
                    generation,
                    surface_idx: outcome.surface_idx,
                    intensity: outcome.intensity,
                });
            } else {
                guard.abort();
            }
        }
    }

    /// Settle a led run after the transfer completes: charge the budget
    /// actual probe bytes (the reservation was an expectation) and fold
    /// the *final* surface into the estimate at evidence-appropriate
    /// confidence — sampled convergence is a measurement, a post-drift
    /// surface is the monitor's inference, and an unsampled run's clean
    /// bulk completion is mere reinforcement. The flight itself was
    /// already released at convergence by [`Self::lead_converged`] (or
    /// aborts with the run on the cold-start path).
    pub fn finish_led(
        &self,
        key: ShardKey,
        cluster_idx: Option<usize>,
        outcome: Option<AsmOutcome>,
        report: &RunReport,
        reserved_mb: f64,
        generation: u64,
        occ: ProbeOcc,
    ) {
        let (sample_mb, bulk_mb) = split_bytes(report);
        self.stats.note_bytes(sample_mb, bulk_mb);
        let budget = self.budget(key);
        budget.credit(reserved_mb);
        budget.drain(sample_mb);
        budget.credit(bulk_mb * self.config.budget.earn_fraction);
        match (outcome, cluster_idx) {
            (Some(outcome), Some(cluster_idx)) => {
                if report.bulk_retunes() > 0 {
                    // The final surface was chosen by the drift monitor,
                    // not by a probe — moderate confidence, same as the
                    // passive path treats drift.
                    self.estimates.record(
                        key,
                        cluster_idx,
                        outcome.surface_idx,
                        outcome.intensity,
                        self.config.estimate.drift_confidence,
                        generation,
                        occ,
                    );
                } else if outcome.sampled {
                    self.estimates.record(
                        key,
                        cluster_idx,
                        outcome.surface_idx,
                        outcome.intensity,
                        self.config.estimate.lead_confidence,
                        generation,
                        occ,
                    );
                } else {
                    // Never sampled: the clean bulk run is the only
                    // evidence, so reinforce the warm-start record.
                    self.estimates.reinforce(
                        key,
                        cluster_idx,
                        outcome.surface_idx,
                        outcome.intensity,
                        generation,
                        occ,
                    );
                }
            }
            (None, _) => {
                self.stats.leader_aborts.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }

    /// Settle a piggybacked or estimate-served run: earn bulk credit
    /// and feed the estimate passively — a drift re-tune re-points it
    /// at the monitor's new surface, a clean completion reinforces it.
    pub fn finish_passive(
        &self,
        key: ShardKey,
        cluster_idx: Option<usize>,
        outcome: Option<AsmOutcome>,
        report: &RunReport,
        generation: u64,
        occ: ProbeOcc,
    ) {
        let (sample_mb, bulk_mb) = split_bytes(report);
        self.stats.note_bytes(sample_mb, bulk_mb);
        self.budget(key).credit(bulk_mb * self.config.budget.earn_fraction);
        if let (Some(outcome), Some(cluster_idx)) = (outcome, cluster_idx) {
            if report.bulk_retunes() > 0 {
                self.estimates.record(
                    key,
                    cluster_idx,
                    outcome.surface_idx,
                    outcome.intensity,
                    self.config.estimate.drift_confidence,
                    generation,
                    occ,
                );
            } else {
                self.estimates.reinforce(
                    key,
                    cluster_idx,
                    outcome.surface_idx,
                    outcome.intensity,
                    generation,
                    occ,
                );
            }
        }
    }

    /// The probe metrics block (rendered by `coordinator::Metrics`).
    pub fn render(&self) -> String {
        let led = self.stats.led.load(Ordering::Relaxed);
        let piggybacked = self.stats.piggybacked.load(Ordering::Relaxed);
        let estimate_served = self.stats.estimate_served.load(Ordering::Relaxed);
        let admissions = led + piggybacked + estimate_served;
        let reuse_pct = if admissions > 0 {
            100.0 * (piggybacked + estimate_served) as f64 / admissions as f64
        } else {
            0.0
        };
        let (sample_mb, bulk_mb) = self.stats.bytes();
        let total_mb = sample_mb + bulk_mb;
        let overhead_pct = if total_mb > 0.0 { 100.0 * sample_mb / total_mb } else { 0.0 };
        let mut out = format!(
            "probe plane: {led} led, {piggybacked} piggybacked, {estimate_served} estimate-served \
             ({} budget-forced), {} follower timeouts, {} leader aborts\n\
             probe bytes: {sample_mb:.0} MB sampled of {total_mb:.0} MB moved \
             ({overhead_pct:.2}% overhead), estimate reuse {reuse_pct:.0}% of {admissions} admissions\n",
            self.stats.budget_forced.load(Ordering::Relaxed),
            self.stats.follower_timeouts.load(Ordering::Relaxed),
            self.stats.leader_aborts.load(Ordering::Relaxed),
        );
        for (key, est) in self.estimates.entries() {
            let budget = self.budget(key);
            out.push_str(&format!(
                "  {}: surface {} (intensity {:.2}), confidence {:.2} @gen {}, \
                 budget {:.0}/{:.0} MB\n",
                key.name(),
                est.surface_idx,
                est.intensity,
                est.decayed(self.estimates.config(), est.generation),
                est.generation,
                budget.available_mb(),
                budget.capacity_mb(),
            ));
        }
        out
    }
}

impl std::fmt::Debug for ProbePlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProbePlane")
            .field("estimates", &self.estimates.len())
            .field("in_flight", &self.flights.in_flight())
            .field("admissions", &self.stats.admissions())
            .finish()
    }
}

fn split_bytes(report: &RunReport) -> (f64, f64) {
    let sample_mb: f64 =
        report.phases.iter().filter(|p| p.is_sample).map(|p| p.mb).sum();
    (sample_mb, report.total_mb() - sample_mb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Phase;
    use crate::sim::dataset::SizeClass;
    use crate::sim::params::Params;
    use crate::sim::testbed::TestbedId;

    fn key() -> ShardKey {
        ShardKey::new(TestbedId::Xsede, SizeClass::Large)
    }

    fn outcome(surface_idx: usize, sampled: bool) -> AsmOutcome {
        AsmOutcome { surface_idx, converged_idx: surface_idx, sampled, intensity: 0.5 }
    }

    fn report(sample_mb: f64, bulk_params: &[Params]) -> RunReport {
        let mut phases = Vec::new();
        if sample_mb > 0.0 {
            phases.push(Phase {
                params: Params::new(2, 2, 2),
                mb: sample_mb,
                seconds: 2.0,
                steady_mbps: 1_000.0,
                is_sample: true,
            });
        }
        for &params in bulk_params {
            phases.push(Phase {
                params,
                mb: 500.0,
                seconds: 4.0,
                steady_mbps: 1_000.0,
                is_sample: false,
            });
        }
        RunReport {
            optimizer: "ASM",
            phases,
            final_params: *bulk_params.last().unwrap(),
            predicted_mbps: Some(1_000.0),
        }
    }

    #[test]
    fn lead_then_confident_estimate_is_served() {
        let plane = ProbePlane::default();
        let guard = match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { guard, warm_start } => {
                assert!(warm_start.is_none(), "no estimate yet");
                guard
            }
            _ => panic!("cold plane must lead"),
        };
        // Convergence releases the flight and records the estimate...
        plane.lead_converged(key(), Some(0), guard, outcome(3, true), 0, ProbeOcc::default());
        // ...and the post-transfer settlement charges the budget.
        plane.finish_led(
            key(),
            Some(0),
            Some(outcome(3, true)),
            &report(50.0, &[Params::new(4, 4, 2)]),
            10.0,
            0,
            ProbeOcc::default(),
        );
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Serve(Some(3)) => {}
            Admission::Serve(other) => panic!("served the wrong surface: {other:?}"),
            _ => panic!("fresh confident estimate must be served"),
        }
        // A request mapping to a *different* cluster must not be served
        // this cluster's surface index; it leads its own ladder.
        match plane.admit(key(), Some(1), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { warm_start: None, .. } => {}
            _ => panic!("another cluster's estimate must not short-circuit"),
        }
        assert_eq!(plane.stats.led.load(Ordering::Relaxed), 2);
        assert_eq!(plane.stats.estimate_served.load(Ordering::Relaxed), 1);
        let rendered = plane.render();
        assert!(rendered.contains("probe plane: 2 led"), "{rendered}");
        assert!(rendered.contains("xsede/large"), "{rendered}");
    }

    #[test]
    fn exhausted_budget_forces_estimate_reuse() {
        let plane = ProbePlane::new(ProbeConfig {
            budget: BudgetConfig { capacity_mb: 100.0, initial_mb: 20.0, earn_fraction: 0.0 },
            ..Default::default()
        });
        // Over budget with no estimate at all: median, no sampling.
        match plane.admit(key(), Some(0), 0, 50.0, ProbeOcc::default()) {
            Admission::Serve(None) => {}
            _ => panic!("exhausted budget must force estimate reuse"),
        }
        assert_eq!(plane.stats.budget_forced.load(Ordering::Relaxed), 1);
        // Within budget: lead (and pay).
        match plane.admit(key(), Some(0), 0, 15.0, ProbeOcc::default()) {
            Admission::Lead { .. } => {}
            _ => panic!("affordable probe must lead"),
        }
        assert!(plane.budget(key()).available_mb() < 20.0);
        // The guard dropped above (abort); a low-confidence estimate
        // plus no budget serves that estimate, not the median.
        plane.finish_passive(
            key(),
            Some(0),
            Some(outcome(4, false)),
            &report(0.0, &[Params::new(4, 4, 2)]),
            0,
            ProbeOcc::default(),
        );
        match plane.admit(key(), Some(0), 0, 50.0, ProbeOcc::default()) {
            Admission::Serve(Some(4)) => {}
            _ => panic!("budget-forced reuse must still prefer the estimate"),
        }
    }

    #[test]
    fn generation_bump_degrades_confidence_to_warm_start() {
        let plane = ProbePlane::default();
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { guard, .. } => {
                plane.lead_converged(key(), Some(0), guard, outcome(3, true), 0, ProbeOcc::default());
                plane.finish_led(
                    key(),
                    Some(0),
                    Some(outcome(3, true)),
                    &report(50.0, &[Params::new(4, 4, 2)]),
                    10.0,
                    0,
            ProbeOcc::default(),
        );
            }
            _ => panic!("cold plane must lead"),
        }
        // Same generation: confident serve. New generation: the 0.5
        // penalty drops it below the 0.6 threshold, so the request
        // leads again — warm-started at the old surface.
        match plane.admit(key(), Some(0), 1, 10.0, ProbeOcc::default()) {
            Admission::Lead { warm_start: Some(3), .. } => {}
            _ => panic!("generation bump must demote the estimate to a warm start"),
        }
    }

    #[test]
    fn occupancy_shift_demotes_estimate_to_warm_start() {
        let plane = ProbePlane::default();
        let quiet = ProbeOcc::default();
        let convoy = ProbeOcc { epoch: 4, streams: 48 };
        // Learn the network on a quiet link.
        let guard = match plane.admit(key(), Some(0), 0, 10.0, quiet) {
            Admission::Lead { guard, .. } => guard,
            _ => panic!("cold plane must lead"),
        };
        plane.lead_converged(key(), Some(0), guard, outcome(3, true), 0, quiet);
        plane.finish_led(
            key(),
            Some(0),
            Some(outcome(3, true)),
            &report(50.0, &[Params::new(4, 4, 2)]),
            10.0,
            0,
            quiet,
        );
        // Same occupancy class: confident serve.
        match plane.admit(key(), Some(0), 0, 10.0, quiet) {
            Admission::Serve(Some(3)) => {}
            _ => panic!("quiet estimate serves quiet admissions"),
        }
        // A convoy arrives: quiet knowledge is demoted to a warm start
        // and the request re-samples under the contention it will
        // actually transfer under.
        let guard = match plane.admit(key(), Some(0), 0, 10.0, convoy) {
            Admission::Lead { guard, warm_start: Some(3) } => guard,
            _ => panic!("occupancy shift must demote the estimate to a warm start"),
        };
        // The convoy-learned surface serves convoy admissions, but not
        // quiet ones after the convoy drains.
        plane.lead_converged(key(), Some(0), guard, outcome(7, true), 0, convoy);
        match plane.admit(key(), Some(0), 0, 10.0, convoy) {
            Admission::Serve(Some(7)) => {}
            _ => panic!("convoy estimate serves convoy admissions"),
        }
        match plane.admit(key(), Some(0), 0, 10.0, quiet) {
            Admission::Lead { warm_start: Some(7), .. } => {}
            _ => panic!("convoy knowledge must not be served as quiet-network truth"),
        }
    }

    #[test]
    fn passive_drift_repoints_the_estimate() {
        let plane = ProbePlane::default();
        // Two bulk phases with different params ⇒ one drift re-tune.
        let drifted = report(0.0, &[Params::new(4, 4, 2), Params::new(8, 2, 2)]);
        assert_eq!(drifted.bulk_retunes(), 1);
        plane.finish_passive(key(), Some(0), Some(outcome(4, false)), &drifted, 0, ProbeOcc::default());
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Serve(Some(4)) => {}
            _ => panic!("drift confidence (0.7) clears the serve threshold"),
        }
    }

    #[test]
    fn starved_budget_forces_estimate_reuse_until_bulk_earns() {
        let plane = ProbePlane::new(ProbeConfig {
            budget: BudgetConfig { capacity_mb: 500.0, initial_mb: 500.0, earn_fraction: 0.1 },
            ..Default::default()
        });
        plane.starve_budget(key());
        assert_eq!(plane.budget(key()).available_mb(), 0.0);
        match plane.admit(key(), Some(0), 0, 50.0, ProbeOcc::default()) {
            Admission::Serve(None) => {}
            _ => panic!("starved budget must force estimate reuse"),
        }
        assert_eq!(plane.stats.budget_forced.load(Ordering::Relaxed), 1);
        // Bulk traffic earns tokens back; probing resumes.
        plane.finish_passive(
            key(),
            None,
            None,
            &report(0.0, &[Params::new(4, 4, 2)]),
            0,
            ProbeOcc::default(),
        );
        assert!(plane.budget(key()).available_mb() > 0.0);
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { .. } => {}
            _ => panic!("earned budget must allow probing again"),
        }
    }

    #[test]
    fn bulk_bytes_earn_budget_back() {
        let plane = ProbePlane::new(ProbeConfig {
            budget: BudgetConfig { capacity_mb: 1_000.0, initial_mb: 0.0, earn_fraction: 0.1 },
            ..Default::default()
        });
        // 1000 MB of bulk at 10% earn = 100 MB of tokens.
        plane.finish_passive(
            key(),
            None,
            None,
            &report(0.0, &[Params::new(4, 4, 2), Params::new(4, 4, 2)]),
            0,
            ProbeOcc::default(),
        );
        let available = plane.budget(key()).available_mb();
        assert!((available - 100.0).abs() < 1e-6, "earned {available}");
    }

    #[test]
    fn unsampled_leader_warm_starts_but_never_suppresses_sampling() {
        let plane = ProbePlane::default();
        let guard = match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { guard, .. } => guard,
            _ => panic!("cold plane must lead"),
        };
        // Short-transfer fast path: the ladder ran zero samples. The
        // surface is an unmeasured guess — followers must not inherit
        // it as a result, and later requests must still sample.
        plane.lead_converged(key(), Some(0), guard, outcome(5, false), 0, ProbeOcc::default());
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { warm_start: Some(5), .. } => {}
            Admission::Serve(_) => panic!("unmeasured guess must not be served outright"),
            _ => panic!("next request must lead, warm-started at the guess"),
        }
        // Clean bulk completions reinforce the guess over the serve
        // threshold (0.5 → +0.1 per confirmation, decay in between) —
        // after two it has real evidence behind it.
        for _ in 0..2 {
            plane.finish_led(
                key(),
                Some(0),
                Some(outcome(5, false)),
                &report(0.0, &[Params::new(4, 4, 2)]),
                10.0,
                0,
            ProbeOcc::default(),
        );
        }
        match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Serve(Some(5)) => {}
            _ => panic!("bulk-confirmed estimate clears the threshold"),
        }
    }

    #[test]
    fn drift_inferred_final_surface_gets_moderate_confidence() {
        let plane = ProbePlane::default();
        // Sampled ladder converged on 3, drift re-tuned to 8 mid-bulk:
        // the final surface is the monitor's inference, not a probe.
        let drifted = report(50.0, &[Params::new(4, 4, 2), Params::new(8, 2, 2)]);
        assert_eq!(drifted.bulk_retunes(), 1);
        plane.finish_led(
            key(),
            Some(0),
            Some(AsmOutcome { surface_idx: 8, converged_idx: 3, sampled: true, intensity: 0.9 }),
            &drifted,
            10.0,
            0,
            ProbeOcc::default(),
        );
        let (est, confidence) = plane.estimates.current(key(), 0, 0, ProbeOcc::default()).unwrap();
        assert_eq!(est.surface_idx, 8);
        assert!(
            (confidence - plane.config.estimate.drift_confidence).abs() < 0.01,
            "drift-inferred surface recorded at {confidence}, want drift confidence"
        );
    }

    #[test]
    fn followers_release_at_convergence_not_transfer_end() {
        let plane = Arc::new(ProbePlane::default());
        let guard = match plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()) {
            Admission::Lead { guard, .. } => guard,
            _ => panic!("cold plane must lead"),
        };
        let follower = {
            let plane = plane.clone();
            std::thread::spawn(move || plane.admit(key(), Some(0), 0, 10.0, ProbeOcc::default()))
        };
        // Simulate the mid-run convergence hook firing while the
        // leader's bulk transfer is still in progress.
        std::thread::sleep(Duration::from_millis(10));
        plane.lead_converged(key(), Some(0), guard, outcome(2, true), 0, ProbeOcc::default());
        match follower.join().unwrap() {
            // Piggybacked on the converged ladder, or admitted after the
            // estimate was already recorded — either way, no re-probe.
            Admission::Piggyback(result) => assert_eq!(result.surface_idx, 2),
            Admission::Serve(Some(2)) => {}
            _ => panic!("follower must reuse the converged ladder"),
        }
        // The bulk transfer "completes" much later; settlement only
        // touches the budget and the estimate.
        plane.finish_led(
            key(),
            Some(0),
            Some(outcome(2, true)),
            &report(50.0, &[Params::new(4, 4, 2)]),
            10.0,
            0,
            ProbeOcc::default(),
        );
    }
}
