//! Log-bucketed streaming histogram: the bounded-memory backbone of
//! every latency/throughput aggregate in [`crate::coordinator::metrics`].
//!
//! ## Shape
//!
//! Values are folded into geometric buckets with ratio `GAMMA = 1.01`:
//! bucket `i` covers `[GAMMA^i, GAMMA^(i+1))`. Each bucket keeps its
//! exact count **and** exact sum, so its representative is the bucket
//! *mean* — a singleton bucket reproduces its value bit-for-bit, which
//! is what keeps small-sample quantiles (and the metrics golden
//! fixture) identical to the exact [`crate::util::stats::quantile`].
//! Non-positive values (achieved-zero samples, zero wall times) land in
//! a dedicated zero bucket whose representative is exactly `0.0`;
//! non-finite inputs are ignored outright.
//!
//! ## Guarantees
//!
//! * **Bounded memory** — the bucket count is `O(log(max/min)/log γ)`,
//!   independent of how many values are recorded. Nanoseconds across
//!   `[1, 10^12]` need fewer than 2 800 buckets; a metrics stream
//!   confined to a realistic band uses far fewer.
//! * **≤ 1% relative quantile error** — every recorded value differs
//!   from its bucket mean by at most a factor of γ, so any quantile
//!   (an interpolation between two order statistics, each off by at
//!   most γ−1 relatively) is within γ−1 = 1% of the exact quantile
//!   over the same data.
//! * **Mergeable** — [`LogHistogram::merge`] adds bucket contents;
//!   counts merge exactly, sums commute exactly and associate to
//!   within f64 rounding.
//! * **Exact mean** — the global sum and count are kept verbatim, so
//!   [`LogHistogram::mean`] carries no bucketing error at all.
//!
//! The empty-histogram quantile is `0.0`, exactly like
//! [`crate::util::stats::quantile`] on an empty slice.

use crate::util::json::{Json, JsonError};
use std::collections::BTreeMap;

/// Geometric bucket ratio. γ−1 bounds the relative quantile error.
pub const GAMMA: f64 = 1.01;

#[derive(Debug, Clone, Copy, PartialEq, Default)]
struct Bucket {
    count: u64,
    sum: f64,
}

/// A mergeable streaming histogram with geometric buckets (see the
/// module docs for the guarantees).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LogHistogram {
    /// Sparse geometric buckets, keyed by `floor(ln(x)/ln γ)`.
    buckets: BTreeMap<i32, Bucket>,
    /// Count of non-positive (clamped-to-zero) values.
    zero: u64,
    /// Exact totals over everything recorded (zero bucket included).
    count: u64,
    sum: f64,
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value. Non-finite values are ignored; values ≤ 0 are
    /// clamped into the zero bucket (their clamped value still feeds
    /// the exact sum, so the mean of e.g. `[0.0, 2.0]` stays exact).
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        self.count += 1;
        self.sum += x;
        if x <= 0.0 {
            self.zero += 1;
            return;
        }
        let idx = (x.ln() / GAMMA.ln()).floor() as i32;
        let bucket = self.buckets.entry(idx).or_default();
        bucket.count += 1;
        bucket.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact arithmetic mean (0 when empty, like
    /// [`crate::util::stats::mean`]).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Live bucket count (zero bucket included when occupied) — the
    /// memory footprint, bounded regardless of record volume.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len() + usize::from(self.zero > 0)
    }

    /// p-quantile by the same linear interpolation as
    /// [`crate::util::stats::quantile`], over bucket means instead of
    /// raw order statistics. Empty histogram returns 0.0, exactly like
    /// `quantile(&[], p)`.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let pos = p.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let lo = pos.floor() as u64;
        let hi = pos.ceil() as u64;
        let v_lo = self.value_at_rank(lo);
        if lo == hi {
            v_lo
        } else {
            let v_hi = self.value_at_rank(hi);
            v_lo + (pos - lo as f64) * (v_hi - v_lo)
        }
    }

    /// The bucket-mean representative of the `rank`-th smallest
    /// recorded value (0-based).
    fn value_at_rank(&self, rank: u64) -> f64 {
        let mut seen = self.zero;
        if rank < seen {
            return 0.0;
        }
        for bucket in self.buckets.values() {
            seen += bucket.count;
            if rank < seen {
                return bucket.sum / bucket.count as f64;
            }
        }
        // rank >= count only via floating-point edge; clamp to the max.
        self.buckets
            .values()
            .next_back()
            .map_or(0.0, |b| b.sum / b.count as f64)
    }

    /// Fold `other` into `self`. Counts merge exactly; sums commute
    /// exactly and associate to within f64 rounding.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (idx, b) in &other.buckets {
            let bucket = self.buckets.entry(*idx).or_default();
            bucket.count += b.count;
            bucket.sum += b.sum;
        }
        self.zero += other.zero;
        self.count += other.count;
        self.sum += other.sum;
    }

    /// The per-bucket difference `self − earlier`: what was recorded
    /// *since* the `earlier` cut, assuming `earlier` is a prefix of
    /// `self`'s recording history (the cumulative-snapshot case the
    /// windowed telemetry layer subtracts over). Counts subtract
    /// saturating per bucket — a non-prefix `earlier` can never drive a
    /// count negative — and each surviving bucket's sum is clamped to
    /// ≥ 0 (zeroed when its count hits 0). The global count and sum are
    /// recomputed from the surviving buckets, preserving the
    /// `count == zero + Σ bucket counts` invariant `from_json` checks.
    pub fn subtract(&self, earlier: &LogHistogram) -> LogHistogram {
        let mut buckets = BTreeMap::new();
        for (idx, b) in &self.buckets {
            let prev = earlier.buckets.get(idx).copied().unwrap_or_default();
            let count = b.count.saturating_sub(prev.count);
            if count == 0 {
                continue;
            }
            let sum = (b.sum - prev.sum).max(0.0);
            buckets.insert(*idx, Bucket { count, sum });
        }
        let zero = self.zero.saturating_sub(earlier.zero);
        let count = zero + buckets.values().map(|b| b.count).sum::<u64>();
        let sum = buckets.values().map(|b| b.sum).sum::<f64>();
        LogHistogram { buckets, zero, count, sum }
    }

    /// JSON encoding: `{"gamma":1.01,"count":N,"sum":S,"zero":Z,
    /// "buckets":[[idx,count,sum],...]}` (buckets ascending by index).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("gamma", Json::Num(GAMMA))
            .set("count", Json::Num(self.count as f64))
            .set("sum", Json::Num(self.sum))
            .set("zero", Json::Num(self.zero as f64))
            .set(
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|(idx, b)| {
                            Json::Arr(vec![
                                Json::Num(*idx as f64),
                                Json::Num(b.count as f64),
                                Json::Num(b.sum),
                            ])
                        })
                        .collect(),
                ),
            );
        obj
    }

    /// Decode [`LogHistogram::to_json`] output, validating that the
    /// total count equals the zero bucket plus every bucket count.
    pub fn from_json(value: &Json) -> Result<LogHistogram, JsonError> {
        let count = value
            .get("count")
            .and_then(Json::as_u64)
            .ok_or_else(|| JsonError { message: "missing/invalid 'count'".into() })?;
        let sum = value.req_f64("sum")?;
        let zero = value
            .get("zero")
            .and_then(Json::as_u64)
            .ok_or_else(|| JsonError { message: "missing/invalid 'zero'".into() })?;
        let mut buckets = BTreeMap::new();
        let mut bucketed = 0u64;
        for entry in value.req_arr("buckets")? {
            let triple = entry.as_arr().filter(|t| t.len() == 3).ok_or_else(|| JsonError {
                message: "histogram bucket is not an [idx,count,sum] triple".into(),
            })?;
            let idx = triple[0]
                .as_f64()
                .filter(|x| x.fract() == 0.0)
                .map(|x| x as i32)
                .ok_or_else(|| JsonError { message: "non-integer bucket index".into() })?;
            let bucket_count = triple[1]
                .as_u64()
                .ok_or_else(|| JsonError { message: "invalid bucket count".into() })?;
            let bucket_sum = triple[2]
                .as_f64()
                .ok_or_else(|| JsonError { message: "invalid bucket sum".into() })?;
            bucketed += bucket_count;
            buckets.insert(idx, Bucket { count: bucket_count, sum: bucket_sum });
        }
        if zero + bucketed != count {
            return Err(JsonError {
                message: format!(
                    "inconsistent histogram: count {} != zero {} + bucketed {}",
                    count, zero, bucketed
                ),
            });
        }
        Ok(LogHistogram { buckets, zero, count, sum })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, gen, Config};
    use crate::util::stats::quantile;

    fn hist_of(xs: &[f64]) -> LogHistogram {
        let mut h = LogHistogram::new();
        for &x in xs {
            h.record(x);
        }
        h
    }

    #[test]
    fn empty_histogram_matches_exact_quantile() {
        let h = LogHistogram::new();
        for p in [0.0, 0.5, 0.99, 1.0] {
            // Literal equality with the exact implementation's empty-slice
            // behavior (0.0), not an assumed NaN.
            assert_eq!(h.quantile(p), quantile(&[], p));
        }
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.bucket_count(), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn distinct_values_reproduce_exact_quantiles_bitwise() {
        // Values whose pairwise ratios all exceed γ occupy singleton
        // buckets, so the bucket-mean representatives are the values
        // themselves and interpolation matches util::stats::quantile
        // bit-for-bit. This is the property the metrics golden fixture
        // leans on.
        let xs = [10_000.0, 20_000.0, 30_000.0, 40_000.0];
        let h = hist_of(&xs);
        for p in [0.0, 0.25, 0.5, 0.75, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(p), quantile(&xs, p), "p={p}");
        }
        assert_eq!(h.quantile(0.5), 25_000.0);
    }

    #[test]
    fn mean_is_exact() {
        let h = hist_of(&[1000.0, 2000.0]);
        assert_eq!(h.mean(), 1500.0);
        let with_zero = hist_of(&[2.0, 0.0]);
        assert_eq!(with_zero.mean(), 1.0);
    }

    #[test]
    fn zero_and_negative_values_clamp_into_zero_bucket() {
        let h = hist_of(&[0.0, -5.0, 100.0]);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(1.0), 100.0);
        // The clamped values contribute 0 to the sum.
        assert!((h.mean() - 100.0 / 3.0).abs() < 1e-12);
        assert_eq!(h.bucket_count(), 2);
    }

    #[test]
    fn non_finite_values_are_ignored() {
        let h = hist_of(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 7.0]);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile(0.5), 7.0);
    }

    #[test]
    fn quantile_error_is_within_documented_bound() {
        forall(
            Config { cases: 200, seed: 0x415_7 },
            |rng| gen::vec_f64(rng, 1, 200, 1e-3, 1e9),
            |xs| {
                let h = hist_of(xs);
                for p in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                    let exact = quantile(xs, p);
                    let est = h.quantile(p);
                    let tol = (GAMMA - 1.0) * exact.abs() + 1e-9;
                    if (est - exact).abs() > tol {
                        return Err(format!(
                            "p={p}: est {est} vs exact {exact} (tol {tol})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_is_commutative_exactly() {
        forall(
            Config { cases: 100, seed: 0x4D_31 },
            |rng| {
                (
                    gen::vec_f64(rng, 0, 60, 1e-2, 1e7),
                    gen::vec_f64(rng, 0, 60, 1e-2, 1e7),
                )
            },
            |(a, b)| {
                let (ha, hb) = (hist_of(a), hist_of(b));
                let mut ab = ha.clone();
                ab.merge(&hb);
                let mut ba = hb.clone();
                ba.merge(&ha);
                // f64 addition commutes, so the merge does too — exactly.
                if ab != ba {
                    return Err("merge not commutative".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_is_associative_within_rounding() {
        forall(
            Config { cases: 100, seed: 0x4D_32 },
            |rng| {
                (
                    gen::vec_f64(rng, 0, 40, 1e-2, 1e7),
                    gen::vec_f64(rng, 0, 40, 1e-2, 1e7),
                    gen::vec_f64(rng, 0, 40, 1e-2, 1e7),
                )
            },
            |(a, b, c)| {
                let (ha, hb, hc) = (hist_of(a), hist_of(b), hist_of(c));
                // (a ⊔ b) ⊔ c
                let mut left = ha.clone();
                left.merge(&hb);
                left.merge(&hc);
                // a ⊔ (b ⊔ c)
                let mut bc = hb.clone();
                bc.merge(&hc);
                let mut right = ha.clone();
                right.merge(&bc);
                if left.count() != right.count() {
                    return Err("associativity broke counts".into());
                }
                // Sums may differ across association order by f64
                // rounding only.
                let scale = left.mean().abs().max(1.0);
                if (left.mean() - right.mean()).abs() > 1e-12 * scale {
                    return Err(format!(
                        "means diverged: {} vs {}",
                        left.mean(),
                        right.mean()
                    ));
                }
                for p in [0.1, 0.5, 0.9] {
                    let (ql, qr) = (left.quantile(p), right.quantile(p));
                    let scale = ql.abs().max(1.0);
                    if (ql - qr).abs() > 1e-12 * scale {
                        return Err(format!("p={p} quantiles diverged: {ql} vs {qr}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let h = hist_of(&[1.0, 10.0, 100.0]);
        let mut merged = h.clone();
        merged.merge(&LogHistogram::new());
        assert_eq!(merged, h);
        let mut empty = LogHistogram::new();
        empty.merge(&h);
        assert_eq!(empty, h);
    }

    #[test]
    fn merge_matches_recording_everything_into_one() {
        let a = [5.0, 50.0, 500.0];
        let b = [7.0, 70.0];
        let mut merged = hist_of(&a);
        merged.merge(&hist_of(&b));
        let whole = hist_of(&[5.0, 50.0, 500.0, 7.0, 70.0]);
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.bucket_count(), whole.bucket_count());
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(merged.quantile(p), whole.quantile(p));
        }
    }

    #[test]
    fn bucket_count_is_bounded_over_100k_records() {
        // The regression the ISSUE demands: memory must not grow with
        // record volume. 100k values across six decades fit in the
        // analytic bucket bound log(1e6)/log(γ) ≈ 1 389 (+1 for zero).
        let mut h = LogHistogram::new();
        let mut rng = crate::util::rng::Rng::new(0xB0_07);
        for _ in 0..100_000 {
            h.record(rng.range_f64(1.0, 1e6));
        }
        assert_eq!(h.count(), 100_000);
        let bound = ((1e6f64).ln() / GAMMA.ln()).ceil() as usize + 1;
        assert!(
            h.bucket_count() <= bound,
            "bucket count {} exceeded analytic bound {}",
            h.bucket_count(),
            bound
        );
        // And it stays put: recording the same range again adds nothing.
        let before = h.bucket_count();
        for _ in 0..10_000 {
            h.record(rng.range_f64(1.0, 1e6));
        }
        assert_eq!(h.bucket_count(), before, "steady-state bucket count moved");
    }

    #[test]
    fn subtract_recovers_the_suffix_of_a_prefix_snapshot() {
        // earlier is a prefix of later's recording history: the
        // difference is exactly the histogram of the suffix.
        let prefix = [0.0, 3.5, 42.0];
        let suffix = [3.5, 7.0, 1e6];
        let earlier = hist_of(&prefix);
        let mut later = earlier.clone();
        for &x in &suffix {
            later.record(x);
        }
        let delta = later.subtract(&earlier);
        let expect = hist_of(&suffix);
        assert_eq!(delta.count(), expect.count());
        assert!((delta.mean() - expect.mean()).abs() < 1e-9);
        for p in [0.0, 0.5, 1.0] {
            assert!((delta.quantile(p) - expect.quantile(p)).abs() < 1e-9, "p={p}");
        }
        // The result survives the JSON roundtrip's consistency check.
        let text = delta.to_json().to_string_compact();
        assert!(LogHistogram::from_json(&Json::parse(&text).unwrap()).is_ok());
        // Subtracting self is empty; subtracting empty is identity.
        assert!(later.subtract(&later).is_empty());
        assert_eq!(later.subtract(&LogHistogram::new()), later);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let h = hist_of(&[0.0, 3.5, 3.5, 42.0, 1e6]);
        let text = h.to_json().to_string_compact();
        let back = LogHistogram::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, h);
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(back.quantile(p), h.quantile(p));
        }
    }

    #[test]
    fn from_json_rejects_inconsistent_counts() {
        let text = r#"{"gamma":1.01,"count":5,"sum":10.0,"zero":0,"buckets":[[0,2,2.0]]}"#;
        let err = LogHistogram::from_json(&Json::parse(text).unwrap()).unwrap_err();
        assert!(err.message.contains("inconsistent"), "{}", err.message);
    }
}
