//! Deterministic exporters over a registry [`Snapshot`]: Prometheus
//! text exposition and JSON (via [`crate::util::json`]).
//!
//! Both exporters are pure functions of the snapshot: iteration order
//! is the snapshot's `BTreeMap` order, float formatting is the same
//! shortest-roundtrip form `util::json` uses, and nothing wall-clock
//! ever enters a snapshot destined for export (the registry's
//! publishers exclude wall-time families — see `DESIGN.md` §Fleet
//! health plane, determinism contract). Two same-seed runs therefore
//! produce byte-identical exports, which CI enforces by diffing
//! (`obs-conformance`).
//!
//! Histograms export as Prometheus *summaries* (rolling quantiles +
//! exact sum/count) rather than fixed le-buckets: the log-bucketed
//! [`crate::telemetry::LogHistogram`] keeps ≤1% quantile error, and a
//! summary is byte-stable where a re-bucketing to static boundaries
//! would invent precision. The JSON form additionally carries the full
//! mergeable histogram encoding, so downstream consumers can aggregate
//! exports exactly.

use super::hist::LogHistogram;
use super::registry::{Snapshot, Value};
use crate::util::json::Json;

/// Sanitize a hierarchical metric name into the Prometheus charset
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every other byte becomes `_`, and a
/// leading digit gets a `_` prefix.
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            if i == 0 && c.is_ascii_digit() {
                out.push('_');
            }
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Deterministic number formatting, matching `util::json`'s: integral
/// values in f64-exact range print without a fraction, everything else
/// prints shortest-roundtrip. Non-finite becomes `NaN` (Prometheus
/// accepts it; it never appears in practice).
fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        "NaN".to_string()
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

fn hist_sum(h: &LogHistogram) -> f64 {
    h.mean() * h.count() as f64
}

/// Render a snapshot as Prometheus text exposition (0.0.4 format).
pub fn to_prometheus(snapshot: &Snapshot) -> String {
    let mut out = String::new();
    for (name, value) in &snapshot.values {
        let name = sanitize(name);
        match value {
            Value::Counter(v) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
            }
            Value::Gauge(v) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", fmt_num(*v)));
            }
            Value::Hist(h) => {
                out.push_str(&format!("# TYPE {name} summary\n"));
                for (label, p) in [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99)] {
                    out.push_str(&format!(
                        "{name}{{quantile=\"{label}\"}} {}\n",
                        fmt_num(h.quantile(p))
                    ));
                }
                out.push_str(&format!("{name}_sum {}\n", fmt_num(hist_sum(h))));
                out.push_str(&format!("{name}_count {}\n", h.count()));
            }
        }
    }
    out
}

/// Render a snapshot as one JSON object keyed by metric name.
/// Counters and gauges are plain numbers; histograms are objects with
/// quantiles plus the full mergeable encoding.
pub fn to_json(snapshot: &Snapshot) -> Json {
    let mut obj = Json::obj();
    for (name, value) in &snapshot.values {
        match value {
            Value::Counter(v) => {
                obj.set(name, Json::Num(*v as f64));
            }
            Value::Gauge(v) => {
                obj.set(name, Json::Num(*v));
            }
            Value::Hist(h) => {
                let mut entry = Json::obj();
                entry
                    .set("count", Json::Num(h.count() as f64))
                    .set("sum", Json::Num(hist_sum(h)))
                    .set("p50", Json::Num(h.quantile(0.5)))
                    .set("p90", Json::Num(h.quantile(0.9)))
                    .set("p99", Json::Num(h.quantile(0.99)))
                    .set("histogram", h.to_json());
                obj.set(name, entry);
            }
        }
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::Registry;

    fn sample_snapshot() -> Snapshot {
        let reg = Registry::new();
        reg.counter("feedback.rows_dropped").unwrap().add(7);
        reg.gauge("probe.budget.xsede/large.available_mb").unwrap().set(512.5);
        let h = reg.histogram("coordinator.asm.achieved_mbps").unwrap();
        h.record(1000.0);
        h.record(2000.0);
        reg.snapshot()
    }

    #[test]
    fn sanitize_maps_hierarchical_names_into_the_prom_charset() {
        assert_eq!(sanitize("probe.budget.spent_mb"), "probe_budget_spent_mb");
        assert_eq!(sanitize("fabric.shard.xsede/large.rows"), "fabric_shard_xsede_large_rows");
        assert_eq!(sanitize("9lives"), "_9lives");
        assert_eq!(sanitize("a-b c"), "a_b_c");
        assert_eq!(sanitize(""), "_");
    }

    #[test]
    fn prometheus_exposition_covers_all_three_kinds() {
        let text = to_prometheus(&sample_snapshot());
        assert!(text.contains("# TYPE feedback_rows_dropped counter\nfeedback_rows_dropped 7\n"), "{text}");
        assert!(
            text.contains("# TYPE probe_budget_xsede_large_available_mb gauge\nprobe_budget_xsede_large_available_mb 512.5\n"),
            "{text}"
        );
        assert!(text.contains("# TYPE coordinator_asm_achieved_mbps summary\n"), "{text}");
        assert!(text.contains("coordinator_asm_achieved_mbps{quantile=\"0.5\"} 1500\n"), "{text}");
        assert!(text.contains("coordinator_asm_achieved_mbps_sum 3000\n"), "{text}");
        assert!(text.contains("coordinator_asm_achieved_mbps_count 2\n"), "{text}");
    }

    #[test]
    fn json_export_parses_back_and_keeps_the_histogram_mergeable(
    ) {
        let json = to_json(&sample_snapshot());
        let text = json.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("feedback.rows_dropped").and_then(Json::as_u64), Some(7));
        let hist_entry = back.get("coordinator.asm.achieved_mbps").unwrap();
        assert_eq!(hist_entry.get("count").and_then(Json::as_u64), Some(2));
        let decoded =
            LogHistogram::from_json(hist_entry.get("histogram").unwrap()).unwrap();
        assert_eq!(decoded.count(), 2);
        assert_eq!(decoded.mean(), 1500.0);
    }

    #[test]
    fn exports_are_deterministic_for_equal_snapshots() {
        // Two independently built but identical snapshots must render
        // byte-identically in both formats — the contract the
        // obs-conformance CI job enforces end to end.
        let (a, b) = (sample_snapshot(), sample_snapshot());
        assert_eq!(to_prometheus(&a), to_prometheus(&b));
        assert_eq!(to_json(&a).to_string_compact(), to_json(&b).to_string_compact());
    }

    #[test]
    fn non_integral_and_large_values_format_stably() {
        assert_eq!(fmt_num(0.93), "0.93");
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(1e16), "10000000000000000");
        assert_eq!(fmt_num(f64::NAN), "NaN");
    }
}
