//! The unified metrics registry: one lock-sharded namespace every
//! subsystem publishes into, read out as a single deterministic
//! snapshot by the exporters in [`super::export`].
//!
//! ## Shape
//!
//! Three typed instruments, registered once at construction under
//! hierarchical dot names (`probe.budget.xsede/large.available_mb`):
//!
//! * [`Counter`] — a monotone `u64` (lock-free atomic adds).
//! * [`Gauge`] — a last-write-wins `f64` (atomic bit store).
//! * [`Hist`] — a mergeable [`LogHistogram`] behind a mutex.
//!
//! Registration hands back a cheap cloneable handle; the hot path
//! touches only that handle's atomic (or the one histogram mutex),
//! never the registry. The registry itself is sharded by name hash, so
//! concurrent registrations and snapshots contend per shard, not
//! globally. Registering the same name twice — any kind — is an error:
//! a name means one instrument, forever.
//!
//! ## Collectors
//!
//! Subsystems that already keep their own counters (feedback stats,
//! fabric stats, probe plane, link plane) publish through *collector*
//! closures instead of double-counting into handles: a collector runs
//! at snapshot time and emits `name → value` samples into the cut.
//! Collisions between collectors are merged additively (counters add,
//! histograms merge, gauges last-write-wins), so two coordinators
//! attached to the same subsystem family sum instead of clobbering.
//!
//! ## Snapshots
//!
//! [`Registry::snapshot`] returns a [`Snapshot`]: an ordered
//! `BTreeMap<String, Value>` — one consistent, deterministic cut.
//! Snapshots [`Snapshot::merge`] with the same additive semantics, so
//! merging two registries' snapshots equals recording the same data
//! into one (property-tested below).

use super::hist::LogHistogram;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count for the name map. Power of two, small: registration is
/// construction-time, so this only bounds snapshot/registration
/// contention, not hot-path throughput.
const SHARDS: usize = 8;

/// Monotone counter handle. Cloning shares the underlying cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (an `f64` stored as atomic bits).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Histogram handle over the shared mergeable [`LogHistogram`].
#[derive(Debug, Clone, Default)]
pub struct Hist(Arc<Mutex<LogHistogram>>);

impl Hist {
    pub fn record(&self, x: f64) {
        self.0.lock().expect("hist poisoned").record(x);
    }

    pub fn snapshot(&self) -> LogHistogram {
        self.0.lock().expect("hist poisoned").clone()
    }
}

/// One sampled value in a snapshot cut.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(f64),
    Hist(LogHistogram),
}

/// A registered instrument (what the shard map owns).
#[derive(Debug)]
enum Slot {
    Counter(Counter),
    Gauge(Gauge),
    Hist(Hist),
}

impl Slot {
    fn sample(&self) -> Value {
        match self {
            Slot::Counter(c) => Value::Counter(c.get()),
            Slot::Gauge(g) => Value::Gauge(g.get()),
            Slot::Hist(h) => Value::Hist(h.snapshot()),
        }
    }
}

/// A collector closure emits samples into this builder at snapshot
/// time. Collisions merge additively (see module docs).
#[derive(Debug, Default)]
pub struct Samples {
    values: BTreeMap<String, Value>,
}

impl Samples {
    pub fn counter(&mut self, name: &str, v: u64) {
        merge_value(&mut self.values, name, Value::Counter(v));
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        merge_value(&mut self.values, name, Value::Gauge(v));
    }

    pub fn hist(&mut self, name: &str, h: &LogHistogram) {
        merge_value(&mut self.values, name, Value::Hist(h.clone()));
    }
}

/// Additive merge of one sample into a cut: counters add, histograms
/// merge, gauges (and any kind mismatch) last-write-wins.
fn merge_value(into: &mut BTreeMap<String, Value>, name: &str, value: Value) {
    match (into.get_mut(name), value) {
        (Some(Value::Counter(a)), Value::Counter(b)) => *a += b,
        (Some(Value::Hist(a)), Value::Hist(ref b)) => a.merge(b),
        (Some(slot), value) => *slot = value,
        (None, value) => {
            into.insert(name.to_string(), value);
        }
    }
}

/// One consistent, deterministically-ordered cut of every registered
/// instrument plus every collector's emissions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub values: BTreeMap<String, Value>,
}

impl From<Samples> for Snapshot {
    fn from(samples: Samples) -> Snapshot {
        Snapshot { values: samples.values }
    }
}

impl Snapshot {
    /// Fold `other` into `self` with the additive semantics: counters
    /// add, histograms merge, gauges last-write-wins (`other` wins).
    pub fn merge(&mut self, other: &Snapshot) {
        for (name, value) in &other.values {
            merge_value(&mut self.values, name, value.clone());
        }
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.values.get(name)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

type CollectorFn = Box<dyn Fn(&mut Samples) + Send + Sync>;

/// The lock-sharded registry (see module docs).
pub struct Registry {
    shards: Vec<Mutex<BTreeMap<String, Slot>>>,
    collectors: Mutex<Vec<CollectorFn>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            shards: (0..SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
            collectors: Mutex::new(Vec::new()),
        }
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let registered: usize =
            self.shards.iter().map(|s| s.lock().expect("registry shard poisoned").len()).sum();
        f.debug_struct("Registry")
            .field("registered", &registered)
            .field("collectors", &self.collectors.lock().expect("collectors poisoned").len())
            .finish()
    }
}

/// FNV-1a over the name: same name always lands on the same shard, so
/// duplicate detection is a single-shard map lookup.
fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) % SHARDS
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, name: &str, slot: Slot) -> Result<()> {
        let mut shard = self.shards[shard_of(name)].lock().expect("registry shard poisoned");
        if shard.contains_key(name) {
            bail!("metric '{name}' is already registered");
        }
        shard.insert(name.to_string(), slot);
        Ok(())
    }

    /// Register a monotone counter under `name`. Errors if any
    /// instrument already owns the name.
    pub fn counter(&self, name: &str) -> Result<Counter> {
        let handle = Counter::default();
        self.register(name, Slot::Counter(handle.clone()))?;
        Ok(handle)
    }

    /// Register a gauge under `name`.
    pub fn gauge(&self, name: &str) -> Result<Gauge> {
        let handle = Gauge::default();
        self.register(name, Slot::Gauge(handle.clone()))?;
        Ok(handle)
    }

    /// Register a histogram under `name`.
    pub fn histogram(&self, name: &str) -> Result<Hist> {
        let handle = Hist::default();
        self.register(name, Slot::Hist(handle.clone()))?;
        Ok(handle)
    }

    /// Register a snapshot-time collector (see module docs). Never
    /// fails: collectors have no name of their own; collisions between
    /// their emitted samples merge additively.
    pub fn collect(&self, collector: impl Fn(&mut Samples) + Send + Sync + 'static) {
        self.collectors.lock().expect("collectors poisoned").push(Box::new(collector));
    }

    /// One deterministic cut: every registered instrument sampled,
    /// then every collector run, all merged additively into one
    /// ordered map.
    pub fn snapshot(&self) -> Snapshot {
        let mut samples = Samples::default();
        for shard in &self.shards {
            for (name, slot) in shard.lock().expect("registry shard poisoned").iter() {
                merge_value(&mut samples.values, name, slot.sample());
            }
        }
        for collector in self.collectors.lock().expect("collectors poisoned").iter() {
            collector(&mut samples);
        }
        Snapshot { values: samples.values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, gen, Config};

    #[test]
    fn typed_handles_register_and_sample() {
        let reg = Registry::new();
        let c = reg.counter("feedback.rows_dropped").unwrap();
        let g = reg.gauge("feedback.queue_depth").unwrap();
        let h = reg.histogram("coordinator.asm.achieved_mbps").unwrap();
        c.add(3);
        c.inc();
        g.set(7.5);
        h.record(1000.0);
        h.record(2000.0);
        let snap = reg.snapshot();
        assert_eq!(snap.get("feedback.rows_dropped"), Some(&Value::Counter(4)));
        assert_eq!(snap.get("feedback.queue_depth"), Some(&Value::Gauge(7.5)));
        match snap.get("coordinator.asm.achieved_mbps") {
            Some(Value::Hist(h)) => assert_eq!((h.count(), h.mean()), (2, 1500.0)),
            other => panic!("expected a histogram, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_order_is_deterministic_and_sorted() {
        let reg = Registry::new();
        for name in ["z.last", "a.first", "m.middle"] {
            reg.counter(name).unwrap();
        }
        let names: Vec<&String> = reg.snapshot().values.keys().collect();
        assert_eq!(names, ["a.first", "m.middle", "z.last"]);
    }

    #[test]
    fn collectors_merge_additively_on_collision() {
        let reg = Registry::new();
        reg.collect(|s| s.counter("probe.led", 2));
        reg.collect(|s| s.counter("probe.led", 5));
        reg.collect(|s| s.gauge("netplane.active", 1.0));
        reg.collect(|s| s.gauge("netplane.active", 3.0));
        let snap = reg.snapshot();
        assert_eq!(snap.get("probe.led"), Some(&Value::Counter(7)));
        // Gauges are last-write-wins, not additive.
        assert_eq!(snap.get("netplane.active"), Some(&Value::Gauge(3.0)));
    }

    #[test]
    fn duplicate_name_rejected_across_kinds() {
        // Property: whatever the (first kind, second kind) pairing, the
        // second registration of one name fails and the first handle
        // keeps working.
        forall(
            Config { cases: 64, seed: 0x5E_61 },
            |rng| (rng.index(3), rng.index(3), rng.index(1000)),
            |&(first, second, n)| {
                let reg = Registry::new();
                let name = format!("dup.test.{n}");
                let ok = match first {
                    0 => reg.counter(&name).map(|_| ()),
                    1 => reg.gauge(&name).map(|_| ()),
                    _ => reg.histogram(&name).map(|_| ()),
                };
                if ok.is_err() {
                    return Err("first registration must succeed".into());
                }
                let again = match second {
                    0 => reg.counter(&name).map(|_| ()),
                    1 => reg.gauge(&name).map(|_| ()),
                    _ => reg.histogram(&name).map(|_| ()),
                };
                if again.is_ok() {
                    return Err(format!("duplicate '{name}' accepted (kinds {first},{second})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn counter_is_monotone_under_arbitrary_adds() {
        forall(
            Config { cases: 128, seed: 0x5E_62 },
            |rng| {
                (0..rng.index(40)).map(|_| rng.index(1000) as u64).collect::<Vec<u64>>()
            },
            |adds| {
                let reg = Registry::new();
                let c = reg.counter("mono").unwrap();
                let mut last = c.get();
                let mut expect = 0u64;
                for &n in adds {
                    c.add(n);
                    expect += n;
                    let now = c.get();
                    if now < last {
                        return Err(format!("counter moved backwards: {last} -> {now}"));
                    }
                    last = now;
                }
                if c.get() != expect {
                    return Err(format!("counter {} != sum of adds {expect}", c.get()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merging_two_registries_equals_sequential_recording() {
        // Property: splitting a recording stream across two registries
        // and merging their snapshots equals recording everything into
        // one registry — for counters (adds commute) and histograms
        // (merge is exact on counts). The registry's merge is the
        // histogram's merge, so the f64 sums agree exactly here too:
        // both sides add the same values in the same order per bucket.
        forall(
            Config { cases: 64, seed: 0x5E_63 },
            |rng| {
                (
                    gen::vec_f64(rng, 0, 40, 1e-2, 1e6),
                    gen::vec_f64(rng, 0, 40, 1e-2, 1e6),
                    rng.index(1000) as u64,
                    rng.index(1000) as u64,
                )
            },
            |(xs_a, xs_b, n_a, n_b)| {
                let a = Registry::new();
                let b = Registry::new();
                let one = Registry::new();
                let (ca, cb, call) = (
                    a.counter("c").unwrap(),
                    b.counter("c").unwrap(),
                    one.counter("c").unwrap(),
                );
                let (ha, hb, hall) = (
                    a.histogram("h").unwrap(),
                    b.histogram("h").unwrap(),
                    one.histogram("h").unwrap(),
                );
                ca.add(*n_a);
                cb.add(*n_b);
                call.add(*n_a);
                call.add(*n_b);
                for &x in xs_a {
                    ha.record(x);
                    hall.record(x);
                }
                for &x in xs_b {
                    hb.record(x);
                    hall.record(x);
                }
                let mut merged = a.snapshot();
                merged.merge(&b.snapshot());
                let sequential = one.snapshot();
                if merged != sequential {
                    return Err(format!(
                        "merged {merged:?} != sequential {sequential:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn snapshot_merge_prefers_others_gauge() {
        let a = Registry::new();
        let b = Registry::new();
        a.gauge("g").unwrap().set(1.0);
        b.gauge("g").unwrap().set(2.0);
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.get("g"), Some(&Value::Gauge(2.0)));
    }

    #[test]
    fn handles_are_send_across_threads() {
        let reg = std::sync::Arc::new(Registry::new());
        let c = reg.counter("threads.hits").unwrap();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(reg.snapshot().get("threads.hits"), Some(&Value::Counter(4000)));
    }
}
