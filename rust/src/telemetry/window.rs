//! Windowed time-series over the cumulative metrics [`Snapshot`]s the
//! registry cuts: a fixed-capacity ring of per-window *deltas*, keyed
//! by virtual time.
//!
//! The registry's instruments are cumulative — a counter only ever
//! grows, a histogram only ever absorbs. Rate questions ("how many
//! budget-forced admissions in the last minute?", "what is the
//! accuracy p50 over the last three windows vs the whole retained
//! history?") need differences between cuts. The [`WindowRing`] keeps
//! them bounded: each observation diffs the new cumulative snapshot
//! against the previous one (counters by saturating subtraction,
//! histograms by [`LogHistogram::subtract`], gauges last-write) and
//! folds the delta into the frame owning `floor(t_s / window_s)`.
//! The ring holds at most `capacity` frames; older windows evict.
//!
//! Everything here is a pure function of (virtual time, snapshot)
//! pairs — no wall clock — so two same-seed replays build
//! byte-identical rings. This is the substrate the
//! [sentry](`super::sentry`) evaluates its detectors over.

use super::hist::LogHistogram;
use super::registry::{Snapshot, Value};
use std::collections::BTreeMap;
use std::collections::VecDeque;

/// One window's accumulated deltas.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WindowFrame {
    /// `floor(t_s / window_s)` of every observation folded in.
    pub id: u64,
    /// Per-counter increments observed during this window.
    pub counters: BTreeMap<String, u64>,
    /// Per-histogram contents recorded during this window.
    pub hists: BTreeMap<String, LogHistogram>,
    /// Last-written gauge values (gauges are levels, not rates).
    pub gauges: BTreeMap<String, f64>,
}

/// Fixed-capacity ring of [`WindowFrame`]s (see the module docs).
#[derive(Debug, Clone, Default)]
pub struct WindowRing {
    window_s: f64,
    capacity: usize,
    prev: Option<Snapshot>,
    frames: VecDeque<WindowFrame>,
}

impl WindowRing {
    /// A ring of at most `capacity` windows, each `window_s` of virtual
    /// time wide. Both are clamped to sane minima (1 s, 1 frame).
    pub fn new(window_s: f64, capacity: usize) -> WindowRing {
        WindowRing {
            window_s: if window_s.is_finite() { window_s.max(1.0) } else { 1.0 },
            capacity: capacity.max(1),
            prev: None,
            frames: VecDeque::new(),
        }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The window id owning virtual time `t_s`.
    pub fn window_id(&self, t_s: f64) -> u64 {
        (t_s.max(0.0) / self.window_s).floor() as u64
    }

    /// Retained frames, oldest first.
    pub fn frames(&self) -> impl Iterator<Item = &WindowFrame> {
        self.frames.iter()
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Observe a cumulative snapshot cut at virtual time `t_s`: diff it
    /// against the previous cut and fold the delta into `t_s`'s window
    /// frame. The first observation diffs against an empty snapshot, so
    /// its frame carries the full cumulative values.
    ///
    /// Observations normally arrive in non-decreasing time order; a
    /// late (out-of-order) cut folds into its own frame when that
    /// window is still retained, else into the oldest retained frame —
    /// deltas are never dropped, so window sums stay reconcilable with
    /// the cumulative totals.
    pub fn observe(&mut self, t_s: f64, snap: &Snapshot) {
        let id = self.window_id(t_s);
        let mut delta_counters: Vec<(String, u64)> = Vec::new();
        let mut delta_hists: Vec<(String, LogHistogram)> = Vec::new();
        let mut gauges: Vec<(String, f64)> = Vec::new();
        let empty = Snapshot::default();
        let prev = self.prev.as_ref().unwrap_or(&empty);
        for (name, value) in &snap.values {
            match value {
                Value::Counter(c) => {
                    let before = match prev.get(name) {
                        Some(Value::Counter(p)) => *p,
                        _ => 0,
                    };
                    let d = c.saturating_sub(before);
                    if d > 0 {
                        delta_counters.push((name.clone(), d));
                    }
                }
                Value::Hist(h) => {
                    let d = match prev.get(name) {
                        Some(Value::Hist(p)) => h.subtract(p),
                        _ => h.clone(),
                    };
                    if !d.is_empty() {
                        delta_hists.push((name.clone(), d));
                    }
                }
                Value::Gauge(g) => gauges.push((name.clone(), *g)),
            }
        }
        self.prev = Some(snap.clone());

        let frame = self.frame_for(id);
        for (name, d) in delta_counters {
            *frame.counters.entry(name).or_insert(0) += d;
        }
        for (name, d) in delta_hists {
            frame.hists.entry(name).or_default().merge(&d);
        }
        for (name, g) in gauges {
            frame.gauges.insert(name, g);
        }
    }

    /// The frame an observation for window `id` folds into, creating
    /// (and evicting) as needed.
    fn frame_for(&mut self, id: u64) -> &mut WindowFrame {
        let newest = self.frames.back().map(|f| f.id);
        match newest {
            None => {
                self.frames.push_back(WindowFrame { id, ..Default::default() });
            }
            Some(newest_id) if id > newest_id => {
                self.frames.push_back(WindowFrame { id, ..Default::default() });
                while self.frames.len() > self.capacity {
                    self.frames.pop_front();
                }
            }
            Some(_) => {
                // In-window or late observation: fold into the matching
                // retained frame, else the oldest retained one.
                let pos = self.frames.iter().position(|f| f.id == id).unwrap_or(0);
                return &mut self.frames[pos];
            }
        }
        self.frames.back_mut().expect("frame just pushed")
    }

    /// Sum of `name`'s counter deltas over the newest `n` retained
    /// windows (`usize::MAX` for all retained).
    pub fn counter_delta(&self, name: &str, n: usize) -> u64 {
        self.frames
            .iter()
            .rev()
            .take(n)
            .filter_map(|f| f.counters.get(name))
            .sum()
    }

    /// Merge of `name`'s per-window histogram deltas over the newest
    /// `n` retained windows (`usize::MAX` for all retained).
    pub fn merged_hist(&self, name: &str, n: usize) -> LogHistogram {
        let mut merged = LogHistogram::new();
        for frame in self.frames.iter().rev().take(n) {
            if let Some(h) = frame.hists.get(name) {
                merged.merge(h);
            }
        }
        merged
    }

    /// The most recent value of gauge `name` across the newest `n`
    /// retained windows.
    pub fn gauge(&self, name: &str, n: usize) -> Option<f64> {
        self.frames
            .iter()
            .rev()
            .take(n)
            .find_map(|f| f.gauges.get(name).copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::Samples;
    use crate::util::proptest::{forall, gen, Config};
    use crate::util::rng::Rng;

    fn counter_snap(total: u64) -> Snapshot {
        let mut s = Samples::default();
        s.counter("c", total);
        Snapshot::from(s)
    }

    #[test]
    fn first_observation_carries_the_full_cumulative_value() {
        let mut ring = WindowRing::new(60.0, 4);
        ring.observe(10.0, &counter_snap(7));
        assert_eq!(ring.len(), 1);
        assert_eq!(ring.counter_delta("c", usize::MAX), 7);
    }

    #[test]
    fn windows_split_deltas_by_virtual_time() {
        let mut ring = WindowRing::new(60.0, 8);
        ring.observe(10.0, &counter_snap(3)); // window 0: +3
        ring.observe(50.0, &counter_snap(5)); // window 0: +2
        ring.observe(70.0, &counter_snap(9)); // window 1: +4
        ring.observe(200.0, &counter_snap(9)); // window 3: +0 (frame still opens)
        let frames: Vec<_> = ring.frames().collect();
        assert_eq!(
            frames.iter().map(|f| f.id).collect::<Vec<_>>(),
            vec![0, 1, 3],
            "an empty delta still opens its window"
        );
        assert_eq!(frames[0].counters.get("c"), Some(&5));
        assert_eq!(frames[1].counters.get("c"), Some(&4));
        assert_eq!(frames[2].counters.get("c"), None);
        assert_eq!(ring.counter_delta("c", 2), 4, "newest two windows");
        assert_eq!(ring.counter_delta("c", usize::MAX), 9);
    }

    #[test]
    fn gauges_are_levels_not_rates() {
        let mut ring = WindowRing::new(60.0, 4);
        let mut s = Samples::default();
        s.gauge("g", 5.0);
        ring.observe(10.0, &Snapshot::from(s));
        let mut s = Samples::default();
        s.gauge("g", 2.0);
        ring.observe(20.0, &Snapshot::from(s));
        assert_eq!(ring.gauge("g", usize::MAX), Some(2.0), "last write wins");
        let mut s = Samples::default();
        s.gauge("other", 1.0);
        ring.observe(70.0, &Snapshot::from(s));
        assert_eq!(ring.gauge("g", 1), None, "newest window never saw g");
        assert_eq!(ring.gauge("g", 2), Some(2.0));
    }

    #[test]
    fn late_observations_fold_into_their_own_retained_window() {
        let mut ring = WindowRing::new(60.0, 8);
        ring.observe(10.0, &counter_snap(1)); // window 0
        ring.observe(70.0, &counter_snap(2)); // window 1
        ring.observe(30.0, &counter_snap(5)); // late: window 0, +3
        let frames: Vec<_> = ring.frames().collect();
        assert_eq!(frames[0].counters.get("c"), Some(&4));
        assert_eq!(frames[1].counters.get("c"), Some(&1));
        // A late cut whose window already evicted folds into the oldest
        // retained frame instead of vanishing.
        let mut tiny = WindowRing::new(60.0, 1);
        tiny.observe(10.0, &counter_snap(1));
        tiny.observe(70.0, &counter_snap(2));
        tiny.observe(30.0, &counter_snap(6));
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny.counter_delta("c", usize::MAX), 5);
    }

    // Satellite: window-delta sums equal the cumulative counter total
    // whenever nothing evicted.
    #[test]
    fn window_delta_sums_equal_cumulative_totals() {
        forall(
            Config { cases: 120, seed: 0x51_D0 },
            |rng| {
                let steps = 1 + (rng.next_u64() % 40) as usize;
                (0..steps)
                    .map(|_| (rng.next_u64() % 400, rng.next_u64() % 50))
                    .collect::<Vec<(u64, u64)>>()
            },
            |steps: &Vec<(u64, u64)>| {
                let mut ring = WindowRing::new(10.0, usize::MAX);
                let mut t = 0.0;
                let mut total = 0u64;
                for (dt, inc) in steps {
                    t += *dt as f64 / 10.0;
                    total += inc;
                    ring.observe(t, &counter_snap(total));
                }
                let summed = ring.counter_delta("c", usize::MAX);
                if summed != total {
                    return Err(format!("window deltas sum to {summed}, cumulative is {total}"));
                }
                Ok(())
            },
        );
    }

    // Satellite: eviction never produces a negative (underflowed)
    // delta — every retained frame still matches the per-window
    // increments computed independently, and their sum never exceeds
    // the cumulative total.
    #[test]
    fn eviction_never_produces_negative_deltas() {
        forall(
            Config { cases: 120, seed: 0x51_D1 },
            |rng| {
                let steps = 1 + (rng.next_u64() % 60) as usize;
                (0..steps)
                    .map(|_| (rng.next_u64() % 300, rng.next_u64() % 50))
                    .collect::<Vec<(u64, u64)>>()
            },
            |steps: &Vec<(u64, u64)>| {
                let mut ring = WindowRing::new(10.0, 4);
                let mut expected: BTreeMap<u64, u64> = BTreeMap::new();
                let mut t = 0.0;
                let mut total = 0u64;
                for (dt, inc) in steps {
                    t += *dt as f64 / 10.0;
                    total += inc;
                    ring.observe(t, &counter_snap(total));
                    *expected.entry(ring.window_id(t)).or_insert(0) += inc;
                }
                if ring.len() > 4 {
                    return Err(format!("ring retained {} frames over capacity 4", ring.len()));
                }
                for frame in ring.frames() {
                    let got = frame.counters.get("c").copied().unwrap_or(0);
                    let want = expected.get(&frame.id).copied().unwrap_or(0);
                    // Eviction can fold a late delta into the oldest
                    // frame, inflating it; it must never underflow or
                    // lose counts.
                    if got > total {
                        return Err(format!(
                            "window {} delta {got} exceeds cumulative total {total}",
                            frame.id
                        ));
                    }
                    if got < want && Some(frame.id) != ring.frames().next().map(|f| f.id) {
                        return Err(format!(
                            "window {} delta {got} lost counts (want >= {want})",
                            frame.id
                        ));
                    }
                }
                if ring.counter_delta("c", usize::MAX) > total {
                    return Err("retained deltas exceed the cumulative total".into());
                }
                Ok(())
            },
        );
    }

    // Satellite: merging per-window histogram deltas reproduces a
    // single wide window within LogHistogram's 1% bucket error.
    #[test]
    fn merged_window_quantiles_match_a_single_wide_window() {
        forall(
            Config { cases: 80, seed: 0x51_D2 },
            |rng| gen::vec_f64(rng, 1, 120, 1e-2, 1e6),
            |xs: &Vec<f64>| {
                let mut ring = WindowRing::new(10.0, usize::MAX);
                let mut wide = LogHistogram::new();
                let mut cumulative = LogHistogram::new();
                let mut inner = Rng::new(0x51_D3);
                let mut t = 0.0;
                for &x in xs {
                    t += inner.range_f64(0.0, 25.0);
                    wide.record(x);
                    cumulative.record(x);
                    let mut s = Samples::default();
                    s.hist("h", &cumulative);
                    ring.observe(t, &Snapshot::from(s));
                }
                let merged = ring.merged_hist("h", usize::MAX);
                if merged.count() != wide.count() {
                    return Err(format!(
                        "merged windows hold {} records, wide window {}",
                        merged.count(),
                        wide.count()
                    ));
                }
                for p in [0.0, 0.1, 0.5, 0.9, 1.0] {
                    let (a, b) = (merged.quantile(p), wide.quantile(p));
                    let tol = 0.01 * b.abs() + 1e-9;
                    if (a - b).abs() > tol {
                        return Err(format!("p={p}: merged {a} vs wide {b} (tol {tol})"));
                    }
                }
                Ok(())
            },
        );
    }
}
