//! The sentry: deterministic anomaly detectors over the windowed
//! telemetry, with typed raise/clear alert edges.
//!
//! PRs 6–7 built the *passive* observability half — traces, registry,
//! exporters, accuracy ledger. The sentry is the active half: every
//! settlement it is ticked with a virtual time, a [`Settlement`]
//! summary, and the same single-cut cumulative [`Snapshot`] the
//! exporters consume; it folds the cut into a [`WindowRing`] and
//! evaluates a **fixed, ordered** detector set ([`DETECTORS`]) against
//! the windows:
//!
//! 1. **accuracy-below-floor** — the accuracy ledger's p50 falls below
//!    the SLO in *both* the short (last `accuracy_short_windows`
//!    windows) and long (all retained windows) horizons, after at
//!    least `accuracy_min_count` scores exist. Requiring both horizons
//!    is the burn-rate guard: a couple of contended transfers dent the
//!    short window without tripping the long one, while a real
//!    brownout drags both.
//! 2. **probe-budget-famine** — one or more budget-forced admissions
//!    in the current window: the shard is serving estimates because it
//!    *cannot afford* to sample, not because it is confident.
//! 3. **occupancy-leak** — the settlement's network still carries load
//!    (registered transfers, carried or ambient Mbps) at settlement,
//!    when the sequential replay's lease discipline says it must be
//!    drained.
//! 4. **stale-knowledge** — one or more stale-generation estimate
//!    demotions in the current window: requests keep consulting
//!    knowledge recorded under a KB generation the refresher has
//!    already superseded.
//! 5. **allowance-thrash** — the settled transfer spent time clamped
//!    below its solo stream allowance by fair-share contention.
//!
//! Detectors are edge-triggered: an [`Alert`] is raised on the first
//! firing tick and carries its clear time once a tick evaluates calm.
//! Every input is on the deterministic allowlist — virtual time,
//! counters, per-window histogram deltas, gauges of the sequential
//! replay, the settlement flags — never a wall clock, so same-seed
//! replays produce byte-identical alert timelines. That is what lets
//! the scenario engine treat alerts as a conformance surface
//! (`expect-alert` / `expect-quiet`, the `alert-conformance`
//! invariant) with a *tested* false-positive policy: a fault-free
//! control replay must raise nothing at all.

use super::hist::LogHistogram;
use super::registry::{Samples, Snapshot, Value};
use super::window::WindowRing;
use crate::util::json::Json;

/// The fixed detector set, in evaluation order.
pub const DETECTORS: [&str; 5] = [
    "accuracy-below-floor",
    "probe-budget-famine",
    "occupancy-leak",
    "stale-knowledge",
    "allowance-thrash",
];

/// Sentry tuning knobs. Every default is sized for the scenario
/// engine's virtual-minutes timescale.
#[derive(Debug, Clone, Copy)]
pub struct SentryConfig {
    /// Window width in virtual seconds.
    pub window_s: f64,
    /// Windows retained in the ring (the "long" horizon).
    pub retain: usize,
    /// Accuracy SLO: the ledger p50 the fleet must hold.
    pub accuracy_slo: f64,
    /// The "short" burn-rate horizon, in windows.
    pub accuracy_short_windows: usize,
    /// Minimum scores retained before the accuracy detector speaks at
    /// all (a first led request's ratio is legitimate noise).
    pub accuracy_min_count: u64,
}

impl Default for SentryConfig {
    fn default() -> Self {
        SentryConfig {
            window_s: 60.0,
            retain: 32,
            accuracy_slo: 0.75,
            accuracy_short_windows: 3,
            accuracy_min_count: 3,
        }
    }
}

/// What one settlement tells the sentry beyond the snapshot: the
/// serving shard/network, the score, the pinned generation, and
/// whether the transfer was fair-share clamped.
#[derive(Debug, Clone, PartialEq)]
pub struct Settlement {
    pub shard: String,
    pub network: String,
    pub achieved_mbps: f64,
    pub optimal_mbps: f64,
    pub generation: u64,
    /// The transfer spent time clamped below its solo allowance
    /// (`ContentionExposure::contended_s > 0`).
    pub contended: bool,
}

/// One raised alert, with its clear edge once observed.
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    pub detector: &'static str,
    /// The metric family (or family prefix) whose windows fired.
    pub family: String,
    /// Virtual time of the raising tick.
    pub raised_t_s: f64,
    /// Virtual time of the first calm tick (`None` = still active).
    pub cleared_t_s: Option<f64>,
    /// The triggering window value...
    pub value: f64,
    /// ...and the threshold it crossed.
    pub threshold: f64,
    pub detail: String,
}

impl Alert {
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("detector", Json::Str(self.detector.to_string()))
            .set("family", Json::Str(self.family.clone()))
            .set("raised_t_s", Json::Num(self.raised_t_s))
            .set("cleared_t_s", self.cleared_t_s.map_or(Json::Null, Json::Num))
            .set("value", Json::Num(self.value))
            .set("threshold", Json::Num(self.threshold))
            .set("detail", Json::Str(self.detail.clone()));
        obj
    }
}

/// The alert timeline as a JSON array (raise order).
pub fn alerts_to_json(alerts: &[Alert]) -> Json {
    Json::Arr(alerts.iter().map(Alert::to_json).collect())
}

/// Human-readable alert timeline (the `--alerts` rendering).
pub fn render_alerts(alerts: &[Alert]) -> String {
    if alerts.is_empty() {
        return "alerts: none raised\n".to_string();
    }
    let active = alerts.iter().filter(|a| a.cleared_t_s.is_none()).count();
    let mut out = format!("alerts: {} raised, {} active\n", alerts.len(), active);
    for a in alerts {
        let edge = match a.cleared_t_s {
            Some(t) => format!("cleared {t:.0}s"),
            None => "active".to_string(),
        };
        out.push_str(&format!(
            "  {} on {} raised {:.0}s ({edge}): {} [value {:.2}, threshold {:.2}]\n",
            a.detector, a.family, a.raised_t_s, a.detail, a.value, a.threshold
        ));
    }
    out
}

/// A detector's firing evidence for one tick.
struct Firing {
    family: String,
    value: f64,
    threshold: f64,
    detail: String,
}

/// The detector engine (see the module docs).
#[derive(Debug)]
pub struct Sentry {
    config: SentryConfig,
    ring: WindowRing,
    ticks: u64,
    /// Per-detector index into `alerts` while active.
    active: [Option<usize>; 5],
    /// Per-detector raise totals (exported).
    raised: [u64; 5],
    alerts: Vec<Alert>,
}

impl Default for Sentry {
    fn default() -> Self {
        Sentry::new(SentryConfig::default())
    }
}

impl Sentry {
    pub fn new(config: SentryConfig) -> Sentry {
        Sentry {
            config,
            ring: WindowRing::new(config.window_s, config.retain),
            ticks: 0,
            active: [None; 5],
            raised: [0; 5],
            alerts: Vec::new(),
        }
    }

    pub fn config(&self) -> &SentryConfig {
        &self.config
    }

    /// Settlements evaluated so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Every alert raised so far, in raise order.
    pub fn alerts(&self) -> &[Alert] {
        &self.alerts
    }

    /// Alerts currently raised without a clear edge.
    pub fn active_count(&self) -> usize {
        self.active.iter().flatten().count()
    }

    /// Evaluate every detector against the settlement at virtual time
    /// `t_s`, folding the cumulative `snap` into the window ring first.
    pub fn tick(&mut self, t_s: f64, settlement: &Settlement, snap: &Snapshot) {
        self.ring.observe(t_s, snap);
        self.ticks += 1;
        let firings = [
            self.accuracy_below_floor(),
            self.probe_budget_famine(),
            self.occupancy_leak(settlement, snap),
            self.stale_knowledge(settlement),
            self.allowance_thrash(settlement),
        ];
        for (idx, firing) in firings.into_iter().enumerate() {
            self.edge(idx, t_s, firing);
        }
    }

    /// Edge-trigger detector `idx`: raise on calm→firing, clear on
    /// firing→calm, hold otherwise.
    fn edge(&mut self, idx: usize, t_s: f64, firing: Option<Firing>) {
        match (firing, self.active[idx]) {
            (Some(f), None) => {
                self.active[idx] = Some(self.alerts.len());
                self.raised[idx] += 1;
                self.alerts.push(Alert {
                    detector: DETECTORS[idx],
                    family: f.family,
                    raised_t_s: t_s,
                    cleared_t_s: None,
                    value: f.value,
                    threshold: f.threshold,
                    detail: f.detail,
                });
            }
            (None, Some(alert_idx)) => {
                self.alerts[alert_idx].cleared_t_s = Some(t_s);
                self.active[idx] = None;
            }
            _ => {}
        }
    }

    fn accuracy_hist(&self, windows: usize) -> LogHistogram {
        self.ring.merged_hist("health.accuracy.overall", windows)
    }

    fn accuracy_below_floor(&self) -> Option<Firing> {
        let long = self.accuracy_hist(usize::MAX);
        if long.count() < self.config.accuracy_min_count {
            return None;
        }
        let short = self.accuracy_hist(self.config.accuracy_short_windows);
        if short.is_empty() {
            return None;
        }
        let slo = self.config.accuracy_slo;
        let (long_p50, short_p50) = (long.quantile(0.5), short.quantile(0.5));
        if long_p50 < slo && short_p50 < slo {
            // long_p50 < slo makes the denominator strictly positive.
            let burn = (slo - short_p50) / (slo - long_p50);
            Some(Firing {
                family: "health.accuracy.overall".to_string(),
                value: short_p50,
                threshold: slo,
                detail: format!(
                    "accuracy p50 {short_p50:.2} over the last {} window(s) and {long_p50:.2} \
                     over {} retained, both below SLO {slo:.2} (burn ratio {burn:.2})",
                    self.config.accuracy_short_windows,
                    self.ring.len(),
                ),
            })
        } else {
            None
        }
    }

    fn probe_budget_famine(&self) -> Option<Firing> {
        let forced = self.ring.counter_delta("probe.budget_forced", 1);
        if forced >= 1 {
            Some(Firing {
                family: "probe.budget_forced".to_string(),
                value: forced as f64,
                threshold: 1.0,
                detail: format!(
                    "{forced} budget-forced admission(s) in the current window: estimates \
                     served for want of probe budget, not for confidence"
                ),
            })
        } else {
            None
        }
    }

    fn occupancy_leak(&self, settlement: &Settlement, snap: &Snapshot) -> Option<Firing> {
        let gauge = |suffix: &str| -> f64 {
            match snap.get(&format!("netplane.{}.{suffix}", settlement.network)) {
                Some(Value::Gauge(v)) => *v,
                _ => 0.0,
            }
        };
        let transfers = gauge("transfers");
        let carried = gauge("carried_mbps");
        let ambient = gauge("ambient_mbps");
        if transfers > 0.5 || carried > 1e-6 || ambient > 1e-6 {
            Some(Firing {
                family: format!("netplane.{}", settlement.network),
                value: carried.max(ambient),
                threshold: 0.0,
                detail: format!(
                    "{transfers:.0} transfer(s), {carried:.0} Mbps carried ({ambient:.0} Mbps \
                     ambient) still on {} at settlement",
                    settlement.network
                ),
            })
        } else {
            None
        }
    }

    fn stale_knowledge(&self, settlement: &Settlement) -> Option<Firing> {
        let demoted = self.ring.counter_delta("probe.stale_demotions", 1);
        if demoted >= 1 {
            Some(Firing {
                family: "probe.stale_demotions".to_string(),
                value: demoted as f64,
                threshold: 1.0,
                detail: format!(
                    "{demoted} stale-generation estimate demotion(s) in the current window \
                     (now serving generation {})",
                    settlement.generation
                ),
            })
        } else {
            None
        }
    }

    fn allowance_thrash(&self, settlement: &Settlement) -> Option<Firing> {
        if settlement.contended {
            Some(Firing {
                family: format!("netplane.{}", settlement.network),
                value: 1.0,
                threshold: 0.5,
                detail: format!(
                    "settlement on {} ({}) spent time clamped below its solo stream \
                     allowance by fair-share contention",
                    settlement.shard, settlement.network
                ),
            })
        } else {
            None
        }
    }

    /// Publish the sentry families into an export cut. A sentry that
    /// was never ticked publishes nothing: serve paths without
    /// settlements (and hand-built metrics in tests) keep their
    /// exports sentry-free.
    pub fn export_into(&self, s: &mut Samples) {
        if self.ticks == 0 {
            return;
        }
        s.counter("sentry.ticks", self.ticks);
        s.counter("sentry.alerts.raised", self.alerts.len() as u64);
        s.gauge("sentry.alerts.active", self.active_count() as f64);
        for (idx, name) in DETECTORS.iter().enumerate() {
            s.counter(&format!("sentry.{name}.raised"), self.raised[idx]);
            s.gauge(
                &format!("sentry.{name}.active"),
                if self.active[idx].is_some() { 1.0 } else { 0.0 },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settlement() -> Settlement {
        Settlement {
            shard: "xsede/large".to_string(),
            network: "xsede".to_string(),
            achieved_mbps: 900.0,
            optimal_mbps: 1000.0,
            generation: 0,
            contended: false,
        }
    }

    fn accuracy_snap(hist: &LogHistogram) -> Snapshot {
        let mut s = Samples::default();
        s.hist("health.accuracy.overall", hist);
        Snapshot::from(s)
    }

    #[test]
    fn accuracy_detector_needs_min_count_then_raises_and_clears() {
        let mut sentry = Sentry::default();
        let mut ledger = LogHistogram::new();
        // Two bad scores: below min_count, no alert even at p50 0.4.
        for (t, score) in [(10.0, 0.4), (20.0, 0.4)] {
            ledger.record(score);
            sentry.tick(t, &settlement(), &accuracy_snap(&ledger));
        }
        assert!(sentry.alerts().is_empty(), "min-count guard must hold early noise");
        // Third bad score: both horizons breach.
        ledger.record(0.4);
        sentry.tick(30.0, &settlement(), &accuracy_snap(&ledger));
        assert_eq!(sentry.alerts().len(), 1);
        let alert = &sentry.alerts()[0];
        assert_eq!(alert.detector, "accuracy-below-floor");
        assert_eq!(alert.raised_t_s, 30.0);
        assert!(alert.cleared_t_s.is_none());
        assert!(alert.detail.contains("burn ratio"), "{}", alert.detail);
        assert_eq!(sentry.active_count(), 1);
        // Healthy scores far enough ahead that the short horizon sees
        // only them: the alert clears (the long horizon still remembers
        // the dip — that is the short window's job to forgive).
        for (t, score) in [(400.0, 1.0), (460.0, 1.0), (520.0, 1.0), (580.0, 1.0)] {
            ledger.record(score);
            sentry.tick(t, &settlement(), &accuracy_snap(&ledger));
        }
        assert_eq!(sentry.alerts().len(), 1, "edge-triggered: no re-raise while calm");
        assert_eq!(sentry.alerts()[0].cleared_t_s, Some(400.0));
        assert_eq!(sentry.active_count(), 0);
    }

    #[test]
    fn short_horizon_dip_alone_does_not_raise() {
        // A healthy long history with a couple of contended transfers
        // in the newest window: the conjunctive horizons hold.
        let mut sentry = Sentry::default();
        let mut ledger = LogHistogram::new();
        for (idx, score) in [0.95, 0.9, 0.95, 0.9, 0.95, 0.9].iter().enumerate() {
            ledger.record(*score);
            sentry.tick(10.0 + 60.0 * idx as f64, &settlement(), &accuracy_snap(&ledger));
        }
        for score in [0.4, 0.4] {
            ledger.record(score);
            sentry.tick(400.0, &settlement(), &accuracy_snap(&ledger));
        }
        assert!(
            sentry.alerts().is_empty(),
            "a short-window dip with a healthy long horizon must not raise: {:?}",
            sentry.alerts()
        );
    }

    fn counter_snap(name: &str, total: u64) -> Snapshot {
        let mut s = Samples::default();
        s.counter(name, total);
        Snapshot::from(s)
    }

    #[test]
    fn famine_raises_on_forced_admissions_and_clears_on_a_calm_window() {
        let mut sentry = Sentry::default();
        sentry.tick(10.0, &settlement(), &counter_snap("probe.budget_forced", 0));
        assert!(sentry.alerts().is_empty());
        sentry.tick(70.0, &settlement(), &counter_snap("probe.budget_forced", 2));
        let alert = &sentry.alerts()[0];
        assert_eq!(alert.detector, "probe-budget-famine");
        assert_eq!(alert.raised_t_s, 70.0);
        assert_eq!(alert.value, 2.0);
        // Next window, no new forced admissions: clears.
        sentry.tick(140.0, &settlement(), &counter_snap("probe.budget_forced", 2));
        assert_eq!(sentry.alerts()[0].cleared_t_s, Some(140.0));
    }

    #[test]
    fn stale_knowledge_tracks_demotion_deltas() {
        let mut sentry = Sentry::default();
        sentry.tick(10.0, &settlement(), &counter_snap("probe.stale_demotions", 1));
        assert_eq!(sentry.alerts().len(), 1);
        assert_eq!(sentry.alerts()[0].detector, "stale-knowledge");
        assert!(sentry.alerts()[0].detail.contains("generation 0"));
        sentry.tick(100.0, &settlement(), &counter_snap("probe.stale_demotions", 1));
        assert_eq!(sentry.alerts()[0].cleared_t_s, Some(100.0));
        // A fresh demotion re-raises a *new* alert.
        sentry.tick(130.0, &settlement(), &counter_snap("probe.stale_demotions", 2));
        assert_eq!(sentry.alerts().len(), 2);
    }

    fn gauge_snap(name: &str, v: f64) -> Snapshot {
        let mut s = Samples::default();
        s.gauge(name, v);
        Snapshot::from(s)
    }

    #[test]
    fn occupancy_leak_watches_the_settlements_network() {
        let mut sentry = Sentry::default();
        // Ambient load on another network is not this settlement's leak.
        sentry.tick(10.0, &settlement(), &gauge_snap("netplane.didclab.ambient_mbps", 500.0));
        assert!(sentry.alerts().is_empty());
        sentry.tick(20.0, &settlement(), &gauge_snap("netplane.xsede.ambient_mbps", 4000.0));
        let alert = &sentry.alerts()[0];
        assert_eq!(alert.detector, "occupancy-leak");
        assert_eq!(alert.family, "netplane.xsede");
        assert_eq!(alert.value, 4000.0);
        sentry.tick(90.0, &settlement(), &gauge_snap("netplane.xsede.ambient_mbps", 0.0));
        assert_eq!(sentry.alerts()[0].cleared_t_s, Some(90.0));
    }

    #[test]
    fn allowance_thrash_follows_the_contended_flag() {
        let mut sentry = Sentry::default();
        let contended = Settlement { contended: true, ..settlement() };
        sentry.tick(10.0, &contended, &Snapshot::default());
        sentry.tick(20.0, &contended, &Snapshot::default());
        assert_eq!(sentry.alerts().len(), 1, "held, not re-raised");
        assert_eq!(sentry.alerts()[0].detector, "allowance-thrash");
        sentry.tick(30.0, &settlement(), &Snapshot::default());
        assert_eq!(sentry.alerts()[0].cleared_t_s, Some(30.0));
    }

    #[test]
    fn identical_tick_sequences_produce_identical_alerts_and_exports() {
        let run = || {
            let mut sentry = Sentry::default();
            sentry.tick(10.0, &settlement(), &counter_snap("probe.budget_forced", 1));
            let contended = Settlement { contended: true, ..settlement() };
            sentry.tick(70.0, &contended, &counter_snap("probe.budget_forced", 1));
            sentry.tick(140.0, &settlement(), &counter_snap("probe.budget_forced", 1));
            let mut samples = Samples::default();
            sentry.export_into(&mut samples);
            let rendered = render_alerts(sentry.alerts());
            let json = alerts_to_json(sentry.alerts()).to_string_compact();
            (sentry.alerts().to_vec(), Snapshot::from(samples), rendered, json)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.values, b.1.values);
        assert_eq!(a.2, b.2);
        assert_eq!(a.3, b.3);
        assert!(a.2.contains("probe-budget-famine"), "{}", a.2);
        assert!(a.3.contains("\"cleared_t_s\":null") || a.3.contains("\"cleared_t_s\":"), "{}", a.3);
    }

    #[test]
    fn untouched_sentry_exports_nothing() {
        let sentry = Sentry::default();
        let mut samples = Samples::default();
        sentry.export_into(&mut samples);
        assert!(Snapshot::from(samples).is_empty(), "never-ticked sentry must stay invisible");
        // One tick makes every family appear, raised or not.
        let mut sentry = Sentry::default();
        sentry.tick(10.0, &settlement(), &Snapshot::default());
        let mut samples = Samples::default();
        sentry.export_into(&mut samples);
        let snap = Snapshot::from(samples);
        assert_eq!(snap.get("sentry.ticks"), Some(&Value::Counter(1)));
        assert_eq!(snap.get("sentry.alerts.active"), Some(&Value::Gauge(0.0)));
        for name in DETECTORS {
            assert!(snap.get(&format!("sentry.{name}.raised")).is_some(), "{name}");
            assert!(snap.get(&format!("sentry.{name}.active")).is_some(), "{name}");
        }
    }
}
