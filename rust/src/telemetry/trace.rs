//! Decision-provenance traces: one structured, deterministic event
//! stream per served request, emitted at every layer hop of the serve
//! path — shard routing, fault-board consultation, link admission,
//! probe-plane admission, the ASM ladder, netplane allowance clamps,
//! lease release, and settlement.
//!
//! ## Determinism contract
//!
//! Two same-seed runs must produce **byte-identical** traces, so every
//! field is a discrete fact or a simulation-derived number:
//!
//! * virtual timestamps are a per-trace monotone sequence counter, not
//!   wall clocks;
//! * no wall-clock quantity is ever recorded (in particular, the probe
//!   plane's *decayed estimate confidence* is wall-clock-dependent and
//!   deliberately excluded — provenance carries the estimate's cluster,
//!   surface, KB generation, and occupancy stamp instead);
//! * all floats (goodput, clamped allowances, contention exposure)
//!   derive from the simulator's seeded arithmetic.
//!
//! See DESIGN.md § "Decision-provenance telemetry" for the span
//! taxonomy.

use crate::util::json::Json;
use std::sync::Mutex;

/// Where the knowledge behind a decision came from.
#[derive(Debug, Clone, PartialEq)]
pub enum Provenance {
    /// The offline knowledge base: generation + matched cluster
    /// (`None` = cold/empty KB).
    Kb { generation: u64, cluster: Option<usize> },
    /// A stored network estimate, identified by its recording stamp.
    /// The decayed confidence float is deliberately absent: it depends
    /// on wall-clock elapsed time and would break byte-determinism.
    Estimate { cluster: usize, surface: usize, generation: u64, occ_streams: u32 },
    /// A coalesced leader's published probe result.
    Leader { cluster: usize, surface: usize, generation: u64 },
    /// Fresh real-time sampling (the request pays for its own probes).
    Fresh,
}

impl Provenance {
    pub fn kind(&self) -> &'static str {
        match self {
            Provenance::Kb { .. } => "kb",
            Provenance::Estimate { .. } => "estimate",
            Provenance::Leader { .. } => "leader",
            Provenance::Fresh => "fresh",
        }
    }

    fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("kind", Json::Str(self.kind().to_string()));
        match self {
            Provenance::Kb { generation, cluster } => {
                obj.set("generation", Json::Num(*generation as f64));
                obj.set(
                    "cluster",
                    cluster.map_or(Json::Null, |c| Json::Num(c as f64)),
                );
            }
            Provenance::Estimate { cluster, surface, generation, occ_streams } => {
                obj.set("cluster", Json::Num(*cluster as f64))
                    .set("surface", Json::Num(*surface as f64))
                    .set("generation", Json::Num(*generation as f64))
                    .set("occ_streams", Json::Num(*occ_streams as f64));
            }
            Provenance::Leader { cluster, surface, generation } => {
                obj.set("cluster", Json::Num(*cluster as f64))
                    .set("surface", Json::Num(*surface as f64))
                    .set("generation", Json::Num(*generation as f64));
            }
            Provenance::Fresh => {}
        }
        obj
    }

    fn describe(&self) -> String {
        match self {
            Provenance::Kb { generation, cluster } => match cluster {
                Some(c) => format!("kb gen={generation} cluster={c}"),
                None => format!("kb gen={generation} (cold)"),
            },
            Provenance::Estimate { cluster, surface, generation, occ_streams } => format!(
                "estimate c{cluster}/s{surface}@g{generation} occ={occ_streams}"
            ),
            Provenance::Leader { cluster, surface, generation } => {
                format!("leader c{cluster}/s{surface}@g{generation}")
            }
            Provenance::Fresh => "fresh sample".to_string(),
        }
    }
}

/// One typed event on a request's decision trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Shard routing resolved: which shard key serves the request and
    /// whether the shard's KB is borrowed from the global build.
    Route { key: String, borrowed: bool, generation: u64 },
    /// The fault board shaped the testbed before serving (records the
    /// post-shape link capacity so degradations are visible).
    FaultConsult { bandwidth_mbps: f64 },
    /// The contention plane admitted the transfer onto its link.
    LinkAdmit { epoch: u64, streams: u32 },
    /// Probe-plane admission: how this request obtains network
    /// knowledge, what it reserved from the probe budget, and the
    /// provenance of the knowledge it starts from.
    Admission {
        mode: &'static str,
        cluster: Option<usize>,
        generation: u64,
        /// Probe budget debited at admission (0 when not leading).
        reserved_mb: f64,
        /// Ladder warm-start surface, when an unconfident estimate
        /// seeded one.
        warm_start: Option<usize>,
        provenance: Provenance,
    },
    /// The KB had no surfaces for this cluster: single-chunk fallback.
    ColdStartFallback,
    /// One rung of the ASM ladder: the surface sampled, the θ it chose,
    /// the measured rate, and where the bisection went next.
    LadderStep {
        step: usize,
        surface: usize,
        cc: u32,
        p: u32,
        pp: u32,
        measured_mbps: f64,
        /// The sample fell inside this surface's confidence band.
        in_bound: bool,
        /// Next surface the ladder jumped to (`None` = converged here).
        jump_to: Option<usize>,
    },
    /// The ladder converged (or adopted its admission surface without
    /// sampling).
    Converged { surface: usize, sampled: bool, intensity: f64 },
    /// The drift monitor re-tuned the bulk phase onto another surface.
    BulkRetune { from_surface: usize, to_surface: usize },
    /// The netplane lease clamped the optimizer's asked parallelism.
    AllowanceClamp {
        asked_cc: u32,
        asked_p: u32,
        asked_pp: u32,
        granted_cc: u32,
        granted_p: u32,
        granted_pp: u32,
    },
    /// Neighbor traffic observed on the shared link during a chunk.
    NeighborPressure { offered_mbps: f64, streams: u32 },
    /// The link lease was released; its folded contention exposure.
    LeaseRelease { contended_s: f64, peak_neighbor_mbps: f64 },
    /// Settlement: what was written back to the estimate store and
    /// whether the completed log was offered to ingest.
    Settle {
        estimate_surface: Option<usize>,
        estimate_generation: Option<u64>,
        ingest_offered: bool,
    },
    /// Terminal accounting for the request.
    Done { optimizer: String, achieved_mbps: f64, total_mb: f64, samples: usize },
}

impl TraceEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Route { .. } => "route",
            TraceEvent::FaultConsult { .. } => "fault-consult",
            TraceEvent::LinkAdmit { .. } => "link-admit",
            TraceEvent::Admission { .. } => "admission",
            TraceEvent::ColdStartFallback => "cold-start-fallback",
            TraceEvent::LadderStep { .. } => "ladder-step",
            TraceEvent::Converged { .. } => "converged",
            TraceEvent::BulkRetune { .. } => "bulk-retune",
            TraceEvent::AllowanceClamp { .. } => "allowance-clamp",
            TraceEvent::NeighborPressure { .. } => "neighbor-pressure",
            TraceEvent::LeaseRelease { .. } => "lease-release",
            TraceEvent::Settle { .. } => "settle",
            TraceEvent::Done { .. } => "done",
        }
    }

    fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("kind", Json::Str(self.kind().to_string()));
        match self {
            TraceEvent::Route { key, borrowed, generation } => {
                obj.set("key", Json::Str(key.clone()))
                    .set("borrowed", Json::Bool(*borrowed))
                    .set("generation", Json::Num(*generation as f64));
            }
            TraceEvent::FaultConsult { bandwidth_mbps } => {
                obj.set("bandwidth_mbps", Json::Num(*bandwidth_mbps));
            }
            TraceEvent::LinkAdmit { epoch, streams } => {
                obj.set("epoch", Json::Num(*epoch as f64))
                    .set("streams", Json::Num(*streams as f64));
            }
            TraceEvent::Admission {
                mode,
                cluster,
                generation,
                reserved_mb,
                warm_start,
                provenance,
            } => {
                obj.set("mode", Json::Str(mode.to_string()))
                    .set("cluster", cluster.map_or(Json::Null, |c| Json::Num(c as f64)))
                    .set("generation", Json::Num(*generation as f64))
                    .set("reserved_mb", Json::Num(*reserved_mb))
                    .set(
                        "warm_start",
                        warm_start.map_or(Json::Null, |s| Json::Num(s as f64)),
                    )
                    .set("provenance", provenance.to_json());
            }
            TraceEvent::ColdStartFallback => {}
            TraceEvent::LadderStep { step, surface, cc, p, pp, measured_mbps, in_bound, jump_to } => {
                obj.set("step", Json::Num(*step as f64))
                    .set("surface", Json::Num(*surface as f64))
                    .set("cc", Json::Num(*cc as f64))
                    .set("p", Json::Num(*p as f64))
                    .set("pp", Json::Num(*pp as f64))
                    .set("measured_mbps", Json::Num(*measured_mbps))
                    .set("in_bound", Json::Bool(*in_bound))
                    .set("jump_to", jump_to.map_or(Json::Null, |s| Json::Num(s as f64)));
            }
            TraceEvent::Converged { surface, sampled, intensity } => {
                obj.set("surface", Json::Num(*surface as f64))
                    .set("sampled", Json::Bool(*sampled))
                    .set("intensity", Json::Num(*intensity));
            }
            TraceEvent::BulkRetune { from_surface, to_surface } => {
                obj.set("from_surface", Json::Num(*from_surface as f64))
                    .set("to_surface", Json::Num(*to_surface as f64));
            }
            TraceEvent::AllowanceClamp {
                asked_cc,
                asked_p,
                asked_pp,
                granted_cc,
                granted_p,
                granted_pp,
            } => {
                obj.set("asked_cc", Json::Num(*asked_cc as f64))
                    .set("asked_p", Json::Num(*asked_p as f64))
                    .set("asked_pp", Json::Num(*asked_pp as f64))
                    .set("granted_cc", Json::Num(*granted_cc as f64))
                    .set("granted_p", Json::Num(*granted_p as f64))
                    .set("granted_pp", Json::Num(*granted_pp as f64));
            }
            TraceEvent::NeighborPressure { offered_mbps, streams } => {
                obj.set("offered_mbps", Json::Num(*offered_mbps))
                    .set("streams", Json::Num(*streams as f64));
            }
            TraceEvent::LeaseRelease { contended_s, peak_neighbor_mbps } => {
                obj.set("contended_s", Json::Num(*contended_s))
                    .set("peak_neighbor_mbps", Json::Num(*peak_neighbor_mbps));
            }
            TraceEvent::Settle { estimate_surface, estimate_generation, ingest_offered } => {
                obj.set(
                    "estimate_surface",
                    estimate_surface.map_or(Json::Null, |s| Json::Num(s as f64)),
                )
                .set(
                    "estimate_generation",
                    estimate_generation.map_or(Json::Null, |g| Json::Num(g as f64)),
                )
                .set("ingest_offered", Json::Bool(*ingest_offered));
            }
            TraceEvent::Done { optimizer, achieved_mbps, total_mb, samples } => {
                obj.set("optimizer", Json::Str(optimizer.clone()))
                    .set("achieved_mbps", Json::Num(*achieved_mbps))
                    .set("total_mb", Json::Num(*total_mb))
                    .set("samples", Json::Num(*samples as f64));
            }
        }
        obj
    }

    fn describe(&self) -> String {
        match self {
            TraceEvent::Route { key, borrowed, generation } => format!(
                "routed to {key} gen={generation}{}",
                if *borrowed { " (borrowed)" } else { " (native)" }
            ),
            TraceEvent::FaultConsult { bandwidth_mbps } => {
                format!("fault board consulted; link at {bandwidth_mbps:.0} Mbps")
            }
            TraceEvent::LinkAdmit { epoch, streams } => {
                format!("link admitted at epoch {epoch} ({streams} neighbor streams)")
            }
            TraceEvent::Admission { mode, reserved_mb, warm_start, provenance, .. } => {
                let warm = match warm_start {
                    Some(s) => format!(", warm-start s{s}"),
                    None => String::new(),
                };
                format!(
                    "admission {mode} [{}]{warm} reserved={reserved_mb:.1} MB",
                    provenance.describe()
                )
            }
            TraceEvent::ColdStartFallback => "cold KB: single-chunk fallback".to_string(),
            TraceEvent::LadderStep { step, surface, cc, p, pp, measured_mbps, in_bound, jump_to } => {
                let next = match jump_to {
                    Some(s) => format!("jump s{s}"),
                    None => "converge".to_string(),
                };
                format!(
                    "ladder step {step}: s{surface} θ=({cc},{p},{pp}) -> {measured_mbps:.0} Mbps \
                     {} -> {next}",
                    if *in_bound { "in-bound" } else { "out-of-bound" }
                )
            }
            TraceEvent::Converged { surface, sampled, intensity } => format!(
                "converged on s{surface} (intensity {intensity:.2}{})",
                if *sampled { ", sampled" } else { ", unsampled" }
            ),
            TraceEvent::BulkRetune { from_surface, to_surface } => {
                format!("bulk drift re-tune s{from_surface} -> s{to_surface}")
            }
            TraceEvent::AllowanceClamp {
                asked_cc,
                asked_p,
                asked_pp,
                granted_cc,
                granted_p,
                granted_pp,
            } => format!(
                "allowance clamp ({asked_cc},{asked_p},{asked_pp}) -> \
                 ({granted_cc},{granted_p},{granted_pp})"
            ),
            TraceEvent::NeighborPressure { offered_mbps, streams } => {
                format!("neighbor pressure {offered_mbps:.0} Mbps / {streams} streams")
            }
            TraceEvent::LeaseRelease { contended_s, peak_neighbor_mbps } => format!(
                "lease released (contended {contended_s:.2}s, peak neighbors \
                 {peak_neighbor_mbps:.0} Mbps)"
            ),
            TraceEvent::Settle { estimate_surface, estimate_generation, ingest_offered } => {
                let est = match (estimate_surface, estimate_generation) {
                    (Some(s), Some(g)) => format!("estimate s{s}@g{g}"),
                    _ => "no estimate".to_string(),
                };
                format!(
                    "settled: {est}, ingest {}",
                    if *ingest_offered { "offered" } else { "skipped" }
                )
            }
            TraceEvent::Done { optimizer, achieved_mbps, total_mb, samples } => format!(
                "done: {optimizer} moved {total_mb:.0} MB at {achieved_mbps:.1} Mbps \
                 ({samples} samples)"
            ),
        }
    }
}

/// Accumulates one request's events with monotone virtual timestamps.
/// Carried inside the transfer environment so every layer can append
/// without new plumbing.
#[derive(Debug)]
pub struct TraceBuilder {
    request_id: u64,
    seed: u64,
    seq: u64,
    events: Vec<(u64, TraceEvent)>,
}

impl TraceBuilder {
    pub fn new(request_id: u64, seed: u64) -> Self {
        TraceBuilder { request_id, seed, seq: 0, events: Vec::new() }
    }

    pub fn note(&mut self, event: TraceEvent) {
        let at = self.seq;
        self.seq += 1;
        self.events.push((at, event));
    }

    pub fn finish(self) -> DecisionTrace {
        DecisionTrace { request_id: self.request_id, seed: self.seed, events: self.events }
    }
}

/// One request's complete, immutable decision trace.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionTrace {
    pub request_id: u64,
    pub seed: u64,
    /// `(virtual timestamp, event)` pairs; timestamps are a strictly
    /// monotone per-trace counter.
    pub events: Vec<(u64, TraceEvent)>,
}

impl DecisionTrace {
    pub fn event_kinds(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.events.iter().map(|(_, e)| e.kind())
    }

    fn has(&self, kind: &str) -> bool {
        self.event_kinds().any(|k| k == kind)
    }

    /// Every structural defect in this trace; empty = complete. A
    /// complete trace has an admission, a decision (convergence or
    /// cold-start fallback — required only of ASM traces; the baseline
    /// optimizers have no sampling ladder to converge), a settlement, a
    /// lease release for every link admission, and strictly monotone
    /// virtual timestamps.
    pub fn completeness_errors(&self) -> Vec<String> {
        let mut errors = Vec::new();
        if !self.has("admission") {
            errors.push("no admission event".to_string());
        }
        let asm = self.events.iter().any(|(_, e)| {
            matches!(e, TraceEvent::Done { optimizer, .. } if optimizer == "ASM")
        });
        if asm && !self.has("converged") && !self.has("cold-start-fallback") {
            errors.push("no decision event (converged or cold-start-fallback)".to_string());
        }
        if !self.has("settle") {
            errors.push("no settlement event".to_string());
        }
        if !self.has("done") {
            errors.push("no terminal done event".to_string());
        }
        if self.has("link-admit") && !self.has("lease-release") {
            errors.push("link admitted but lease never released".to_string());
        }
        for pair in self.events.windows(2) {
            if pair[1].0 <= pair[0].0 {
                errors.push(format!(
                    "virtual timestamps not strictly monotone: {} then {}",
                    pair[0].0, pair[1].0
                ));
                break;
            }
        }
        errors
    }

    pub fn is_complete(&self) -> bool {
        self.completeness_errors().is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("request_id", Json::Num(self.request_id as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set(
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|(at, event)| {
                            let mut e = event.to_json();
                            e.set("at", Json::Num(*at as f64));
                            e
                        })
                        .collect(),
                ),
            );
        obj
    }

    /// The human-readable "why this θ" explanation.
    pub fn render_text(&self) -> String {
        let mut out = format!("request {} (seed {:#x})\n", self.request_id, self.seed);
        for (at, event) in &self.events {
            out.push_str(&format!("  [{at:>3}] {:<18} {}\n", event.kind(), event.describe()));
        }
        out
    }
}

/// Collects finished traces across requests; the coordinator's
/// counterpart to [`crate::coordinator::ResponseTap`].
#[derive(Debug, Default)]
pub struct TraceSink {
    traces: Mutex<Vec<DecisionTrace>>,
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, trace: DecisionTrace) {
        self.traces.lock().expect("trace sink poisoned").push(trace);
    }

    /// Take everything collected so far.
    pub fn drain(&self) -> Vec<DecisionTrace> {
        std::mem::take(&mut *self.traces.lock().expect("trace sink poisoned"))
    }

    pub fn len(&self) -> usize {
        self.traces.lock().expect("trace sink poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Deterministic JSON for a batch of traces.
pub fn traces_to_json(traces: &[DecisionTrace]) -> Json {
    Json::Arr(traces.iter().map(DecisionTrace::to_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn complete_trace() -> DecisionTrace {
        let mut tb = TraceBuilder::new(7, 0xABC);
        tb.note(TraceEvent::Route {
            key: "xsede/large".to_string(),
            borrowed: false,
            generation: 2,
        });
        tb.note(TraceEvent::LinkAdmit { epoch: 4, streams: 8 });
        tb.note(TraceEvent::Admission {
            mode: "lead",
            cluster: Some(1),
            generation: 2,
            reserved_mb: 320.0,
            warm_start: Some(3),
            provenance: Provenance::Fresh,
        });
        tb.note(TraceEvent::LadderStep {
            step: 1,
            surface: 3,
            cc: 4,
            p: 4,
            pp: 2,
            measured_mbps: 2500.0,
            in_bound: true,
            jump_to: None,
        });
        tb.note(TraceEvent::Converged { surface: 3, sampled: true, intensity: 0.4 });
        tb.note(TraceEvent::AllowanceClamp {
            asked_cc: 8,
            asked_p: 4,
            asked_pp: 2,
            granted_cc: 4,
            granted_p: 4,
            granted_pp: 2,
        });
        tb.note(TraceEvent::LeaseRelease { contended_s: 1.5, peak_neighbor_mbps: 900.0 });
        tb.note(TraceEvent::Settle {
            estimate_surface: Some(3),
            estimate_generation: Some(2),
            ingest_offered: true,
        });
        tb.note(TraceEvent::Done {
            optimizer: "ASM".to_string(),
            achieved_mbps: 2400.0,
            total_mb: 20_000.0,
            samples: 1,
        });
        tb.finish()
    }

    #[test]
    fn builder_assigns_strictly_monotone_timestamps() {
        let trace = complete_trace();
        for (i, (at, _)) in trace.events.iter().enumerate() {
            assert_eq!(*at, i as u64);
        }
        assert!(trace.is_complete(), "{:?}", trace.completeness_errors());
    }

    #[test]
    fn completeness_flags_each_missing_piece() {
        let mut missing_admission = complete_trace();
        missing_admission.events.retain(|(_, e)| e.kind() != "admission");
        assert!(missing_admission
            .completeness_errors()
            .iter()
            .any(|e| e.contains("no admission")));

        let mut missing_release = complete_trace();
        missing_release.events.retain(|(_, e)| e.kind() != "lease-release");
        assert!(missing_release
            .completeness_errors()
            .iter()
            .any(|e| e.contains("lease never released")));

        let mut shuffled = complete_trace();
        shuffled.events[1].0 = 0; // duplicate timestamp
        assert!(shuffled
            .completeness_errors()
            .iter()
            .any(|e| e.contains("not strictly monotone")));

        // The decision event is required of ASM traces only: baseline
        // optimizers have no sampling ladder to converge.
        let mut no_decision = complete_trace();
        no_decision.events.retain(|(_, e)| e.kind() != "converged");
        assert!(no_decision
            .completeness_errors()
            .iter()
            .any(|e| e.contains("no decision event")));
        for (_, e) in &mut no_decision.events {
            if let TraceEvent::Done { optimizer, .. } = e {
                *optimizer = "GO".to_string();
            }
        }
        assert!(no_decision.is_complete(), "{:?}", no_decision.completeness_errors());
    }

    #[test]
    fn json_rendering_is_deterministic_and_parses() {
        let trace = complete_trace();
        let a = trace.to_json().to_string_compact();
        let b = trace.to_json().to_string_compact();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.req_usize("request_id").unwrap(), 7);
        let events = parsed.req_arr("events").unwrap();
        assert_eq!(events.len(), trace.events.len());
        assert_eq!(events[0].req_str("kind").unwrap(), "route");
    }

    #[test]
    fn text_rendering_reads_as_a_provenance_chain() {
        let text = complete_trace().render_text();
        assert!(text.contains("routed to xsede/large"), "{text}");
        assert!(text.contains("admission lead [fresh sample]"), "{text}");
        assert!(text.contains("ladder step 1"), "{text}");
        assert!(text.contains("allowance clamp (8,4,2) -> (4,4,2)"), "{text}");
        assert!(text.contains("settled: estimate s3@g2, ingest offered"), "{text}");
    }

    #[test]
    fn sink_drains_in_push_order() {
        let sink = TraceSink::new();
        assert!(sink.is_empty());
        sink.push(complete_trace());
        sink.push(complete_trace());
        assert_eq!(sink.len(), 2);
        let drained = sink.drain();
        assert_eq!(drained.len(), 2);
        assert!(sink.is_empty());
    }

    #[test]
    fn provenance_kinds_and_descriptions() {
        let kb = Provenance::Kb { generation: 3, cluster: None };
        assert_eq!(kb.kind(), "kb");
        assert!(kb.describe().contains("cold"));
        let est =
            Provenance::Estimate { cluster: 1, surface: 4, generation: 2, occ_streams: 16 };
        assert_eq!(est.describe(), "estimate c1/s4@g2 occ=16");
        let leader = Provenance::Leader { cluster: 0, surface: 2, generation: 1 };
        assert_eq!(leader.describe(), "leader c0/s2@g1");
    }
}
