//! Decision-provenance telemetry: bounded streaming histograms and
//! deterministic per-request decision traces.
//!
//! Two halves, both serde-free and dependency-light:
//!
//! * [`hist`] — the log-bucketed [`LogHistogram`] behind every
//!   latency/throughput aggregate in [`crate::coordinator::metrics`]:
//!   bounded memory, mergeable, ≤1% relative quantile error, exact
//!   mean.
//! * [`trace`] — the per-request [`DecisionTrace`]: a typed event per
//!   layer hop of the serve path (routing, fault consult, link + probe
//!   admission, ASM ladder, allowance clamps, lease release,
//!   settlement), each carrying the [`Provenance`] of the knowledge it
//!   consumed. Byte-identical under the same seed; the scenario
//!   engine's `trace-complete` invariant and the `dtopt trace` CLI are
//!   built on it.
//!
//! See DESIGN.md § "Decision-provenance telemetry".

pub mod hist;
pub mod trace;

pub use hist::LogHistogram;
pub use trace::{
    traces_to_json, DecisionTrace, Provenance, TraceBuilder, TraceEvent, TraceSink,
};
