//! Decision-provenance telemetry and the fleet health plane: bounded
//! streaming histograms, deterministic per-request decision traces,
//! and the always-on observability substrate (registry, flight
//! recorder, accuracy ledger, exporters).
//!
//! Six parts, all serde-free and dependency-light:
//!
//! * [`hist`] — the log-bucketed [`LogHistogram`] behind every
//!   latency/throughput aggregate in [`crate::coordinator::metrics`]:
//!   bounded memory, mergeable, ≤1% relative quantile error, exact
//!   mean.
//! * [`trace`] — the per-request [`DecisionTrace`]: a typed event per
//!   layer hop of the serve path (routing, fault consult, link + probe
//!   admission, ASM ladder, allowance clamps, lease release,
//!   settlement), each carrying the [`Provenance`] of the knowledge it
//!   consumed. Byte-identical under the same seed; the scenario
//!   engine's `trace-complete` invariant and the `dtopt trace` CLI are
//!   built on it.
//! * [`registry`] — the unified, lock-sharded metrics [`Registry`]:
//!   typed counters/gauges/histograms under hierarchical names plus
//!   snapshot-time collectors, read out as one deterministic
//!   [`Snapshot`] every subsystem publishes into.
//! * [`recorder`] — the bounded [`FlightRecorder`]: a fixed-capacity
//!   ring of per-request [`FlightRecord`] summaries, always on
//!   (`dtopt obs --recent N`).
//! * [`health`] — the [`AccuracyLedger`]: every completed transfer
//!   scored against the simulator oracle's optimal, rolled into
//!   per-shard quantiles — the paper's 93%-of-optimal headline as a
//!   continuously tracked fleet metric.
//! * [`export`] — deterministic Prometheus-text and JSON exporters
//!   over a snapshot (`dtopt obs`, `--metrics-out`, CI's
//!   obs-conformance byte-diff).
//! * [`window`] — the [`WindowRing`]: a fixed-capacity ring of
//!   per-window counter deltas / histogram merges / gauge levels cut
//!   from the cumulative snapshots, keyed by virtual time — rolling
//!   rates and short/long horizons in bounded memory.
//! * [`sentry`] — the [`Sentry`] detector engine over those windows: a
//!   fixed, ordered anomaly detector set evaluated each settlement,
//!   emitting typed [`Alert`] raise/clear edges deterministic enough to
//!   be a scenario conformance surface (`expect-alert`,
//!   `alert-conformance`).
//!
//! See DESIGN.md § "Decision-provenance telemetry", § "Fleet health
//! plane", and § "Sentry plane".

pub mod export;
pub mod health;
pub mod hist;
pub mod recorder;
pub mod registry;
pub mod sentry;
pub mod trace;
pub mod window;

pub use health::{AccuracyLedger, AccuracySummary};
pub use hist::LogHistogram;
pub use recorder::{FlightRecord, FlightRecorder};
pub use registry::{Counter, Gauge, Hist, Registry, Samples, Snapshot, Value};
pub use sentry::{
    alerts_to_json, render_alerts, Alert, Sentry, SentryConfig, Settlement, DETECTORS,
};
pub use trace::{
    traces_to_json, DecisionTrace, Provenance, TraceBuilder, TraceEvent, TraceSink,
};
pub use window::{WindowFrame, WindowRing};
