//! The accuracy ledger: achieved-vs-optimal scoring, per shard,
//! continuously.
//!
//! The paper's headline number — "up to 93% accuracy compared with the
//! optimal achievable throughput" — is an offline evaluation result.
//! This module makes it an always-on fleet metric: every completed
//! transfer is scored as `achieved_mbps / optimal_mbps`, where the
//! oracle is the same one the experiments score against —
//! `TransferPath::optimal` evaluated under the request's own hidden
//! network state (the simulator's exhaustive best over every parameter
//! choice, the quantity `TransferResponse::optimal_mbps` already
//! carries). Ratios accumulate into one mergeable
//! [`LogHistogram`] per shard plus an overall pool, so rolling
//! quantiles (p10/p50/p90) are available per `ShardKey` at any time
//! and merge exactly across coordinators.
//!
//! The ratio can exceed 1.0: the oracle is evaluated at the *submit*
//! instant's state, while a transfer's achieved goodput integrates
//! over its whole (simulated) run — a load drop mid-transfer can beat
//! the frozen oracle. That is signal, not error, so ratios are only
//! clamped below at zero.
//!
//! The scenario engine asserts a floor over these ratios per replay
//! (`scenario::invariant::accuracy_floor_report`); the exporters
//! publish the per-shard histograms as `health.accuracy.<shard>`
//! families (see `DESIGN.md` §Fleet health plane).

use super::hist::LogHistogram;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Per-shard achieved-vs-optimal accuracy quantiles (see module docs).
#[derive(Debug, Default)]
pub struct AccuracyLedger {
    shards: Mutex<BTreeMap<String, LogHistogram>>,
    overall: Mutex<LogHistogram>,
}

/// One shard's rolled-up accuracy summary.
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracySummary {
    pub transfers: u64,
    pub mean: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
}

fn summarize(hist: &LogHistogram) -> AccuracySummary {
    AccuracySummary {
        transfers: hist.count(),
        mean: hist.mean(),
        p10: hist.quantile(0.10),
        p50: hist.quantile(0.50),
        p90: hist.quantile(0.90),
    }
}

impl AccuracyLedger {
    pub fn new() -> AccuracyLedger {
        AccuracyLedger::default()
    }

    /// Score one completed transfer. A non-positive or non-finite
    /// oracle means no oracle was computed for this request — nothing
    /// is recorded (scoring against a missing optimum would poison the
    /// quantiles with zeros).
    pub fn score(&self, shard: &str, achieved_mbps: f64, optimal_mbps: f64) {
        if !(optimal_mbps > 0.0) || !achieved_mbps.is_finite() {
            return;
        }
        let ratio = (achieved_mbps / optimal_mbps).max(0.0);
        self.shards
            .lock()
            .expect("ledger poisoned")
            .entry(shard.to_string())
            .or_default()
            .record(ratio);
        self.overall.lock().expect("ledger poisoned").record(ratio);
    }

    /// Transfers scored across every shard.
    pub fn scored(&self) -> u64 {
        self.overall.lock().expect("ledger poisoned").count()
    }

    /// The pooled accuracy summary (`None` when nothing is scored yet).
    pub fn overall(&self) -> Option<AccuracySummary> {
        let overall = self.overall.lock().expect("ledger poisoned");
        (!overall.is_empty()).then(|| summarize(&overall))
    }

    /// One shard's accuracy summary.
    pub fn shard(&self, shard: &str) -> Option<AccuracySummary> {
        self.shards.lock().expect("ledger poisoned").get(shard).map(summarize)
    }

    /// Every shard's raw histogram, ordered by shard name (the pooled
    /// histogram under the reserved name is *not* included).
    pub fn snapshot(&self) -> BTreeMap<String, LogHistogram> {
        self.shards.lock().expect("ledger poisoned").clone()
    }

    /// The pooled histogram.
    pub fn overall_hist(&self) -> LogHistogram {
        self.overall.lock().expect("ledger poisoned").clone()
    }

    /// Human-readable block (rendered by `dtopt obs`, deliberately not
    /// part of `Metrics::render`, whose bytes are golden-pinned).
    pub fn render(&self) -> String {
        let Some(overall) = self.overall() else {
            return "accuracy ledger: no scored transfers yet\n".to_string();
        };
        let mut out = format!(
            "accuracy ledger: p10 {:.2}, p50 {:.2}, p90 {:.2} of optimal over {} transfers\n",
            overall.p10, overall.p50, overall.p90, overall.transfers,
        );
        for (shard, hist) in self.snapshot() {
            let s = summarize(&hist);
            out.push_str(&format!(
                "  {shard}: p10 {:.2}, p50 {:.2}, p90 {:.2} ({} transfers)\n",
                s.p10, s.p50, s.p90, s.transfers,
            ));
        }
        out
    }

    /// Machine-readable form: quantiles plus the raw mergeable
    /// histograms, per shard and pooled.
    pub fn to_json(&self) -> Json {
        let summary_json = |s: &AccuracySummary, hist: &LogHistogram| {
            let mut obj = Json::obj();
            obj.set("transfers", Json::Num(s.transfers as f64))
                .set("mean", Json::Num(s.mean))
                .set("p10", Json::Num(s.p10))
                .set("p50", Json::Num(s.p50))
                .set("p90", Json::Num(s.p90))
                .set("histogram", hist.to_json());
            obj
        };
        let mut obj = Json::obj();
        if let Some(overall) = self.overall() {
            obj.set("overall", summary_json(&overall, &self.overall_hist()));
        }
        let mut shards = Json::obj();
        for (shard, hist) in self.snapshot() {
            shards.set(&shard, summary_json(&summarize(&hist), &hist));
        }
        obj.set("shards", shards);
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_accumulate_per_shard_and_overall() {
        let ledger = AccuracyLedger::new();
        ledger.score("xsede/large", 930.0, 1000.0);
        ledger.score("xsede/large", 800.0, 1000.0);
        ledger.score("didclab/small", 450.0, 500.0);
        assert_eq!(ledger.scored(), 3);
        let xsede = ledger.shard("xsede/large").unwrap();
        assert_eq!(xsede.transfers, 2);
        assert!((xsede.mean - 0.865).abs() < 1e-9, "{}", xsede.mean);
        let overall = ledger.overall().unwrap();
        assert_eq!(overall.transfers, 3);
        assert!(ledger.shard("no/such").is_none());
    }

    #[test]
    fn missing_oracle_is_not_scored() {
        let ledger = AccuracyLedger::new();
        ledger.score("x", 100.0, 0.0);
        ledger.score("x", 100.0, -1.0);
        ledger.score("x", 100.0, f64::NAN);
        ledger.score("x", f64::NAN, 100.0);
        assert_eq!(ledger.scored(), 0);
        assert!(ledger.overall().is_none());
    }

    #[test]
    fn ratios_above_one_are_kept() {
        // A mid-transfer load drop can beat the frozen submit-time
        // oracle; the ledger records it rather than clamping to 1.
        let ledger = AccuracyLedger::new();
        ledger.score("x", 1200.0, 1000.0);
        let s = ledger.shard("x").unwrap();
        assert!((s.p50 - 1.2).abs() < 1e-9, "{}", s.p50);
    }

    #[test]
    fn quantiles_track_the_distribution() {
        let ledger = AccuracyLedger::new();
        for pct in [80, 85, 90, 93, 96] {
            ledger.score("x", pct as f64, 100.0);
        }
        let s = ledger.shard("x").unwrap();
        assert!((s.p50 - 0.90).abs() < 0.01, "{}", s.p50);
        assert!(s.p10 >= 0.79 && s.p10 <= 0.86, "{}", s.p10);
        assert!(s.p90 >= 0.92 && s.p90 <= 0.97, "{}", s.p90);
    }

    #[test]
    fn render_and_json_report_every_shard() {
        let ledger = AccuracyLedger::new();
        ledger.score("a/one", 90.0, 100.0);
        ledger.score("b/two", 50.0, 100.0);
        let text = ledger.render();
        assert!(text.contains("a/one"), "{text}");
        assert!(text.contains("b/two"), "{text}");
        assert!(text.contains("over 2 transfers"), "{text}");
        let json = ledger.to_json();
        let shards = json.get("shards").unwrap();
        assert!(shards.get("a/one").is_some());
        assert_eq!(
            json.get("overall").and_then(|o| o.get("transfers")).and_then(Json::as_u64),
            Some(2)
        );
    }

    #[test]
    fn empty_ledger_renders_a_placeholder() {
        let ledger = AccuracyLedger::new();
        assert_eq!(ledger.render(), "accuracy ledger: no scored transfers yet\n");
    }
}
